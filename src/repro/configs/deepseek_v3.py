"""deepseek-v3 — paper evaluation model (§7.2): 256 routed experts, 8 active,
MLA.  [arXiv:2412.19437]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    num_experts=256,
    top_k=8,
    moe_d_ff=2048,
    num_shared_experts=1,
    first_k_dense=3,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
)
