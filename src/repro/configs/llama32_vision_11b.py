"""llama-3.2-vision-11b [vlm] — cross-attention image layers every 5th layer;
the ViT vision encoder + projector is a stub providing patch embeddings via
``input_specs()``.  [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=5,      # 8 cross-attention layers among 40
    num_image_tokens=1601,   # (448/14)^2 + cls, per image tile
)
