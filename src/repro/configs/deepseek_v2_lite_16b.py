"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512, decoupled RoPE),
2 shared + 64 routed experts top-6, first layer dense.  [arXiv:2405.04434]

This is also one of the paper's own evaluation models (§7.2), so it is the
primary subject of the ElasticMoE reproduction experiments.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,      # MLA: one latent head; kept for bookkeeping
    d_ff=10944,           # dense MLP of the first layer
    vocab_size=102400,
    num_experts=64,
    top_k=6,
    moe_d_ff=1408,
    num_shared_experts=2,
    first_k_dense=1,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=0,        # v2-lite uses full-rank q
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
)
