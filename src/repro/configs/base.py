"""Model configuration system.

Every assigned architecture (and the paper's own evaluation models) is an
instance of :class:`ModelConfig`.  The config is a *complete* architectural
description — ``models/model.py`` builds init/apply functions from it with no
other inputs, and ``launch/dryrun.py`` derives input specs from it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

ArchType = str  # "dense" | "moe" | "ssm" | "hybrid" | "encoder" | "vlm"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: ArchType
    num_layers: int
    d_model: int
    vocab_size: int

    # ---- attention ----
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                 # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_fraction: float = 1.0        # fraction of head_dim that is rotary
                                      # (chatglm3 "2d rope" = 0.5, stablelm = 0.25)
    attn_window: Optional[int] = None # sliding-window attention (beyond-paper
                                      # variant enabling long_500k on dense archs)
    causal: bool = True               # False for encoder-only (hubert)

    # ---- feed-forward ----
    d_ff: int = 0                     # dense MLP hidden dim (SwiGLU)
    mlp_gated: bool = True            # SwiGLU vs plain GELU MLP

    # ---- norm ----
    norm_type: str = "rmsnorm"        # "rmsnorm" | "layernorm"

    # ---- MoE ----
    num_experts: int = 0              # routed experts (0 -> dense MLP)
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    num_shared_experts: int = 0       # deepseek-style shared experts
    dense_residual: bool = False      # arctic: dense MLP in parallel with MoE
    first_k_dense: int = 0            # deepseek: first k layers use dense MLP
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # ---- MLA (deepseek v2) ----
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0              # 0 -> full-rank q projection
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # ---- SSM (mamba2 / zamba2) ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # ---- hybrid (zamba2) ----
    attn_every: int = 0               # shared attention block every k ssm blocks

    # ---- vlm (llama 3.2 vision) ----
    cross_attn_every: int = 0         # cross-attn layer every k self-attn layers
    num_image_tokens: int = 0         # patch embeddings provided by stub frontend

    # ---- audio (hubert) ----
    num_frame_tokens: int = 0         # frame embeddings provided by stub frontend

    # ---- substrate ----
    dtype: str = "bfloat16"
    max_seq_len: int = 131072
    tie_embeddings: bool = False

    # -------------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def has_decode(self) -> bool:
        """Encoder-only architectures have no autoregressive decode step."""
        return self.arch_type != "encoder"

    @property
    def supports_long_context(self) -> bool:
        """True if a 500k-token decode is sub-quadratic / O(1)-state."""
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return self.attn_window is not None

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    # Parameter count (embedding + blocks), used for roofline MODEL_FLOPS.
    def param_count(self, active_only: bool = False) -> int:
        D, H = self.d_model, self.num_heads
        hd = self.resolved_head_dim
        kvh = self.num_kv_heads
        n = 0
        n += self.vocab_size * D                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * D                  # lm head
        per_layer = 0
        # attention
        if self.arch_type not in ("ssm",):
            if self.use_mla:
                r, qr = self.kv_lora_rank, (self.q_lora_rank or 0)
                qk = self.qk_nope_dim + self.qk_rope_dim
                if qr:
                    per_attn = D * qr + qr * H * qk
                else:
                    per_attn = D * H * qk
                per_attn += D * (r + self.qk_rope_dim)          # kv down + k_rope
                per_attn += r * H * (self.qk_nope_dim + self.v_head_dim)
                per_attn += H * self.v_head_dim * D             # o proj
            else:
                per_attn = D * H * hd + 2 * D * kvh * hd + H * hd * D
        else:
            per_attn = 0
        # ffn
        ff_mult = 3 if self.mlp_gated else 2
        if self.is_moe:
            routed = self.num_experts * ff_mult * D * self.moe_d_ff
            active = self.top_k * ff_mult * D * self.moe_d_ff
            shared = self.num_shared_experts * ff_mult * D * self.moe_d_ff
            dense = ff_mult * D * self.d_ff if self.dense_residual else 0
            per_ffn = (active if active_only else routed) + shared + dense
            per_ffn += D * self.num_experts                     # router
        elif self.d_ff:
            per_ffn = ff_mult * D * self.d_ff
        else:
            per_ffn = 0
        # ssm
        per_ssm = 0
        if self.arch_type in ("ssm", "hybrid"):
            di, ds = self.d_inner, self.ssm_state
            nh = self.ssm_heads
            per_ssm = D * (2 * di + 2 * ds + nh) + di * self.ssm_conv + di * D
        if self.arch_type == "ssm":
            per_layer = per_ssm
        elif self.arch_type == "hybrid":
            per_layer = per_ssm  # shared attn counted once below
        else:
            per_layer = per_attn + per_ffn
        n += self.num_layers * per_layer
        if self.arch_type == "hybrid" and self.attn_every:
            # one shared attention+mlp block reused every attn_every layers
            n += (D * H * hd + 2 * D * kvh * hd + H * hd * D
                  + ff_mult * D * self.d_ff)
        if self.arch_type == "vlm" and self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            n += n_cross * (D * H * hd + 2 * D * kvh * hd + H * hd * D)
        return n


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized variant of the same architecture family
    (2 layers, d_model<=512, <=4 experts), per the reproduction brief."""
    small: dict = dict(
        num_layers=2,
        d_model=min(cfg.d_model, 256),
        vocab_size=min(cfg.vocab_size, 512),
        max_seq_len=1024,
    )
    if cfg.num_heads:
        small["num_heads"] = min(cfg.num_heads, 4)
        small["num_kv_heads"] = max(1, min(cfg.num_kv_heads,
                                           min(cfg.num_heads, 4)))
        small["head_dim"] = 64 if cfg.resolved_head_dim >= 64 else cfg.resolved_head_dim
    if cfg.d_ff:
        small["d_ff"] = min(cfg.d_ff, 512)
    if cfg.is_moe:
        small["num_experts"] = min(cfg.num_experts, 4)
        small["top_k"] = min(cfg.top_k, 2)
        small["moe_d_ff"] = min(cfg.moe_d_ff, 256)
        small["num_shared_experts"] = min(cfg.num_shared_experts, 1)
        small["first_k_dense"] = min(cfg.first_k_dense, 1)
    if cfg.use_mla:
        small["kv_lora_rank"] = min(cfg.kv_lora_rank, 64)
        small["q_lora_rank"] = min(cfg.q_lora_rank, 64) if cfg.q_lora_rank else 0
        small["qk_nope_dim"] = 32
        small["qk_rope_dim"] = 16
        small["v_head_dim"] = 32
        small["head_dim"] = 0
    if cfg.ssm_state:
        small["ssm_state"] = min(cfg.ssm_state, 16)
        small["ssm_head_dim"] = 32
        small["ssm_chunk"] = 16
    if cfg.attn_every:
        small["attn_every"] = 1
        small["num_layers"] = 2
    if cfg.cross_attn_every:
        small["cross_attn_every"] = 2
        small["num_image_tokens"] = 16
    if cfg.num_frame_tokens:
        small["num_frame_tokens"] = 64
    small["dtype"] = "float32"
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
