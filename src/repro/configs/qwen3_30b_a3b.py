"""qwen3-30b-a3b — paper evaluation model (§7.2): 128 experts, 8 active.
[arXiv:2505.09388]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    num_experts=128,
    top_k=8,
    moe_d_ff=768,
)
