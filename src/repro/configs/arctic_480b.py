"""arctic-480b [moe] — 128 routed experts top-2 in parallel with a dense
residual MLP (dense-MoE hybrid).  [hf:Snowflake/snowflake-arctic-base]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,            # dense residual MLP hidden dim
    vocab_size=32000,
    num_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,  # arctic: dense FFN + MoE in parallel
)
