"""chatglm3-6b [dense] — RoPE 2d (partial rotary 0.5), GQA kv=2.  [arXiv:2406.12793]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    arch_type="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_fraction=0.5,   # chatglm applies rotary to half of each head dim
    qkv_bias=True,       # chatglm uses bias on qkv only
)
