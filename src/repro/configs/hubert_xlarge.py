"""hubert-xlarge [audio] — encoder-only transformer backbone (same arch as
wav2vec2); the conv feature-extractor frontend is a stub that provides frame
embeddings via ``input_specs()``.  [arXiv:2106.07447]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="encoder",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    rope_fraction=0.0,    # hubert uses learned/conv positions; frontend stub
    mlp_gated=False,      # GELU MLP
    norm_type="layernorm",
    num_frame_tokens=1,   # frames arrive pre-embedded from the stub frontend
)
