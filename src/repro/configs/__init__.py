"""Config registry: ``get_config(name)`` / ``--arch <id>``.

The ten assigned architectures plus the paper's own evaluation models.
"""
from __future__ import annotations

from repro.configs.base import (INPUT_SHAPES, SHAPES_BY_NAME, InputShape,
                                ModelConfig, reduced)

from repro.configs.chatglm3_6b import CONFIG as _chatglm3
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.llama32_vision_11b import CONFIG as _llama_vision
from repro.configs.qwen15_05b import CONFIG as _qwen15
from repro.configs.stablelm_3b import CONFIG as _stablelm
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.mamba2_13b import CONFIG as _mamba2
from repro.configs.yi_6b import CONFIG as _yi
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2lite
from repro.configs.zamba2_27b import CONFIG as _zamba2
from repro.configs.qwen3_30b_a3b import CONFIG as _qwen3moe
from repro.configs.deepseek_v3 import CONFIG as _dsv3

# The 10 assigned architectures (public-literature pool).
ASSIGNED = {
    c.name: c
    for c in (
        _chatglm3, _hubert, _llama_vision, _qwen15, _stablelm,
        _arctic, _mamba2, _yi, _dsv2lite, _zamba2,
    )
}

# Paper's own evaluation models (deepseek-v2-lite is in both sets).
PAPER_MODELS = {c.name: c for c in (_dsv2lite, _qwen3moe, _dsv3)}

REGISTRY = {**ASSIGNED, **PAPER_MODELS}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced(get_config(name[: -len("-smoke")]))
    if name not in REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = [
    "ModelConfig", "InputShape", "INPUT_SHAPES", "SHAPES_BY_NAME",
    "ASSIGNED", "PAPER_MODELS", "REGISTRY", "get_config", "reduced",
]
