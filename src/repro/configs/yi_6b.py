"""yi-6b [dense] — llama-architecture GQA kv=4.  [arXiv:2403.04652]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
)
