"""zamba2-2.7b [hybrid] — Mamba2 backbone with a single *shared* attention
block applied every 6 SSM blocks.  [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,           # shared attention block's MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=128,
    attn_every=6,         # 54 layers -> 9 shared-attention applications
)
