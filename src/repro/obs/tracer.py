"""Lock-cheap, thread-safe span tracer (DESIGN.md §9).

One process-global :class:`Tracer` (installed via :func:`install`) collects
timeline events from every layer of the serving stack — scale-phase spans,
per-``TransferOp`` worker-thread spans, decode-tick spans, request
lifecycle instants, routing-skew counters — into a bounded ring buffer.

Design constraints, in order:

* **true no-op when disabled** — the default global is a
  :data:`NULL_TRACER` singleton whose methods return immediately; hot
  paths pay one module-global read plus an attribute call.  The
  :func:`traced` decorator additionally short-circuits on an identity
  check so wrapped methods skip even the context-manager protocol.
* **thread-safe without a hot-path lock** — events land in a
  ``collections.deque(maxlen=...)``; ``deque.append`` is atomic under the
  GIL, so ``TransferEngine`` worker threads and the serve loop record
  concurrently without contention.  The only lock guards the (rare)
  first-sighting registration of a thread name.
* **monotonic, injectable clock** — defaults to ``time.perf_counter``;
  the simulator installs a tracer whose clock reads modelled time, and
  every recording method also accepts explicit timestamps so
  already-measured intervals (``TransferOp.t_done``) and sim-time spans
  (``SimScaleEvent.t_command``..``t_ready``) export losslessly.

Timestamps are stored in **seconds** (clock domain of the installed
clock); the Chrome-trace exporter (obs/export.py) converts to µs.
"""
from __future__ import annotations

import functools
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Union

Lane = Union[int, str]


class TraceEvent:
    """One recorded event.  ``ph`` follows the Chrome-trace phase codes:
    ``"X"`` complete span (``t0``..``t1``), ``"i"`` instant (``t0``),
    ``"C"`` counter sample (``t0``, value in ``args``)."""

    __slots__ = ("name", "cat", "ph", "t0", "t1", "tid", "args")

    def __init__(self, name: str, cat: str, ph: str, t0: float, t1: float,
                 tid: Lane, args: Optional[dict]):
        self.name = name
        self.cat = cat
        self.ph = ph
        self.t0 = t0
        self.t1 = t1
        self.tid = tid
        self.args = args

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def __repr__(self) -> str:  # debugging/tests
        return (f"TraceEvent({self.name!r}, cat={self.cat!r}, ph={self.ph!r},"
                f" t0={self.t0:.6f}, dur={self.dur:.6f}, tid={self.tid!r})")


class _Span:
    """Re-entrant-free context manager emitted by :meth:`Tracer.span`."""

    __slots__ = ("_tr", "_name", "_cat", "_args", "_tid", "_t0")

    def __init__(self, tr: "Tracer", name: str, cat: str,
                 args: Optional[dict], tid: Optional[Lane]):
        self._tr = tr
        self._name = name
        self._cat = cat
        self._args = args
        self._tid = tid

    def __enter__(self) -> "_Span":
        self._t0 = self._tr._clock()
        return self

    def __exit__(self, *exc) -> bool:
        self._tr.complete(self._name, self._t0, self._tr._clock(),
                          cat=self._cat, args=self._args, tid=self._tid)
        return False


class MetricsRegistry:
    """Thread-safe counters and gauges, independent of the event buffer
    (aggregates survive ring-buffer eviction)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}

    def inc(self, name: str, value: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        with self._lock:
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges)}


class Tracer:
    """Collecting tracer.  All recording methods are safe to call from any
    thread; events beyond ``capacity`` evict the oldest (bounded memory —
    a serve loop can run traced indefinitely)."""

    enabled = True

    def __init__(self, *, capacity: int = 65536,
                 clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._events: deque = deque(maxlen=capacity)
        self._thread_names: Dict[int, str] = {}
        self._name_lock = threading.Lock()
        self.metrics = MetricsRegistry()

    # ------------------------------------------------------------- clock
    def now(self) -> float:
        return self._clock()

    # ------------------------------------------------------------ record
    def _resolve_tid(self, tid: Optional[Lane]) -> Lane:
        if tid is not None:
            return tid
        ident = threading.get_ident()
        if ident not in self._thread_names:
            with self._name_lock:
                self._thread_names.setdefault(
                    ident, threading.current_thread().name)
        return ident

    def complete(self, name: str, t0: float, t1: float, *, cat: str = "",
                 args: Optional[dict] = None,
                 tid: Optional[Lane] = None) -> None:
        """Record an already-measured span (explicit timestamps, in the
        tracer's clock domain — real seconds or sim seconds)."""
        self._events.append(TraceEvent(name, cat, "X", t0, t1,
                                       self._resolve_tid(tid), args))

    def span(self, name: str, *, cat: str = "",
             args: Optional[dict] = None,
             tid: Optional[Lane] = None) -> _Span:
        """``with tracer.span("decode.tick", cat="serve"): ...`` — times
        the body with the tracer's clock."""
        return _Span(self, name, cat, args, tid)

    def instant(self, name: str, *, cat: str = "",
                args: Optional[dict] = None, t: Optional[float] = None,
                tid: Optional[Lane] = None) -> None:
        if t is None:
            t = self._clock()
        self._events.append(TraceEvent(name, cat, "i", t, t,
                                       self._resolve_tid(tid), args))

    def counter(self, name: str, value: float, *, cat: str = "",
                t: Optional[float] = None,
                tid: Optional[Lane] = None) -> None:
        if t is None:
            t = self._clock()
        self._events.append(TraceEvent(name, cat, "C", t, t,
                                       self._resolve_tid(tid),
                                       {"value": value}))

    # ------------------------------------------------------------ access
    def events(self) -> List[TraceEvent]:
        return list(self._events)

    def thread_names(self) -> Dict[int, str]:
        with self._name_lock:
            return dict(self._thread_names)

    def clear(self) -> None:
        self._events.clear()


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled fast path: every method returns immediately.  ``now``
    still reads the wall clock so call sites can use it unconditionally."""

    enabled = False
    metrics = None  # sentinel: no aggregation when disabled

    def now(self) -> float:
        return time.perf_counter()

    def complete(self, *a: Any, **k: Any) -> None:
        pass

    def span(self, *a: Any, **k: Any) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, *a: Any, **k: Any) -> None:
        pass

    def counter(self, *a: Any, **k: Any) -> None:
        pass

    def events(self) -> List[TraceEvent]:
        return []

    def thread_names(self) -> Dict[int, str]:
        return {}

    def clear(self) -> None:
        pass


NULL_TRACER = NullTracer()
_active: Union[Tracer, NullTracer] = NULL_TRACER


def install(tracer: Optional[Tracer]) -> Union[Tracer, NullTracer]:
    """Install the process-global tracer (``None`` disables tracing)."""
    global _active
    _active = tracer if tracer is not None else NULL_TRACER
    return _active


def get_tracer() -> Union[Tracer, NullTracer]:
    return _active


def traced(name: str, cat: str = "") -> Callable:
    """Decorator form of :meth:`Tracer.span` with a disabled-path
    short-circuit: one global read + identity check per call."""
    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any):
            tr = _active
            if tr is NULL_TRACER:
                return fn(*args, **kwargs)
            with tr.span(name, cat=cat):
                return fn(*args, **kwargs)
        return wrapper
    return deco
