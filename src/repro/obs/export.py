"""Chrome-trace / Perfetto JSON export (DESIGN.md §9).

Converts a :class:`~repro.obs.tracer.Tracer`'s event buffer into the
Trace Event Format consumed by ``chrome://tracing``, Perfetto UI and
``tools/trace_report.py``:

    {"traceEvents": [{"name", "cat", "ph", "ts", "dur", "pid", "tid",
                      "args"}, ...],
     "displayTimeUnit": "ms"}

Timestamps are exported in **microseconds relative to the earliest
event** so real-clock (``perf_counter``) and sim-clock traces both start
near zero.  Lanes: integer ``tid``s are OS thread idents (named from the
tracer's lazy thread-name capture — the ``hmm-transfer-*`` workers get
their own rows); string lanes (``"scale"``, ``"sim"``) are mapped to
stable synthetic tids with ``thread_name`` metadata.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Union

from repro.obs.tracer import NullTracer, TraceEvent, Tracer

PID = 1


def chrome_trace(tracer: Union[Tracer, NullTracer],
                 extra_metadata: Optional[dict] = None) -> dict:
    """Render the tracer's buffered events as a Chrome-trace document."""
    events = tracer.events()
    t_base = min((e.t0 for e in events), default=0.0)
    lane_ids: Dict[str, int] = {}
    out: List[dict] = [{"ph": "M", "name": "process_name", "pid": PID,
                        "tid": 0, "args": {"name": "repro"}}]

    def lane(tid) -> int:
        if isinstance(tid, str):
            if tid not in lane_ids:
                # synthetic lanes get small negative tids: they sort ahead
                # of OS-thread rows and can never collide with an ident
                lane_ids[tid] = -(len(lane_ids) + 1)
                out.append({"ph": "M", "name": "thread_name", "pid": PID,
                            "tid": lane_ids[tid], "args": {"name": tid}})
            return lane_ids[tid]
        return tid

    for ident, name in tracer.thread_names().items():
        out.append({"ph": "M", "name": "thread_name", "pid": PID,
                    "tid": ident, "args": {"name": name}})
    for e in events:
        ts = (e.t0 - t_base) * 1e6
        rec = {"name": e.name, "cat": e.cat or "default", "ph": e.ph,
               "ts": ts, "pid": PID, "tid": lane(e.tid)}
        if e.ph == "X":
            rec["dur"] = max(e.t1 - e.t0, 0.0) * 1e6
        elif e.ph == "i":
            rec["s"] = "t"  # thread-scoped instant
        if e.args:
            rec["args"] = dict(e.args)
        out.append(rec)
    doc = {"traceEvents": out, "displayTimeUnit": "ms"}
    if extra_metadata:
        doc["metadata"] = dict(extra_metadata)
    return doc


def write_chrome_trace(path: str, tracer: Union[Tracer, NullTracer],
                       extra_metadata: Optional[dict] = None) -> dict:
    doc = chrome_trace(tracer, extra_metadata)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return doc


def load_trace(path: str) -> dict:
    """Load and schema-check an exported trace (raises on malformed)."""
    with open(path) as fh:
        doc = json.load(fh)
    validate_trace(doc)
    return doc


def validate_trace(doc: dict) -> None:
    """Minimal Trace-Event-Format schema check (CI smoke + tests)."""
    assert isinstance(doc, dict) and "traceEvents" in doc, \
        "not a Chrome-trace document"
    for rec in doc["traceEvents"]:
        assert {"ph", "pid", "tid"} <= rec.keys(), rec
        if rec["ph"] in ("X", "i", "C"):
            assert "ts" in rec and "name" in rec, rec
        if rec["ph"] == "X":
            assert "dur" in rec and rec["dur"] >= 0, rec
