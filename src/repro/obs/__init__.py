"""Unified tracing & telemetry for the serving stack (DESIGN.md §9).

Span schema (shared by the real engine and the simulator — a driver
closed-loop run over either backend exports the same trace shape):

| cat        | events (ph)                                               |
|------------|-----------------------------------------------------------|
| ``scale``  | ``scale.<PHASE>`` spans, one per ScalePhase (lane "scale")|
| ``hmm``    | ``hmm.begin_scale/stage_increment/commit/abort/boot`` spans|
| ``transfer``| one span per TransferOp, named by its label, emitted on  |
|            | the worker thread that ran it (kvmig ops included)        |
| ``serve``  | ``decode.tick`` / ``prefill.chunks`` spans, ``chunk.plan``|
|            | / ``admit`` / ``preempt`` / ``kv.cow_copy`` instants      |
| ``req``    | ``req.admit`` / ``req.first_token`` / ``req.finish``      |
| ``routing``| ``routing.top_expert_share`` counter samples              |

Usage::

    from repro import obs
    obs.install(obs.Tracer())            # enable (None to disable)
    ... serve ...
    obs.write_chrome_trace("trace.json", obs.get_tracer())
"""
from repro.obs.export import (chrome_trace, load_trace, validate_trace,
                              write_chrome_trace)
from repro.obs.tracer import (NULL_TRACER, MetricsRegistry, NullTracer,
                              TraceEvent, Tracer, get_tracer, install,
                              traced)

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "TraceEvent", "MetricsRegistry",
    "install", "get_tracer", "traced",
    "chrome_trace", "write_chrome_trace", "load_trace", "validate_trace",
]
