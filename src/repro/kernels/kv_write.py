"""In-place ragged KV-cache write (Pallas TPU, input/output aliasing).

EXPERIMENTS.md §Perf iteration 0 found that the XLA:CPU lowering of the
per-sequence cache write (`cache.at[b, pos[b]].set(...)`) materializes an
f32 round-trip copy of the whole cache.  On TPU the correct primitive is an
*aliased* kernel: ``input_output_aliases={1: 0}`` makes the output buffer
the cache buffer itself, and the grid touches exactly one (sequence, block)
tile per batch row — the rest of the cache is never read or written.

The write position arrives via scalar prefetch so the BlockSpec index_map
selects the single block containing ``pos[b]``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(pos_ref, new_ref, cache_ref, out_ref, *, block_s):
    b = pl.program_id(0)
    off = pos_ref[b] % block_s
    # copy-through then overwrite one row: the block is both read & written,
    # everything outside this block is untouched (aliased buffer)
    block = cache_ref[0]
    row = new_ref[0].astype(out_ref.dtype)              # [KVH, hd]
    upd = jax.lax.dynamic_update_slice(
        block, row[None], (off, 0, 0))
    out_ref[0] = upd


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"),
                   donate_argnums=(0,))
def kv_cache_write(cache: jax.Array, new_kv: jax.Array, pos: jax.Array, *,
                   block_s: int = 128, interpret: bool = False) -> jax.Array:
    """cache [B,S,KVH,hd]; new_kv [B,KVH,hd]; pos [B] -> updated cache.

    Writes ``new_kv[b]`` at ``cache[b, pos[b]]`` touching one S-block per
    sequence; the cache buffer is donated + aliased (true in-place on TPU).
    """
    B, S, KVH, hd = cache.shape
    bs = min(block_s, S)
    assert S % bs == 0

    return pl.pallas_call(
        functools.partial(_kernel, block_s=bs),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B,),
            in_specs=[
                pl.BlockSpec((1, KVH, hd), lambda b, pos: (b, 0, 0)),
                pl.BlockSpec((1, bs, KVH, hd),
                             lambda b, pos: (b, pos[b] // bs, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bs, KVH, hd),
                                   lambda b, pos: (b, pos[b] // bs, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        input_output_aliases={2: 0},   # flat inputs: (pos, new_kv, cache)
                                       # -> cache (idx 2) aliases output 0
        interpret=interpret,
    )(pos, new_kv, cache)
