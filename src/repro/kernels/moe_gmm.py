"""Paged grouped expert matmul (Pallas TPU).

This kernel is the *consumer* of the virtual expert page table
(core/expert_pages.py): expert weights live as non-contiguous pages in a
per-device pool, and the kernel addresses them **by index** via scalar
prefetch — the TPU-native realization of the paper's vpage-remap.  EP
reconfiguration only rewrites the (tiny) page table; no weight buffer is
ever reshaped or copied locally, and XLA never materializes a gathered
weight tensor.

Layout
------
pool   [n_pages, D, F]   physical pages, one expert's (wi|wg|wo) per page
table  [E_local]  int32  page index of each local expert (scalar prefetch)
x      [E_local, C, D]   dispatched tokens, grouped per expert
out    [E_local, C, F]

Grid: (E_local, C/bc, F/bf); the D contraction is unblocked (one MXU pass
per tile).  Block shapes default to MXU-aligned 128x128 tiles; VMEM per
step = bc*D + D*bf + bc*bf elements (~2.5 MB at D=2048, f32) << 16 MB v5e
VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(table_ref, x_ref, pool_ref, o_ref):
    # x_ref: [1, bc, D]; pool_ref: [1, D, bf] (page selected via index_map)
    x = x_ref[0]
    w = pool_ref[0]
    o_ref[0] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _clamp_block_f(dim: int, block: int) -> int:
    """The 'clamp' half of pad-or-clamp for the lane (minormost) dim, which
    the kernel cannot cheaply pad: largest multiple of 128 <= ``block`` that
    divides ``dim`` — Mosaic requires lane blocks to be 128-aligned — else
    the full dim (always legal, just a bigger VMEM tile)."""
    b = min(block, dim) - min(block, dim) % 128
    while b >= 128 and dim % b:
        b -= 128
    return b if b >= 128 and dim % b == 0 else dim


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def paged_gmm(table: jax.Array, pool: jax.Array, x: jax.Array,
              *, block_c: int = 128, block_f: int = 128,
              interpret: bool = False) -> jax.Array:
    """out[e] = x[e] @ pool[table[e]] for each local expert e.

    Non-MXU-aligned shapes are handled pad-or-clamp: a token count ``C`` not
    divisible by ``block_c`` is zero-padded up to the next block (zero rows
    produce zero outputs, sliced off after the call — cheap: pads
    activations, never weights; the resulting ``bc`` is either 128-aligned
    or the full dim, both Mosaic-legal).  A hidden dim ``F`` not divisible
    by ``block_f`` instead *clamps* the block — to a 128-aligned divisor or
    the whole dim, never an unaligned lane tile — because padding F would
    mean copying every pool page.  Aliased tables — multiple entries naming
    the same page, the post-CoW sharing shape — are fine by construction:
    each grid step only reads ``pool[table[e]]``.
    """
    E_local, C, D = x.shape
    n_pages, D2, F = pool.shape
    assert D == D2, (D, D2)
    bc = min(block_c, C)
    if C % bc:
        C_pad = -(-C // bc) * bc
        x = jnp.pad(x, ((0, 0), (0, C_pad - C), (0, 0)))
    bf = _clamp_block_f(F, block_f)
    C_run = x.shape[1]

    grid = (E_local, C_run // bc, F // bf)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bc, D), lambda e, i, j, tbl: (e, i, 0)),
                pl.BlockSpec((1, D, bf),
                             lambda e, i, j, tbl: (tbl[e], 0, j)),
            ],
            out_specs=pl.BlockSpec((1, bc, bf),
                                   lambda e, i, j, tbl: (e, i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((E_local, C_run, F), x.dtype),
        interpret=interpret,
    )(table, x, pool)
    return out[:, :C] if C_run != C else out


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def paged_expert_ffn(table_i, table_g, table_o, pool_i, pool_g, pool_o, x,
                     *, block_c: int = 128, block_f: int = 128,
                     interpret: bool = False):
    """Full SwiGLU expert FFN over paged weights:
    ``down( up(x) * silu(gate(x)) )`` with independent page tables for the
    three weight banks (they migrate independently during EP remap)."""
    h = paged_gmm(table_i, pool_i, x, block_c=block_c, block_f=block_f,
                  interpret=interpret)
    g = paged_gmm(table_g, pool_g, x, block_c=block_c, block_f=block_f,
                  interpret=interpret)
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    return paged_gmm(table_o, pool_o, h, block_c=block_c, block_f=block_f,
                     interpret=interpret)
