"""Paged grouped expert matmul (Pallas TPU).

This kernel is the *consumer* of the virtual expert page table
(core/expert_pages.py): expert weights live as non-contiguous pages in a
per-device pool, and the kernel addresses them **by index** via scalar
prefetch — the TPU-native realization of the paper's vpage-remap.  EP
reconfiguration only rewrites the (tiny) page table; no weight buffer is
ever reshaped or copied locally, and XLA never materializes a gathered
weight tensor.

Layout
------
pool   [n_pages, D, F]   physical pages, one expert's (wi|wg|wo) per page
table  [E_local]  int32  page index of each local expert (scalar prefetch)
x      [E_local, C, D]   dispatched tokens, grouped per expert
out    [E_local, C, F]

Grid: (E_local, C/bc, F/bf); the D contraction is unblocked (one MXU pass
per tile).  Block shapes default to MXU-aligned 128x128 tiles; VMEM per
step = bc*D + D*bf + bc*bf elements (~2.5 MB at D=2048, f32) << 16 MB v5e
VMEM.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(table_ref, x_ref, pool_ref, o_ref):
    # x_ref: [1, bc, D]; pool_ref: [1, D, bf] (page selected via index_map)
    x = x_ref[0]
    w = pool_ref[0]
    o_ref[0] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _quant_kernel(table_ref, x_ref, pool_ref, scale_ref, o_ref):
    # int8 page with one f32 scale per (page, bank): the per-page dequant
    # commutes out of the contraction — x @ (w_i8 * s) = (x @ w_i8) * s —
    # so the MXU streams int8 weights at half the HBM bytes and one scalar
    # multiply lands on the output tile.  scale tile selected by the SAME
    # prefetched page table as the weight tile.
    x = x_ref[0].astype(jnp.float32)
    w = pool_ref[0].astype(jnp.float32)
    acc = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0] = (acc * scale_ref[0, 0]).astype(o_ref.dtype)


def _clamp_block_f(dim: int, block: int) -> int:
    """The 'clamp' half of pad-or-clamp for the lane (minormost) dim, which
    the kernel cannot cheaply pad: largest multiple of 128 <= ``block`` that
    divides ``dim`` — Mosaic requires lane blocks to be 128-aligned — else
    the full dim (always legal, just a bigger VMEM tile)."""
    b = min(block, dim) - min(block, dim) % 128
    while b >= 128 and dim % b:
        b -= 128
    if b >= 128 and dim % b == 0:
        return b
    if block < dim:
        # the caller asked for a small lane tile but none divides dim: the
        # grid silently degrades to one full-width tile per step, multiplying
        # the VMEM working set by dim/block — surface the perf cliff instead
        # of hiding it (trace-time: block sizes are static)
        warnings.warn(
            f"paged_gmm: no 128-aligned block <= {block} divides F={dim}; "
            f"falling back to a full-width lane tile (VMEM working set "
            f"~{dim / max(block, 1):.1f}x the requested block). Pad F to a "
            f"multiple of 128 or pick block_f from its divisors.",
            stacklevel=3)
    return dim


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def paged_gmm(table: jax.Array, pool: jax.Array, x: jax.Array,
              *, block_c: int = 128, block_f: int = 128,
              interpret: bool = False) -> jax.Array:
    """out[e] = x[e] @ pool[table[e]] for each local expert e.

    Non-MXU-aligned shapes are handled pad-or-clamp: a token count ``C`` not
    divisible by ``block_c`` is zero-padded up to the next block (zero rows
    produce zero outputs, sliced off after the call — cheap: pads
    activations, never weights; the resulting ``bc`` is either 128-aligned
    or the full dim, both Mosaic-legal).  A hidden dim ``F`` not divisible
    by ``block_f`` instead *clamps* the block — to a 128-aligned divisor or
    the whole dim, never an unaligned lane tile — because padding F would
    mean copying every pool page.  Aliased tables — multiple entries naming
    the same page, the post-CoW sharing shape — are fine by construction:
    each grid step only reads ``pool[table[e]]``.
    """
    E_local, C, D = x.shape
    n_pages, D2, F = pool.shape
    assert D == D2, (D, D2)
    bc = min(block_c, C)
    if C % bc:
        C_pad = -(-C // bc) * bc
        x = jnp.pad(x, ((0, 0), (0, C_pad - C), (0, 0)))
    bf = _clamp_block_f(F, block_f)
    C_run = x.shape[1]

    grid = (E_local, C_run // bc, F // bf)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bc, D), lambda e, i, j, tbl: (e, i, 0)),
                pl.BlockSpec((1, D, bf),
                             lambda e, i, j, tbl: (tbl[e], 0, j)),
            ],
            out_specs=pl.BlockSpec((1, bc, bf),
                                   lambda e, i, j, tbl: (e, i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((E_local, C_run, F), x.dtype),
        interpret=interpret,
    )(table, x, pool)
    return out[:, :C] if C_run != C else out


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def paged_expert_ffn(table_i, table_g, table_o, pool_i, pool_g, pool_o, x,
                     *, block_c: int = 128, block_f: int = 128,
                     interpret: bool = False):
    """Full SwiGLU expert FFN over paged weights:
    ``down( up(x) * silu(gate(x)) )`` with independent page tables for the
    three weight banks (they migrate independently during EP remap)."""
    h = paged_gmm(table_i, pool_i, x, block_c=block_c, block_f=block_f,
                  interpret=interpret)
    g = paged_gmm(table_g, pool_g, x, block_c=block_c, block_f=block_f,
                  interpret=interpret)
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    return paged_gmm(table_o, pool_o, h, block_c=block_c, block_f=block_f,
                     interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def quant_paged_gmm(table: jax.Array, pool: jax.Array, scales: jax.Array,
                    x: jax.Array, *, block_c: int = 128, block_f: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Int8 variant of ``paged_gmm``: ``pool`` is int8 ``[n_pages, D, F]``
    and ``scales`` the per-page f32 dequant scales ``[n_pages]`` (one scalar
    per page, ``kernels.quant.quantize_rows`` over ``(-2, -1)``).  The scale
    BlockSpec dereferences the same prefetched page table as the weight
    pages, so remapped pages always compute with their own scale.  Output in
    ``x.dtype``; oracle: ``ref.quant_paged_gmm_ref``."""
    E_local, C, D = x.shape
    n_pages, D2, F = pool.shape
    assert D == D2, (D, D2)
    bc = min(block_c, C)
    if C % bc:
        C_pad = -(-C // bc) * bc
        x = jnp.pad(x, ((0, 0), (0, C_pad - C), (0, 0)))
    bf = _clamp_block_f(F, block_f)
    C_run = x.shape[1]
    scales2 = scales.astype(jnp.float32).reshape(n_pages, 1)

    grid = (E_local, C_run // bc, F // bf)
    out = pl.pallas_call(
        _quant_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bc, D), lambda e, i, j, tbl: (e, i, 0)),
                pl.BlockSpec((1, D, bf),
                             lambda e, i, j, tbl: (tbl[e], 0, j)),
                pl.BlockSpec((1, 1), lambda e, i, j, tbl: (tbl[e], 0),
                             memory_space=pltpu.SMEM),
            ],
            out_specs=pl.BlockSpec((1, bc, bf),
                                   lambda e, i, j, tbl: (e, i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((E_local, C_run, F), x.dtype),
        interpret=interpret,
    )(table, x, pool, scales2)
    return out[:, :C] if C_run != C else out


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def quant_paged_expert_ffn(table_i, table_g, table_o, pool_i, pool_g, pool_o,
                           scale_i, scale_g, scale_o, x,
                           *, block_c: int = 128, block_f: int = 128,
                           interpret: bool = False):
    """SwiGLU expert FFN over int8 paged weights with per-page f32 scales
    (one per bank — they migrate with their bank during EP remap)."""
    h = quant_paged_gmm(table_i, pool_i, scale_i, x, block_c=block_c,
                        block_f=block_f, interpret=interpret)
    g = quant_paged_gmm(table_g, pool_g, scale_g, x, block_c=block_c,
                        block_f=block_f, interpret=interpret)
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    return quant_paged_gmm(table_o, pool_o, scale_o, h, block_c=block_c,
                           block_f=block_f, interpret=interpret)
