"""Absorbed MLA decode attention over the latent cache (Pallas TPU).

The hot loop of DeepSeek-V2/V3 decode (the paper's primary models): one
query token attends over the rank-``r`` latent cache

    s_t   = q_eff . c_t + q_rope . kr_t          (scores)
    ctx   = softmax(s) . C                        (latent readout)

with q already *absorbed* through W_uk (models/mla.py) so per-step FLOPs
scale with r, not H*(dn+dv).  Online softmax over sequence blocks with
per-sequence valid-length masking via scalar prefetch — the same structure
as kernels/paged_attention.py but contracting the shared latent instead of
per-head K/V.  Output is the latent context [B, H, r]; the caller applies
W_uv and o_proj (dense matmuls XLA already handles well).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(lengths_ref, qe_ref, qr_ref, c_ref, kr_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, block_k, n_k):
    b = pl.program_id(0)
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]

    @pl.when(ki * block_k < length)
    def _step():
        qe = qe_ref[0].astype(jnp.float32)          # [H, r]
        qr = qr_ref[0].astype(jnp.float32)          # [H, dr]
        c = c_ref[0].astype(jnp.float32)            # [bk, r]
        kr = kr_ref[0].astype(jnp.float32)          # [bk, dr]
        s = (jax.lax.dot_general(qe, c, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
             + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
             ) * scale                               # [H, bk]
        pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, c, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)      # [H, r]
        m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def mla_decode_attention(q_eff: jax.Array, q_rope: jax.Array,
                         c_cache: jax.Array, kr_cache: jax.Array,
                         lengths: jax.Array, *, block_k: int = 128,
                         interpret: bool = False) -> jax.Array:
    """q_eff [B,H,r]; q_rope [B,H,dr]; c_cache [B,S,r]; kr_cache [B,S,dr];
    lengths [B] -> latent context [B,H,r]."""
    B, H, r = q_eff.shape
    dr = q_rope.shape[-1]
    S = c_cache.shape[1]
    bk = min(block_k, S)
    assert S % bk == 0
    n_k = S // bk
    dn = 0  # scale uses the full qk dim of the absorbed form
    scale = 1.0 / math.sqrt(128 + dr) if r >= 128 else 1.0 / math.sqrt(r + dr)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=bk, n_k=n_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, n_k),
            in_specs=[
                pl.BlockSpec((1, H, r), lambda b, ki, L: (b, 0, 0)),
                pl.BlockSpec((1, H, dr), lambda b, ki, L: (b, 0, 0)),
                pl.BlockSpec((1, bk, r), lambda b, ki, L: (b, ki, 0)),
                pl.BlockSpec((1, bk, dr), lambda b, ki, L: (b, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, r), lambda b, ki, L: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H,), jnp.float32),
                pltpu.VMEM((H,), jnp.float32),
                pltpu.VMEM((H, r), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, r), q_eff.dtype),
        interpret=interpret,
    )(lengths, q_eff, q_rope, c_cache, kr_cache)
