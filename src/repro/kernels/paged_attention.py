"""Decode attention over the KV cache (Pallas TPU) — two layouts.

``paged_decode_attention``: slot-contiguous cache ``[B, S_max, KVH, hd]``
(one dense row per sequence).  One query token per sequence, masked by
per-sequence valid length.  Lengths arrive via scalar prefetch so the kernel
skips kv tiles entirely beyond a sequence's length — on real hardware this
is the difference between O(S_max) and O(len) HBM traffic per step.

``block_paged_decode_attention``: the PagedAttention layout — one shared
block *pool* ``[NB, bs, KVH, hd]`` plus per-sequence block tables
``[B, MB]`` (``serving/kv_blocks.py``).  The block table rides the scalar
prefetch too: each kv BlockSpec index_map dereferences ``table[b, ki]`` to
pick the physical block, so the kernel reads exactly the blocks a sequence
owns — non-contiguous pool rows appear contiguous to the softmax, the
kernel-level zero-copy-remap guarantee (permuting pool rows + tables is a
no-op, asserted in tests).

Grid (B, KVH, n_k); q block [1, 1, G, hd] (the G=H/KVH grouped query heads
of one kv head), kv blocks [1, bk, 1, hd] (dense) / [1, bs, 1, hd] (paged);
online softmax in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, block_k, n_k):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]

    @pl.when(ki * block_k < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)                  # [G, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def paged_decode_attention(q: jax.Array, k_cache: jax.Array,
                           v_cache: jax.Array, lengths: jax.Array, *,
                           block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q [B,H,hd]; k/v_cache [B,S_max,KVH,hd]; lengths [B] -> [B,H,hd]."""
    B, H, hd = q.shape
    S_max, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    bk = min(block_k, S_max)
    assert S_max % bk == 0
    n_k = S_max // bk
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KVH, G, hd)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=bk, n_k=n_k),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, KVH, n_k),
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, h, ki, L: (b, h, 0, 0)),
                pl.BlockSpec((1, bk, 1, hd), lambda b, h, ki, L: (b, ki, h, 0)),
                pl.BlockSpec((1, bk, 1, hd), lambda b, h, ki, L: (b, ki, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, h, ki, L: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(B, H, hd)


def _block_kernel(lengths_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale, block_k, n_k):
    # block table is consumed by the BlockSpec index maps; the compute body
    # is identical to the slot-contiguous kernel
    del bt_ref
    _kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            scale=scale, block_k=block_k, n_k=n_k)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                                 v_pool: jax.Array, block_tables: jax.Array,
                                 lengths: jax.Array, *,
                                 interpret: bool = False) -> jax.Array:
    """q [B,H,hd]; k/v_pool [NB,bs,KVH,hd]; block_tables [B,MB] int32;
    lengths [B] -> [B,H,hd].  kv tile ``ki`` of sequence ``b`` is pool row
    ``block_tables[b, ki]`` — dereferenced in the BlockSpec index_map via
    scalar prefetch, so only owned blocks are streamed from HBM (and none at
    all beyond ``lengths[b]``)."""
    B, H, hd = q.shape
    bs, KVH = k_pool.shape[1], k_pool.shape[2]
    MB = block_tables.shape[1]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KVH, G, hd)

    out = pl.pallas_call(
        functools.partial(_block_kernel, scale=scale, block_k=bs, n_k=MB),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KVH, MB),
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, ki, L, BT: (b, h, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, ki, L, BT: (BT[b, ki], 0, h, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, ki, L, BT: (BT[b, ki], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, h, ki, L, BT: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        interpret=interpret,
    )(lengths, block_tables.astype(jnp.int32), qg, k_pool, v_pool)
    return out.reshape(B, H, hd)


def _quant_block_kernel(lengths_ref, bt_ref, q_ref, k_ref, ks_ref, v_ref,
                        vs_ref, o_ref, m_ref, l_ref, acc_ref, *, scale,
                        block_k, n_k):
    # int8 pools + [NB, bs] f32 scale pools: the scale tiles ride the SAME
    # block-table dereference as the entry tiles (scalar-prefetch path), so
    # a remapped/migrated block always arrives with its own scales.
    del bt_ref
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]

    @pl.when(ki * block_k < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)                  # [G, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bs, hd] int8
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        sk = ks_ref[0, :]                                    # [bs] f32
        sv = vs_ref[0, :]
        # per-token k-dequant commutes out of the q.k^T contraction:
        # column-scale the scores instead of materializing a dequant tile
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) \
            * sk[None, :] * scale
        pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        # v-dequant folds into the probability rows the same way
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p * sv[None, :], v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quant_block_paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                                       k_scale: jax.Array,
                                       v_pool: jax.Array,
                                       v_scale: jax.Array,
                                       block_tables: jax.Array,
                                       lengths: jax.Array, *,
                                       interpret: bool = False) -> jax.Array:
    """Int8 variant of ``block_paged_decode_attention``: k/v_pool are int8
    ``[NB, bs, KVH, hd]`` and k/v_scale the per-token f32 scale pools
    ``[NB, bs]`` (``kernels.quant.quantize_rows`` over ``(KVH, hd)``).
    The scale BlockSpecs dereference the same prefetched block table as the
    entry pools, so dequant is fused into the softmax at ~half the HBM
    traffic of the f32 path.  Oracle:
    ``ref.quant_block_paged_decode_attention_ref``."""
    B, H, hd = q.shape
    bs, KVH = k_pool.shape[1], k_pool.shape[2]
    MB = block_tables.shape[1]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KVH, G, hd)

    out = pl.pallas_call(
        functools.partial(_quant_block_kernel, scale=scale, block_k=bs,
                          n_k=MB),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KVH, MB),
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, ki, L, BT: (b, h, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, ki, L, BT: (BT[b, ki], 0, h, 0)),
                pl.BlockSpec((1, bs),
                             lambda b, h, ki, L, BT: (BT[b, ki], 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, ki, L, BT: (BT[b, ki], 0, h, 0)),
                pl.BlockSpec((1, bs),
                             lambda b, h, ki, L, BT: (BT[b, ki], 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, hd),
                                   lambda b, h, ki, L, BT: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        interpret=interpret,
    )(lengths, block_tables.astype(jnp.int32), qg,
      k_pool, k_scale.astype(jnp.float32),
      v_pool, v_scale.astype(jnp.float32))
    return out.reshape(B, H, hd)


def _mixed_kernel(ctx_ref, qlen_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale, block_k, n_k, G):
    # block table is consumed by the BlockSpec index maps
    del bt_ref
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = ctx_ref[b]
    q_len = qlen_ref[b]

    @pl.when(ki * block_k < ctx)
    def _step():
        q3 = q_ref[0, 0].astype(jnp.float32)                 # [Sq, G, hd]
        sq = q3.shape[0]
        q2 = q3.reshape(sq * G, q3.shape[2])                 # [Sq*G, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bs, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q2, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        # query row qi sits at absolute position ctx - q_len + qi; padding
        # rows (qi >= q_len) degrade to full-context decode masking so every
        # row keeps a sane softmax (block 0 is always live: ctx >= 1)
        q_abs = ctx - q_len + qi
        s = jnp.where((pos < ctx) & (pos <= q_abs), s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _done():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = o.reshape(o_ref.shape[2], G,
                                o.shape[-1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mixed_block_paged_attention(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, block_tables: jax.Array,
                                ctx_lens: jax.Array, q_lens: jax.Array, *,
                                interpret: bool = False) -> jax.Array:
    """Mixed chunked-prefill / decode attention (continuous batching).

    q [B,Sq,H,hd]; k/v_pool [NB,bs,KVH,hd]; block_tables [B,MB];
    ctx_lens [B] (total context incl. the chunk, already written to the
    pool); q_lens [B] (valid chunk rows) -> [B,Sq,H,hd].

    Query row ``i`` of sequence ``b`` attends causally from absolute
    position ``ctx_lens[b] - q_lens[b] + i``; ``q_lens == 1`` is exactly
    paged decode, so one kernel serves interleaved prefill+decode buckets.
    Sentinel (``NB``) block-table rows are clamped in-bounds before the
    index_map dereference and position-masked inert; blocks at or beyond
    ``ctx_lens[b]`` are skipped entirely.  Oracle:
    ``ref.mixed_block_paged_attention_ref``.
    """
    B, Sq, H, hd = q.shape
    NB, bs, KVH = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    MB = block_tables.shape[1]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    bt = jnp.minimum(block_tables.astype(jnp.int32), NB - 1)
    qg = q.reshape(B, Sq, KVH, G, hd).transpose(0, 2, 1, 3, 4)

    out = pl.pallas_call(
        functools.partial(_mixed_kernel, scale=scale, block_k=bs, n_k=MB,
                          G=G),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, KVH, MB),
            in_specs=[
                pl.BlockSpec((1, 1, Sq, G, hd),
                             lambda b, h, ki, C, Q, BT: (b, h, 0, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, ki, C, Q, BT: (BT[b, ki], 0, h, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, ki, C, Q, BT: (BT[b, ki], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, Sq, G, hd),
                                   lambda b, h, ki, C, Q, BT:
                                   (b, h, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((Sq * G,), jnp.float32),
                pltpu.VMEM((Sq * G,), jnp.float32),
                pltpu.VMEM((Sq * G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, Sq, G, hd), q.dtype),
        interpret=interpret,
    )(ctx_lens.astype(jnp.int32), q_lens.astype(jnp.int32), bt,
      qg, k_pool, v_pool)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, hd)


def _quant_mixed_kernel(ctx_ref, qlen_ref, bt_ref, q_ref, k_ref, ks_ref,
                        v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                        scale, block_k, n_k, G):
    del bt_ref
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = ctx_ref[b]
    q_len = qlen_ref[b]

    @pl.when(ki * block_k < ctx)
    def _step():
        q3 = q_ref[0, 0].astype(jnp.float32)                 # [Sq, G, hd]
        sq = q3.shape[0]
        q2 = q3.reshape(sq * G, q3.shape[2])                 # [Sq*G, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bs, hd] int8
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        sk = ks_ref[0, :]                                    # [bs] f32
        sv = vs_ref[0, :]
        # same commuting dequant as _quant_block_kernel: column-scale scores
        # by sk, row-scale probabilities by sv
        s = jax.lax.dot_general(q2, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) \
            * sk[None, :] * scale
        pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        q_abs = ctx - q_len + qi
        s = jnp.where((pos < ctx) & (pos <= q_abs), s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p * sv[None, :], v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _done():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = o.reshape(o_ref.shape[2], G,
                                o.shape[-1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quant_mixed_block_paged_attention(q: jax.Array, k_pool: jax.Array,
                                      k_scale: jax.Array, v_pool: jax.Array,
                                      v_scale: jax.Array,
                                      block_tables: jax.Array,
                                      ctx_lens: jax.Array,
                                      q_lens: jax.Array, *,
                                      interpret: bool = False) -> jax.Array:
    """Int8 variant of ``mixed_block_paged_attention``: same masks and mixed
    prefill/decode semantics, int8 k/v pools with [NB, bs] f32 scale pools
    riding the prefetched block table.  Oracle:
    ``ref.quant_mixed_block_paged_attention_ref``."""
    B, Sq, H, hd = q.shape
    NB, bs, KVH = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    MB = block_tables.shape[1]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    bt = jnp.minimum(block_tables.astype(jnp.int32), NB - 1)
    qg = q.reshape(B, Sq, KVH, G, hd).transpose(0, 2, 1, 3, 4)

    out = pl.pallas_call(
        functools.partial(_quant_mixed_kernel, scale=scale, block_k=bs,
                          n_k=MB, G=G),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, KVH, MB),
            in_specs=[
                pl.BlockSpec((1, 1, Sq, G, hd),
                             lambda b, h, ki, C, Q, BT: (b, h, 0, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, ki, C, Q, BT: (BT[b, ki], 0, h, 0)),
                pl.BlockSpec((1, bs),
                             lambda b, h, ki, C, Q, BT: (BT[b, ki], 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, ki, C, Q, BT: (BT[b, ki], 0, h, 0)),
                pl.BlockSpec((1, bs),
                             lambda b, h, ki, C, Q, BT: (BT[b, ki], 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, Sq, G, hd),
                                   lambda b, h, ki, C, Q, BT:
                                   (b, h, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((Sq * G,), jnp.float32),
                pltpu.VMEM((Sq * G,), jnp.float32),
                pltpu.VMEM((Sq * G, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, Sq, G, hd), q.dtype),
        interpret=interpret,
    )(ctx_lens.astype(jnp.int32), q_lens.astype(jnp.int32), bt,
      qg, k_pool, k_scale.astype(jnp.float32),
      v_pool, v_scale.astype(jnp.float32))
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, hd)
