"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (the kernel
body executes in Python, validating logic + BlockSpecs); on a real TPU set
``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to compile to Mosaic.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.moe_gmm import paged_expert_ffn as _ffn
from repro.kernels.moe_gmm import paged_gmm as _gmm
from repro.kernels.paged_attention import paged_decode_attention as _paged
from repro.kernels.ssd_scan import ssd_scan as _ssd

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0" or \
    jax.default_backend() == "cpu"


def _tuned(kernel, kw, keys):
    """Fill block-size kwargs the caller left unset from the autotune
    best-config table (tools/autotune_kernels.py; no-op without a table)."""
    if all(k in kw for k in keys):
        return kw
    from repro.analysis.autotune import best_config
    best = best_config(kernel)
    for k in keys:
        if k in best:
            kw.setdefault(k, best[k])
    return kw


def paged_gmm(table, pool, x, **kw):
    kw.setdefault("interpret", _INTERPRET)
    _tuned("paged_gmm", kw, ("block_c", "block_f"))
    return _gmm(table, pool, x, **kw)


def quant_paged_gmm(table, pool, scales, x, **kw):
    """Int8 paged GMM (per-page f32 scales).  Same impl switch as
    ``paged_expert_ffn``: kernel on accelerators, dequant-then-gather
    reference on CPU (``REPRO_POOLED_IMPL`` / ``impl=`` override)."""
    impl = kw.pop("impl", None) or os.environ.get("REPRO_POOLED_IMPL", "auto")
    if impl == "ref" or (impl == "auto" and jax.default_backend() == "cpu"):
        from repro.kernels.ref import quant_paged_gmm_ref
        return quant_paged_gmm_ref(table, pool, scales, x)
    from repro.kernels.moe_gmm import quant_paged_gmm as _qgmm
    kw.setdefault("interpret", _INTERPRET)
    _tuned("paged_gmm", kw, ("block_c", "block_f"))
    return _qgmm(table, pool, scales, x, **kw)


def paged_expert_ffn(table_i, table_g, table_o, pool_i, pool_g, pool_o, x,
                     **kw):
    """Paged SwiGLU expert FFN (the pooled-expert serving hot path).

    ``impl='kernel'`` forces the Pallas paged-GMM kernel, ``'ref'`` the jnp
    gather oracle; the default ``'auto'`` (overridable via
    ``REPRO_POOLED_IMPL``) runs the kernel on accelerators and the reference
    on CPU — interpret-mode Pallas inside the per-layer decode scan is far
    slower than the gather, and the two are parity-tested in
    test_kernels.py (same policy as ``block_paged_decode_attention``)."""
    impl = kw.pop("impl", None) or os.environ.get("REPRO_POOLED_IMPL", "auto")
    if impl == "ref" or (impl == "auto" and jax.default_backend() == "cpu"):
        from repro.kernels.ref import paged_expert_ffn_ref
        return paged_expert_ffn_ref(table_i, table_g, table_o,
                                    pool_i, pool_g, pool_o, x)
    kw.setdefault("interpret", _INTERPRET)
    _tuned("paged_expert_ffn", kw, ("block_c", "block_f"))
    return _ffn(table_i, table_g, table_o, pool_i, pool_g, pool_o, x, **kw)


def quant_paged_expert_ffn(table_i, table_g, table_o, pool_i, pool_g, pool_o,
                           scale_i, scale_g, scale_o, x, **kw):
    """Int8 paged SwiGLU FFN (per-page, per-bank f32 scales).  Same impl
    switch / autotune consultation as ``paged_expert_ffn``."""
    impl = kw.pop("impl", None) or os.environ.get("REPRO_POOLED_IMPL", "auto")
    if impl == "ref" or (impl == "auto" and jax.default_backend() == "cpu"):
        from repro.kernels.ref import quant_paged_expert_ffn_ref
        return quant_paged_expert_ffn_ref(table_i, table_g, table_o,
                                          pool_i, pool_g, pool_o,
                                          scale_i, scale_g, scale_o, x)
    from repro.kernels.moe_gmm import quant_paged_expert_ffn as _qffn
    kw.setdefault("interpret", _INTERPRET)
    _tuned("paged_expert_ffn", kw, ("block_c", "block_f"))
    return _qffn(table_i, table_g, table_o, pool_i, pool_g, pool_o,
                 scale_i, scale_g, scale_o, x, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _INTERPRET)
    return _flash(q, k, v, **kw)


def paged_decode_attention(q, k_cache, v_cache, lengths, **kw):
    kw.setdefault("interpret", _INTERPRET)
    _tuned("paged_decode_attention", kw, ("block_k",))
    return _paged(q, k_cache, v_cache, lengths, **kw)


def block_paged_decode_attention(q, k_pool, v_pool, block_tables, lengths,
                                 **kw):
    """Block-table paged decode (serving hot path; see kv_blocks.py).

    ``impl='kernel'`` forces the Pallas kernel, ``'ref'`` the jnp gather
    oracle; the default ``'auto'`` (overridable via ``REPRO_PAGED_IMPL``)
    runs the kernel on accelerators and falls back to the reference on CPU —
    interpret-mode Pallas inside the per-layer decode scan is far slower
    than the gather, and the two are parity-tested in test_kernels.py.
    """
    impl = kw.pop("impl", None) or os.environ.get("REPRO_PAGED_IMPL", "auto")
    if impl == "ref" or (impl == "auto" and jax.default_backend() == "cpu"):
        from repro.kernels.ref import block_paged_decode_attention_ref
        return block_paged_decode_attention_ref(q, k_pool, v_pool,
                                                block_tables, lengths)
    from repro.kernels.paged_attention import \
        block_paged_decode_attention as _block_paged
    kw.setdefault("interpret", _INTERPRET)
    return _block_paged(q, k_pool, v_pool, block_tables, lengths, **kw)


def quant_block_paged_decode_attention(q, k_pool, k_scale, v_pool, v_scale,
                                       block_tables, lengths, **kw):
    """Int8 block-table paged decode (per-token f32 scale pools riding the
    block table).  Same impl switch as ``block_paged_decode_attention``."""
    impl = kw.pop("impl", None) or os.environ.get("REPRO_PAGED_IMPL", "auto")
    if impl == "ref" or (impl == "auto" and jax.default_backend() == "cpu"):
        from repro.kernels.ref import quant_block_paged_decode_attention_ref
        return quant_block_paged_decode_attention_ref(
            q, k_pool, k_scale, v_pool, v_scale, block_tables, lengths)
    from repro.kernels.paged_attention import \
        quant_block_paged_decode_attention as _qblock
    kw.setdefault("interpret", _INTERPRET)
    return _qblock(q, k_pool, k_scale, v_pool, v_scale, block_tables,
                   lengths, **kw)


def mixed_block_paged_attention(q, k_pool, v_pool, block_tables, ctx_lens,
                                q_lens, **kw):
    """Mixed chunked-prefill / decode attention over the block pool (the
    continuous-batching hot path; see serving/scheduler.py).

    Same impl switch as ``block_paged_decode_attention``: ``impl='kernel'``
    forces the Pallas kernel, ``'ref'`` the jnp gather oracle, and the
    default ``'auto'`` (overridable via ``REPRO_PAGED_IMPL``) picks the
    kernel on accelerators and the reference on CPU; the two are
    parity-tested in test_kernels.py."""
    impl = kw.pop("impl", None) or os.environ.get("REPRO_PAGED_IMPL", "auto")
    if impl == "ref" or (impl == "auto" and jax.default_backend() == "cpu"):
        from repro.kernels.ref import mixed_block_paged_attention_ref
        return mixed_block_paged_attention_ref(q, k_pool, v_pool,
                                               block_tables, ctx_lens, q_lens)
    from repro.kernels.paged_attention import \
        mixed_block_paged_attention as _mixed
    kw.setdefault("interpret", _INTERPRET)
    return _mixed(q, k_pool, v_pool, block_tables, ctx_lens, q_lens, **kw)


def quant_mixed_block_paged_attention(q, k_pool, k_scale, v_pool, v_scale,
                                      block_tables, ctx_lens, q_lens, **kw):
    """Int8 mixed chunked-prefill / decode attention.  Same impl switch as
    ``mixed_block_paged_attention``."""
    impl = kw.pop("impl", None) or os.environ.get("REPRO_PAGED_IMPL", "auto")
    if impl == "ref" or (impl == "auto" and jax.default_backend() == "cpu"):
        from repro.kernels.ref import quant_mixed_block_paged_attention_ref
        return quant_mixed_block_paged_attention_ref(
            q, k_pool, k_scale, v_pool, v_scale, block_tables, ctx_lens,
            q_lens)
    from repro.kernels.paged_attention import \
        quant_mixed_block_paged_attention as _qmixed
    kw.setdefault("interpret", _INTERPRET)
    return _qmixed(q, k_pool, k_scale, v_pool, v_scale, block_tables,
                   ctx_lens, q_lens, **kw)


def ssd_scan(x, dt, A, Bm, Cm, **kw):
    kw.setdefault("interpret", _INTERPRET)
    return _ssd(x, dt, A, Bm, Cm, **kw)


def mla_decode_attention(q_eff, q_rope, c_cache, kr_cache, lengths, **kw):
    from repro.kernels.mla_decode import mla_decode_attention as _mla
    kw.setdefault("interpret", _INTERPRET)
    return _mla(q_eff, q_rope, c_cache, kr_cache, lengths, **kw)


def kv_cache_write(cache, new_kv, pos, **kw):
    from repro.kernels.kv_write import kv_cache_write as _kvw
    kw.setdefault("interpret", _INTERPRET)
    return _kvw(cache, new_kv, pos, **kw)
