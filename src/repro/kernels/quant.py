"""Int8 quantization helpers shared by the quantized KV / expert-page paths.

Symmetric per-row int8 (DESIGN.md §11): ``scale = max|x| / 127`` over the
row, ``q = clip(round(x / scale))``.  The scale is a *sidecar* array that
travels with its rows through every pool operation:

* KV blocks — one f32 scale per (block, slot) token row of each of k and v,
  stored as ``[NB, bs]`` pools addressed by the SAME block table as the int8
  entry pools (the scalar-prefetch path), so remap / migration / CoW move
  scales and entries together by construction;
* expert pages — one f32 scalar per (page, bank), stored as ``*_scale``
  banks beside the int8 pools in ``params["moe_pool"]``, so the HMM's
  per-bank staging moves them with their pages.

Quantization error is bounded by scale/2 per element (~0.4% of the row
max); the dequant-parity suite (tests/test_quantization.py) pins the
end-to-end token tolerance.
"""
from __future__ import annotations

import jax.numpy as jnp

INT8_MAX = 127.0
#: floor on the row max so all-zero rows quantize to scale EPS/127, not 0/0
EPS = 1e-8


def quantize_rows(x: jnp.ndarray, axes) -> tuple:
    """Quantize ``x`` to int8 with one shared scale per row, where a "row"
    is everything spanned by ``axes`` (e.g. ``(-2, -1)`` for a KV token row
    ``[KVH, hd]`` or an expert page ``[D, F]``).  Returns ``(q, scale)``
    with ``q`` int8 of ``x.shape`` and ``scale`` f32 of the remaining dims;
    ``dequantize_rows(q, scale, axes)`` inverts it up to rounding."""
    axes = tuple(axes)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axes, keepdims=True)
    scale = jnp.maximum(amax, EPS) / INT8_MAX
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, jnp.squeeze(scale, axis=axes)


def dequantize_rows(q: jnp.ndarray, scale: jnp.ndarray, axes) -> jnp.ndarray:
    """Inverse of :func:`quantize_rows` (f32 output)."""
    s = scale.astype(jnp.float32)
    for ax in sorted(tuple(axes)):
        s = jnp.expand_dims(s, ax if ax >= 0 else q.ndim + ax)
    return q.astype(jnp.float32) * s
