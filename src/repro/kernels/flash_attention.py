"""Blocked (flash-style) causal attention for prefill (Pallas TPU).

Grid (B*H, n_q, n_k) with the kv dimension innermost-sequential; running
max / sum / accumulator live in VMEM scratch and the output tile is written
on the final kv step.  Causal masking skips fully-masked tiles via
``pl.when``.  GQA is handled by mapping q-head -> kv-head in the index map.

Block shapes default to (128, head_dim) — MXU-aligned for hd in
{64, 80, 128}; VMEM = bq*hd + 2*bk*hd + bq*bk + scratch << 16 MB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, block_q, block_k, n_k, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        run = qi * block_q + block_q - 1 >= ki * block_k  # any unmasked?

    @pl.when(jnp.asarray(run))
    def _step():
        q = q_ref[0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q [B,S,H,hd]; k/v [B,S,KVH,hd] -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0
    n_q, n_k = S // bq, S // bk
    scale = 1.0 / math.sqrt(hd)

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KVH, S, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KVH, S, hd)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=bq, block_k=bk,
                          n_k=n_k, causal=causal),
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh // G, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)