"""Mamba2 SSD chunk scan (Pallas TPU).

TPU adaptation of the Triton SSD kernel (DESIGN.md §2): the chunk dimension
is a *sequential* grid axis with the carried SSM state living in VMEM
scratch across grid steps; the intra-chunk quadratic part is a pair of MXU
matmuls.  One (batch, head) per grid row.

Inputs per (b, h): x [S, P], dt [S], B/C [S, N], A scalar (via [H] array).
Output y [S, P] plus the final state [N, P] (for decode handoff).

Grid (B*H, n_chunks); chunk Q is the block; VMEM = O(Q*(N+P) + N*P).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_ref, *, chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q]
    A = a_ref[0].astype(jnp.float32)          # scalar (this head)
    Bm = b_ref[0].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)         # [Q, N]

    a = dt * A                                # [Q] log-decays
    acs = jnp.cumsum(a)                       # inclusive
    # off-diagonal: carried state decayed to each position
    y_off = jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(acs)[:, None]
    # intra-chunk quadratic
    seg = acs[:, None] - acs[None, :]
    iq = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], x.shape[0]), 0)
    kq = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], x.shape[0]), 1)
    L = jnp.exp(jnp.where(iq >= kq, seg, -jnp.inf))
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    M = scores * L * dt[None, :]
    y_diag = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_ref[0] = (y_off + y_diag).astype(y_ref.dtype)
    # state update
    decay_out = jnp.exp(acs[-1] - acs) * dt                  # [Q]
    state_new = jax.lax.dot_general(
        Bm * decay_out[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # [N, P]
    state_ref[...] = state_ref[...] * jnp.exp(acs[-1]) + state_new

    @pl.when(ci == n_chunks - 1)
    def _done():
        state_out_ref[0] = state_ref[...].astype(state_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 64, interpret: bool = False):
    """x [B,S,H,P]; dt [B,S,H]; A [H]; Bm/Cm [B,S,N]
    -> (y [B,S,H,P] f32, final_state [B,H,N,P] f32)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    xh = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dth = dt.transpose(0, 2, 1).reshape(B * H, S)
    ah = jnp.broadcast_to(A[None], (B, H)).reshape(B * H)
    bh = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    ch = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(B * H, S, N)

    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=Q, n_chunks=nc),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh_, ci: (bh_, ci, 0)),
            pl.BlockSpec((1, Q), lambda bh_, ci: (bh_, ci)),
            pl.BlockSpec((1,), lambda bh_, ci: (bh_,)),
            pl.BlockSpec((1, Q, N), lambda bh_, ci: (bh_, ci, 0)),
            pl.BlockSpec((1, Q, N), lambda bh_, ci: (bh_, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda bh_, ci: (bh_, ci, 0)),
            pl.BlockSpec((1, N, P), lambda bh_, ci: (bh_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), jnp.float32),
            jax.ShapeDtypeStruct((B * H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xh, dth, ah, bh, ch)
    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    return y, state.reshape(B, H, N, P)
