"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_gmm_ref(table, pool, x):
    """out[e] = x[e] @ pool[table[e]]."""
    w = pool[table]                                   # [E_local, D, F]
    return jnp.einsum("ecd,edf->ecf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def paged_expert_ffn_ref(table_i, table_g, table_o, pool_i, pool_g, pool_o, x):
    h = paged_gmm_ref(table_i, pool_i, x)
    g = paged_gmm_ref(table_g, pool_g, x)
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    return paged_gmm_ref(table_o, pool_o, h)


def quant_paged_gmm_ref(table, pool, scales, x):
    """Dequant-then-delegate oracle for the int8 paged GMM: pool int8
    [n_pages, D, F], scales f32 [n_pages] (one per page)."""
    from repro.kernels.quant import dequantize_rows
    w = dequantize_rows(pool, scales, (-2, -1))
    return paged_gmm_ref(table, w, x.astype(jnp.float32)).astype(x.dtype)


def quant_paged_expert_ffn_ref(table_i, table_g, table_o, pool_i, pool_g,
                               pool_o, scale_i, scale_g, scale_o, x):
    h = quant_paged_gmm_ref(table_i, pool_i, scale_i, x)
    g = quant_paged_gmm_ref(table_g, pool_g, scale_g, x)
    h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    return quant_paged_gmm_ref(table_o, pool_o, scale_o, h)


def flash_attention_ref(q, k, v, causal=True):
    """q [B,S,H,hd]; k/v [B,S,KVH,hd]."""
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, S, KVH, G, hd)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def paged_decode_attention_ref(q, k_cache, v_cache, lengths):
    """q [B,H,hd]; caches [B,S,KVH,hd]; lengths [B]."""
    B, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(hd)
    t = jnp.arange(S)[None, None, None]
    s = jnp.where(t < lengths[:, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def block_paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths):
    """Block-table paged decode: q [B,H,hd]; k/v_pool [NB,bs,KVH,hd];
    block_tables [B,MB] (pool indices; entries past a sequence's length are
    don't-care); lengths [B] -> [B,H,hd].

    Gathers each sequence's K/V through its block table into a contiguous
    [B, MB*bs, KVH, hd] view, then runs the dense masked decode attention —
    the oracle the Pallas kernel (and the engine's CPU fallback) must match.
    """
    B, H, hd = q.shape
    bs, KVH = k_pool.shape[1], k_pool.shape[2]
    MB = block_tables.shape[1]
    k = k_pool[block_tables].reshape(B, MB * bs, KVH, hd)
    v = v_pool[block_tables].reshape(B, MB * bs, KVH, hd)
    return paged_decode_attention_ref(q, k, v, lengths)


def quant_block_paged_decode_attention_ref(q, k_pool, k_scale, v_pool,
                                           v_scale, block_tables, lengths):
    """Dequant-then-delegate oracle for the int8 block-table paged decode:
    k/v_pool int8 [NB,bs,KVH,hd], k/v_scale f32 [NB,bs] (one per token row,
    ``quantize_rows`` over (KVH, hd))."""
    from repro.kernels.quant import dequantize_rows
    k = dequantize_rows(k_pool, k_scale, (-2, -1))
    v = dequantize_rows(v_pool, v_scale, (-2, -1))
    return block_paged_decode_attention_ref(q, k, v, block_tables, lengths)


def mixed_block_paged_attention_ref(q, k_pool, v_pool, block_tables,
                                    ctx_lens, q_lens):
    """Mixed chunked-prefill / decode attention over the block pool.

    q [B,Sq,H,hd]: row ``i`` of sequence ``b`` is the query at absolute
    position ``ctx_lens[b] - q_lens[b] + i`` — a prefill chunk is the last
    ``q_lens[b]`` tokens of a context of ``ctx_lens[b]`` tokens whose K/V
    (including the chunk's own) already sit in the pool.  ``q_lens[b] == 1``
    degenerates to plain paged decode.  Rows ``i >= q_lens[b]`` are padding;
    they attend over the full context (mask ``t < ctx``) so the output is
    deterministic, but callers discard them.  Sentinel block-table entries
    (``NB``) are clamped in-bounds; position masking keeps them inert.
    """
    B, Sq, H, hd = q.shape
    NB, bs, KVH = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    MB = block_tables.shape[1]
    G = H // KVH
    bt = jnp.minimum(block_tables, NB - 1)
    k = k_pool[bt].reshape(B, MB * bs, KVH, hd)
    v = v_pool[bt].reshape(B, MB * bs, KVH, hd)
    qg = q.reshape(B, Sq, KVH, G, hd)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    q_abs = (ctx_lens - q_lens)[:, None] + jnp.arange(Sq)[None]     # [B,Sq]
    t = jnp.arange(MB * bs)
    mask = (t[None, None, None, None, :] < ctx_lens[:, None, None, None, None]) \
        & (t[None, None, None, None, :] <= q_abs[:, None, None, :, None])
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def quant_mixed_block_paged_attention_ref(q, k_pool, k_scale, v_pool,
                                          v_scale, block_tables, ctx_lens,
                                          q_lens):
    """Dequant-then-delegate oracle for the int8 mixed prefill/decode
    attention (same scale layout as the quant block-decode oracle)."""
    from repro.kernels.quant import dequantize_rows
    k = dequantize_rows(k_pool, k_scale, (-2, -1))
    v = dequantize_rows(v_pool, v_scale, (-2, -1))
    return mixed_block_paged_attention_ref(q, k, v, block_tables, ctx_lens,
                                           q_lens)


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """Sequential (exact) SSD recurrence.  x [B,S,H,P], dt [B,S,H], A [H],
    Bm/Cm [B,S,N] -> (y [B,S,H,P] f32, state [B,H,N,P] f32)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp            # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dtt * A[None])   # [B,H]
        state = state * decay[..., None, None] + jnp.einsum(
            "bn,bhp,bh->bhnp", bt, xt.astype(jnp.float32), dtt)
        y = jnp.einsum("bn,bhnp->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((B, H, N, P), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), state


def mla_decode_attention_ref(q_eff, q_rope, c_cache, kr_cache, lengths):
    """Absorbed MLA decode: q_eff [B,H,r], q_rope [B,H,dr],
    c_cache [B,S,r], kr_cache [B,S,dr], lengths [B] -> [B,H,r]."""
    r, dr = q_eff.shape[-1], q_rope.shape[-1]
    qk_dim = (128 if r >= 128 else r) + dr
    s = (jnp.einsum("bhr,btr->bht", q_eff.astype(jnp.float32),
                    c_cache.astype(jnp.float32))
         + jnp.einsum("bhd,btd->bht", q_rope.astype(jnp.float32),
                      kr_cache.astype(jnp.float32))) / math.sqrt(qk_dim)
    t = jnp.arange(c_cache.shape[1])[None, None]
    s = jnp.where(t < lengths[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,btr->bhr", p,
                      c_cache.astype(jnp.float32)).astype(q_eff.dtype)


def kv_cache_write_ref(cache, new_kv, pos):
    b = jnp.arange(cache.shape[0])
    return cache.at[b, pos].set(new_kv.astype(cache.dtype))
