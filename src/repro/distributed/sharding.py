"""Sharding rules: map parameter/activation pytrees onto the production mesh.

Parallelism mapping (DESIGN.md §5):
* TP  -> ``model`` axis: attention heads, MLP hidden, vocab.
* DP  -> ``data`` (+ ``pod``) axes: batch.
* EP  -> experts over ``data``; expert hidden over ``model``
         (the ``moe_ep`` shard_map path consumes exactly these specs).

Rules are name-based over flattened tree paths — the same convention MaxText
uses (logical axis rules), collapsed to the two-three physical axes we have.
A dim is only sharded if its size divides the axis size; otherwise it is
replicated (e.g. GQA kv-head projections with 2 kv heads stay replicated on a
16-way model axis — the TP-correct choice for MQA/GQA).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """How the model maps onto a mesh (passed down to MoE/attention code).

    * production mesh: axes ('pod','data','model') or ('data','model');
      ep_axes=('pod','data'), tp_axis='model', moe_tp=True.
    * elastic engine mesh: axes ('dp','tp'); ep_axes=('dp','tp'),
      tp_axis='tp', moe_tp=False (expert FFN dim unsharded — EP spans all
      devices, matching the paper's EP = DP x TP convention).
    """
    mesh: Mesh
    ep_axes: Tuple[str, ...] = ("pod", "data")
    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("pod", "data")
    moe_tp: bool = True
    moe_dispatch: str = "expert_slots"   # or "packed" (decode optimization)

    @property
    def num_ep(self) -> int:
        n = 1
        for a in self.ep_axes:
            if a in self.mesh.axis_names:
                n *= self.mesh.shape[a]
        return n


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh) -> P:
    return P(dp_axes(mesh))


def act_spec(mesh: Mesh) -> P:
    """[B, S, D] activations: batch over dp axes, rest replicated."""
    return P(dp_axes(mesh), None, None)


def _axis_size(mesh, name):
    return mesh.shape.get(name, 1)


# (regex on the '/'-joined tree path, per-dim logical axes)
# logical axes: 'model' (TP), 'expert' (EP -> data), None (replicated)
_RULES = [
    # --- MoE expert banks: [E, D, F] / [E, F, D]
    (r"moe/wi$",  ("expert", None, "model")),
    (r"moe/wg$",  ("expert", None, "model")),
    (r"moe/wo$",  ("expert", "model", None)),
    (r"moe/router/w$", (None, None)),
    # --- attention projections
    (r"attn/q/w$", (None, "model")),
    (r"attn/q/b$", ("model",)),
    (r"attn/q_up/w$", (None, "model")),
    (r"attn/(k|v)/w$", (None, "model_kv")),
    (r"attn/(k|v)/b$", ("model_kv",)),
    (r"attn/o/w$", ("model", None)),
    (r"attn/k_up/w$", (None, "model")),
    (r"attn/v_up/w$", (None, "model")),
    (r"xattn/q/w$", (None, "model")),
    (r"xattn/(k|v)/w$", (None, "model_kv")),
    (r"xattn/o/w$", ("model", None)),
    # --- MLPs (dense, shared experts): [D, F] / [F, D]
    (r"(mlp|shared)/(up|gate)/w$", (None, "model")),
    (r"(mlp|shared)/(up|gate)/b$", ("model",)),
    (r"(mlp|shared)/down/w$", ("model", None)),
    # --- SSM: head-sharded over model
    (r"ssm/in_proj/w$", (None, None)),
    (r"ssm/out_proj/w$", (None, None)),
    (r"ssm/(A_log|dt_bias|D_skip)$", ("model_h",)),
    # --- embeddings / head
    (r"embed$", ("model", None)),
    (r"lm_head/w$", (None, "model")),
]


def _spec_for_path(path: str, shape, mesh: Mesh, stacked_dims: int,
                   kv_heads: Optional[int] = None) -> P:
    axes: Optional[tuple] = None
    for pat, a in _RULES:
        if re.search(pat, path):
            axes = a
            break
    if axes is None:
        return P()
    out = [None] * len(shape)
    base = stacked_dims  # leading scan-stacked dims stay replicated
    for i, ax in enumerate(axes):
        dim = base + i
        if dim >= len(shape) or ax is None:
            continue
        size = shape[dim]
        if ax == "model_kv" and kv_heads is not None                 and kv_heads % _axis_size(mesh, "model") != 0:
            # GQA with few kv heads: sharding the flattened KVH*hd dim would
            # split inside a head and force cache-wide all-gathers at every
            # decode step (measured ~1 TB/step on chatglm3) — replicate.
            continue
        if ax in ("model", "model_kv", "model_h"):
            m = _axis_size(mesh, "model")
            if size % m == 0 and size >= m:
                out[dim] = "model"
        elif ax == "expert":
            ep = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            e = 1
            for a in ep:
                e *= _axis_size(mesh, a)
            if size % e == 0 and size >= e:
                out[dim] = ep
    return P(*out)


def _n_stacked(path: str) -> int:
    """How many leading dims of this leaf are scan-stacking dims."""
    if re.search(r"(^|/)(blocks|cross_blocks)/", path):
        return 1
    return 0


def param_specs(params, mesh: Mesh, kv_heads: Optional[int] = None):
    """pytree of PartitionSpec, matched to ``params`` structure.

    ``kv_heads``: pass cfg.num_kv_heads to enable head-aligned KV sharding
    (replicates k/v projections when KVH doesn't divide the model axis —
    the beyond-paper fix for GQA resharding storms; see EXPERIMENTS.md
    §Perf iteration A)."""
    def one(path_tuple, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path_tuple)
        return _spec_for_path(path, leaf.shape, mesh, _n_stacked(path),
                              kv_heads)
    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, mesh: Mesh, kv_heads: Optional[int] = None):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, kv_heads))


def cache_specs(cfg, cache, mesh: Mesh, kv_seq_shard: bool = False):
    """Decode-cache sharding: batch over dp axes when divisible, else the KV
    sequence dim over 'data' (long-context, batch=1); heads over 'model' when
    divisible.

    ``kv_seq_shard`` (beyond-paper, EXPERIMENTS.md §Perf iteration A2): when
    the kv-head dim cannot shard over the model axis (GQA with few heads),
    shard the KV *sequence* dim over 'model' instead — flash-decoding style.
    GSPMD turns the softmax over the sharded seq dim into scalar-sized
    all-reduces and the pv matmul into a partial-sum reduction, so each chip
    reads S/16 of the cache instead of all of it."""
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    model = _axis_size(mesh, "model")

    def one(path_tuple, leaf):
        path = "/".join(str(getattr(k, "key", k)) for k in path_tuple)
        shape = leaf.shape
        # layout: [L, B, S|..., heads?, dim]
        spec = [None] * len(shape)
        if len(shape) >= 2 and shape[1] % n_dp == 0 and shape[1] >= n_dp:
            spec[1] = dp
        elif len(shape) >= 3 and "state" not in path and "conv" not in path \
                and shape[2] % n_dp == 0 and shape[2] >= n_dp:
            spec[2] = dp      # shard KV sequence (batch too small)
        # heads dim for k/v caches: [L,B,S,KVH,hd]
        is_kv = bool(re.search(r"(attn_k|attn_v|^k$|^v$|/k$|/v$|img_k|img_v)",
                               path)) and len(shape) == 5
        # MLA latent cache [L,B,S,r] / rope-key cache [L,B,S,dr]
        is_mla = bool(re.search(r"(^|/)(c|kr)$", path)) and len(shape) == 4
        if is_kv and shape[3] % model == 0 and shape[3] >= model:
            spec[3] = "model"
        elif (is_kv or is_mla) and kv_seq_shard and spec[2] is None \
                and shape[2] % model == 0 and shape[2] >= model:
            spec[2] = "model"  # flash-decoding seq sharding
        if "state" in path and len(shape) == 5 and shape[2] % model == 0:
            spec[2] = "model"  # SSM state heads
        return P(*spec)
    return jax.tree_util.tree_map_with_path(one, cache)


def cache_shardings(cfg, cache, mesh: Mesh, kv_seq_shard: bool = False):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(cfg, cache, mesh, kv_seq_shard))
