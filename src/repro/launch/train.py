"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains the *reduced* (smoke) variant of the chosen
architecture; on a real cluster the same step function is what the dry-run
lowers for the production mesh.
"""
import argparse

from repro.configs import ASSIGNED, get_config
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ASSIGNED))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (requires a real cluster)")
    args = ap.parse_args()

    cfg = get_config(args.arch if args.full else args.arch + "-smoke")
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    out = train(cfg, steps=args.steps, batch=args.batch, seq_len=args.seq,
                opt=AdamWConfig(lr=args.lr, total_steps=args.steps),
                log_every=max(args.steps // 10, 1))
    print(f"done: loss {out['history'][0][1]:.4f} -> "
          f"{out['history'][-1][1]:.4f} in {out['wall_s']:.0f}s")


if __name__ == "__main__":
    main()
