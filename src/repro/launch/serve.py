"""Elastic serving launcher:
``python -m repro.launch.serve --arch <id> --devices 8 [--autoscale]``.

Boots the ElasticServer on host devices with the reduced config, replays a
bursty synthetic workload, and (optionally) lets the SLO-aware coordinator
drive scale-up/scale-down across the device ladder.
"""
import os

_N = int(os.environ.get("REPRO_SERVE_DEVICES", "8"))
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_N}"

import argparse

import numpy as np

from repro.configs import ASSIGNED, get_config
from repro.core.coordinator import ScalingPolicy
from repro.core.elastic_engine import ElasticServer
from repro.core.topology import ElasticConfig
from repro.serving.metrics import SLO, summarize
from repro.serving.workload import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b",
                    choices=sorted(ASSIGNED))
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--autoscale", action="store_true")
    args = ap.parse_args()

    import dataclasses
    cfg = dataclasses.replace(get_config(args.arch + "-smoke"),
                              capacity_factor=100.0)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no serving decode")
    if cfg.is_moe and cfg.num_experts % (2 * args.tp):
        raise SystemExit("num_experts must divide the EP ladder")

    slo = SLO(ttft_s=2.0, tpot_s=1.0)
    policy = ScalingPolicy(slo=slo, window=8, cooldown_s=2.0,
                           queue_scale_up=3) if args.autoscale else None
    srv = ElasticServer(cfg, tp=args.tp, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), policy=policy, seed=0)
    ladder = [ElasticConfig(dp=d, tp=args.tp,
                            devices=tuple(range(args.tp * d)))
              for d in (1, 2, 3, 4) if args.tp * d <= _N]
    level = 1
    srv.boot(ladder[level])

    rng = np.random.default_rng(0)
    reqs = [Request(i, 0.15 * i, 16, int(rng.integers(8, 20)),
                    prompt=rng.integers(0, cfg.vocab_size, 16))
            for i in range(args.requests)]
    t, i = 0.0, 0
    while any(r.finish_s is None for r in reqs):
        while i < len(reqs) and reqs[i].arrival_s <= t:
            srv.submit(reqs[i]); i += 1
        if args.autoscale:
            d = srv.autoscale_decision(t)
            if d == "up" and level + 1 < len(ladder):
                level += 1
                srv.scale_to(ladder[level])
                print(f"[t={t:.2f}] scaled up -> "
                      f"{srv.hmm.active_cfg.describe()}")
        srv.tick(t)
        t += 0.05
        if t > 300:
            raise SystemExit("stalled")
    print(summarize(reqs, slo))


if __name__ == "__main__":
    main()
