"""Production meshes (a function, never a module-level constant — importing
this module must not touch jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
