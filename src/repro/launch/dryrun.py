import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, compiles, and fits — with no real allocation.

For each combination this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs sharded ShapeDtypeStructs for params (+opt state) and inputs,
  3. ``jit(step).lower(...).compile()`` — sharding mismatches, unsupported
     collectives or compile-time OOM are hard failures,
  4. records ``memory_analysis()`` / ``cost_analysis()`` + parsed collective
     bytes into experiments/dryrun/*.json for the roofline report.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis.roofline import from_compiled, model_flops_for
from repro.configs import ASSIGNED, INPUT_SHAPES, SHAPES_BY_NAME, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.distributed.sharding import (ParallelCtx, dp_axes, param_specs)
from repro.launch.mesh import make_production_mesh
from repro.models.model import decode_step, forward, init_cache, init_params
from repro.training.optimizer import AdamWConfig, init_state
from repro.training.train_loop import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# sliding-window length used to run long_500k on full-attention archs
LONG_CONTEXT_WINDOW = 8192


def plan_for(mcfg: ModelConfig, shape: InputShape):
    """Returns (effective_cfg, kind) or (None, skip_reason)."""
    if shape.kind == "train":
        return mcfg, "encode_train" if mcfg.arch_type == "encoder" else "train"
    if mcfg.arch_type == "encoder":
        if shape.kind == "prefill":
            return mcfg, "encode"
        return None, "encoder-only: no decode step (DESIGN.md §4)"
    if shape.kind == "prefill":
        return mcfg, "prefill"
    # decode shapes
    if shape.name == "long_500k" and not mcfg.supports_long_context:
        if mcfg.arch_type in ("dense", "moe", "vlm"):
            eff = dataclasses.replace(mcfg, attn_window=LONG_CONTEXT_WINDOW)
            return eff, "decode"
        return None, "full attention at 500k skipped (DESIGN.md §4)"
    return mcfg, "decode"


def parallel_for(mesh, opts=None):
    opts = opts or {}
    ep = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ParallelCtx(mesh=mesh, ep_axes=ep, tp_axis="model", dp_axes=ep,
                       moe_tp=True,
                       moe_dispatch=("packed" if opts.get("moe_packed")
                                     else "expert_slots"))


def _sharded_sds(tree_shapes, specs, mesh):
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree_shapes, specs)


def params_sds(mcfg, mesh, opts=None):
    opts = opts or {}
    shapes = jax.eval_shape(
        partial(init_params, mcfg, jax.random.PRNGKey(0),
                jnp.dtype(mcfg.dtype)))
    kv_heads = mcfg.num_kv_heads if opts.get("kv_aligned") else None
    specs = param_specs(shapes, mesh, kv_heads=kv_heads)
    return _sharded_sds(shapes, specs, mesh)


def input_specs(mcfg: ModelConfig, shape: InputShape, mesh, kind: str,
                opts=None):
    """Sharded ShapeDtypeStructs for every model input of this step."""
    dp = dp_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    bspec = dp if B % n_dp == 0 and B >= n_dp else None

    def sds(shp, dtype, spec):
        return jax.ShapeDtypeStruct(shp, dtype,
                                    sharding=NamedSharding(mesh, P(*spec)))

    if kind in ("train", "encode_train", "encode"):
        batch = {}
        if mcfg.arch_type == "encoder":
            batch["frames"] = sds((B, S, mcfg.d_model), jnp.dtype(mcfg.dtype),
                                  (bspec, None, None))
        else:
            batch["tokens"] = sds((B, S), jnp.int32, (bspec, None))
        if kind != "encode":
            batch["labels"] = sds((B, S), jnp.int32, (bspec, None))
        if mcfg.arch_type == "vlm":
            batch["image_embeds"] = sds(
                (B, mcfg.num_image_tokens, mcfg.d_model),
                jnp.dtype(mcfg.dtype), (bspec, None, None))
        return batch

    if kind == "prefill":
        batch = {"tokens": sds((B, S), jnp.int32, (bspec, None)),
                 "lengths": sds((B,), jnp.int32, (bspec,))}
        if mcfg.arch_type == "vlm":
            batch["image_embeds"] = sds(
                (B, mcfg.num_image_tokens, mcfg.d_model),
                jnp.dtype(mcfg.dtype), (bspec, None, None))
        return batch

    # decode: one new token against a cache of seq_len
    cache_shapes = jax.eval_shape(
        partial(init_cache, mcfg, B, S, jnp.dtype(mcfg.dtype)))
    from repro.distributed.sharding import cache_specs
    cspecs = cache_specs(mcfg, cache_shapes, mesh,
                         kv_seq_shard=(opts or {}).get("kv_seq_shard", False))
    cache = _sharded_sds(cache_shapes, cspecs, mesh)
    return {
        "tokens": sds((B, 1), jnp.int32, (bspec, None)),
        "cache": cache,
        "lengths": sds((B,), jnp.int32, (bspec,)),
    }


def build_step(mcfg: ModelConfig, kind: str, parallel, max_len: int,
               opts=None):
    opts = opts or {}
    if kind in ("train", "encode_train"):
        opt = AdamWConfig(total_steps=1000)
        step = make_train_step(mcfg, opt, parallel,
                               remat=not opts.get("no_remat"))
        return step, ("params", "opt_state", "batch")
    if kind == "encode":
        def encode(params, batch):
            logits, _ = forward(mcfg, params, batch, parallel=parallel,
                                remat=False)
            return logits
        return encode, ("params", "batch")
    if kind == "prefill":
        def pf(params, batch):
            from repro.models.model import prefill
            return prefill(mcfg, params, batch, max_len=max_len,
                           parallel=parallel)
        return pf, ("params", "batch")
    if kind == "decode":
        def dec(params, tokens, cache, lengths):
            return decode_step(mcfg, params, tokens, cache, lengths,
                               parallel=parallel)
        return dec, ("params", "tokens", "cache", "lengths")
    raise ValueError(kind)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            save: bool = True, opts=None) -> dict:
    opts = opts or {}
    mcfg0 = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mcfg, kind = plan_for(mcfg0, shape)
    mesh_name = "multipod" if multi_pod else "singlepod"
    if opts.pop("_tag_opt", False) or opts:
        mesh_name += "-opt"
    if mcfg is None:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": kind}
        if save:
            _save(rec)
        return rec

    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    parallel = parallel_for(mesh, opts)
    chips = mesh.size

    with mesh:
        psds = params_sds(mcfg, mesh, opts)
        ins = input_specs(mcfg, shape, mesh, kind, opts)
        step, argnames = build_step(mcfg, kind, parallel,
                                    max_len=shape.seq_len, opts=opts)

        if kind in ("train", "encode_train"):
            opt_shapes = jax.eval_shape(
                partial(init_state, AdamWConfig()), psds)
            # mu/nu shard like params; step counter replicated
            pspecs = param_specs(psds, mesh)
            opt_sds = type(opt_shapes)(
                step=jax.ShapeDtypeStruct((), jnp.int32,
                                          sharding=NamedSharding(mesh, P())),
                mu=_sharded_sds(opt_shapes.mu, pspecs, mesh),
                nu=_sharded_sds(opt_shapes.nu, pspecs, mesh))
            jfn = jax.jit(step, donate_argnums=(0, 1))
            lowered = jfn.lower(psds, opt_sds, ins)
        elif kind == "decode":
            jfn = jax.jit(step, donate_argnums=(2,))
            lowered = jfn.lower(psds, ins["tokens"], ins["cache"],
                                ins["lengths"])
        else:
            jfn = jax.jit(step)
            lowered = jfn.lower(psds, ins)
        t_lower = time.perf_counter() - t0

        t1 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t1

        mem = compiled.memory_analysis()
        mem_rec = {}
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    mem_rec[attr] = int(v)
        hlo = compiled.as_text()
        rl = from_compiled(compiled, chips,
                           model_flops_for(mcfg, shape,
                                           "train" if "train" in kind else
                                           ("decode" if kind == "decode"
                                            else "prefill")),
                           hlo_text=hlo)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "kind": kind,
        "opts": opts,
        "status": "ok", "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec,
        "roofline": rl.as_dict(),
        "attn_window": mcfg.attn_window,
        "hlo_bytes": len(hlo),
    }
    if save:
        _save(rec)
    return rec


def _save(rec):
    os.makedirs(OUT_DIR, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json"
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def optimized_opts(arch: str, shape_name: str) -> dict:
    """Beyond-paper optimization set, applied per step kind (EXPERIMENTS.md
    §Perf): measured wins on decode; packed dispatch *regresses* train and
    prefill (E_local x compute waste) so it stays decode-only, and
    flash-decoding seq sharding is for the full-cache decode_32k case."""
    shape = SHAPES_BY_NAME[shape_name]
    opts = {}
    if shape.name != "long_500k":
        # replicated kv projections regress the windowed batch=1 case
        # (measured 0.85-0.90x) — keep the sharded layout there
        opts["kv_aligned"] = True
    if shape.kind == "decode":
        opts["moe_packed"] = True
        if shape.name == "decode_32k":
            opts["kv_seq_shard"] = True
    return opts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="beyond-paper sharding/dispatch optimizations "
                         "(EXPERIMENTS.md §Perf): head-aligned KV, "
                         "flash-decoding seq-sharded caches, packed MoE "
                         "dispatch")
    args = ap.parse_args()

    archs = sorted(ASSIGNED) if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in INPUT_SHAPES] if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = ("multipod" if mp else "singlepod") + \
                    ("-opt" if args.optimized else "")
                fname = os.path.join(OUT_DIR,
                                     f"{arch}_{shape}_{mesh_name}.json")
                if args.skip_existing and os.path.exists(fname):
                    print(f"[skip-existing] {arch} {shape} {mesh_name}")
                    continue
                try:
                    rec = run_one(arch, shape, mp,
                                  opts=(optimized_opts(arch, shape)
                                        if args.optimized else {}))
                    if rec["status"] == "ok":
                        r = rec["roofline"]
                        print(f"[ok]   {arch:24s} {shape:12s} {mesh_name:9s} "
                              f"compile={rec['compile_s']:7.1f}s "
                              f"bottleneck={r['bottleneck']:10s} "
                              f"useful={r['useful_flops_ratio']:.2f}")
                    else:
                        print(f"[skip] {arch:24s} {shape:12s} {mesh_name:9s} "
                              f"{rec['reason']}")
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mesh_name, str(e)))
                    print(f"[FAIL] {arch} {shape} {mesh_name}: {e}")
    if failures:
        print(f"\n{len(failures)} FAILURES")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
