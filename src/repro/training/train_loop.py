"""pjit training step + loop.

``make_train_step`` is shared by the real training examples (CPU, small
models) and the multi-pod dry-run (lower/compile only, production mesh).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import (ParallelCtx, act_spec, dp_axes,
                                        param_shardings)
from repro.models.model import init_params, loss_fn
from repro.training.optimizer import (AdamWConfig, AdamWState, apply_updates,
                                      init_state)


def make_train_step(mcfg: ModelConfig, opt: AdamWConfig,
                    parallel: Optional[ParallelCtx] = None,
                    remat: bool = True):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(mcfg, p, batch, parallel=parallel, remat=remat)
        )(params)
        params, opt_state, info = apply_updates(opt, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **info}

    return step


def train(mcfg: ModelConfig, *, steps: int, batch: int, seq_len: int,
          opt: Optional[AdamWConfig] = None, seed: int = 0,
          log_every: int = 10, mesh=None) -> Dict[str, Any]:
    """Single-host training loop (examples / smoke tests)."""
    from repro.training.data import synthetic_batches
    opt = opt or AdamWConfig(total_steps=steps)
    params = init_params(mcfg, jax.random.PRNGKey(seed))
    opt_state = init_state(opt, params)
    parallel = None
    step_fn = jax.jit(make_train_step(mcfg, opt, parallel))
    it = synthetic_batches(mcfg, batch, seq_len, seed)
    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, m = step_fn(params, opt_state, b)
        if i % log_every == 0 or i == steps - 1:
            loss = float(m["loss"])
            history.append((i, loss))
            print(f"step {i:5d}  loss {loss:.4f}  lr {float(m['lr']):.2e}")
    return {"params": params, "opt_state": opt_state, "history": history,
            "wall_s": time.perf_counter() - t0}
