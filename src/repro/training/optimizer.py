"""AdamW + cosine schedule, pure JAX (no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(cfg: AdamWConfig, params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    step = state.step + 1
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step)
        vhat = v / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([n[0] for n in new])
    new_m = tdef.unflatten([n[1] for n in new])
    new_v = tdef.unflatten([n[2] for n in new])
    return new_p, AdamWState(step, new_m, new_v), {"lr": lr, "gnorm": gnorm}
