"""Minimal .npz checkpointing for params/optimizer state (orbax-free)."""
from __future__ import annotations

import os
from typing import Any, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str, template: Any) -> Any:
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    paths, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in p)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(tdef, leaves)
