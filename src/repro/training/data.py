"""Synthetic token pipeline: deterministic, infinite, shardable."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig


def synthetic_batches(mcfg: ModelConfig, batch: int, seq_len: int,
                      seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Markov-ish synthetic LM data: structured enough that loss decreases."""
    rng = np.random.default_rng(seed)
    V = mcfg.vocab_size
    # fixed random bigram preference table (sparse structure to learn)
    nxt = rng.integers(0, V, size=(V,))
    while True:
        toks = np.empty((batch, seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, V, size=batch)
        noise = rng.random((batch, seq_len)) < 0.15
        rand = rng.integers(0, V, size=(batch, seq_len))
        for t in range(seq_len):
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt[toks[:, t]])
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if mcfg.arch_type == "encoder":
            out["frames"] = rng.standard_normal(
                (batch, seq_len, mcfg.d_model)).astype(np.float32)
            out["labels"] = rng.integers(0, V, (batch, seq_len)).astype(np.int32)
        if mcfg.arch_type == "vlm":
            out["image_embeds"] = rng.standard_normal(
                (batch, mcfg.num_image_tokens, mcfg.d_model)).astype(np.float32)
        yield out
