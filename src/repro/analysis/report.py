"""Roofline report generator: experiments/dryrun/*.json -> markdown tables
for EXPERIMENTS.md (§Dry-run and §Roofline).

Usage: PYTHONPATH=src python -m repro.analysis.report [--mesh singlepod]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

ADVICE = {
    ("compute",): "increase arithmetic intensity (bigger per-step batch or "
                  "fused kernels); compute-bound is the good place to be",
    ("memory", "train"): "cut activation traffic: fewer remat boundaries, "
                         "bf16 intermediates, larger fused blocks",
    ("memory", "prefill"): "fuse attention (flash) so scores never round-trip"
                           " HBM; keep QKV in VMEM-sized tiles",
    ("memory", "decode"): "KV reads dominate: shrink the cache (MLA latent / "
                          "GQA / windowing) or batch more sequences per step",
    ("collective", "train"): "overlap grad all-reduce with backprop; shard "
                             "weights to turn all-gathers into reduce-scatters",
    ("collective", "decode"): "decode collectives are latency-bound: replicate"
                              " small KV projections instead of sharding them,"
                              " and shard the dispatch payload before a2a",
    ("collective", "prefill"): "batch collectives per layer; shard a2a "
                               "payloads over the model axis",
}


def load(mesh: str) -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, f"*_{mesh}.json"))):
        recs.append(json.load(open(f)))
    return recs


def advice(bottleneck: str, kind: str) -> str:
    k = "train" if "train" in kind or kind == "encode" else kind
    return ADVICE.get((bottleneck, k)) or ADVICE.get((bottleneck,)) or ""


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(mesh: str) -> str:
    recs = load(mesh)
    lines = [
        "| arch | shape | kind | t_compute | t_memory | t_collective | "
        "bottleneck | MODEL/HLO flops | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"skipped | — | {r['reason']} |")
            continue
        rl = r["roofline"]
        note = "window=%s" % r["attn_window"] if r.get("attn_window") else ""
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{fmt_s(rl['t_compute_s'])} | {fmt_s(rl['t_memory_s'])} | "
            f"{fmt_s(rl['t_collective_s'])} | **{rl['bottleneck']}** | "
            f"{rl['useful_flops_ratio']:.2f} | {note} |")
    return "\n".join(lines)


def dryrun_table(mesh: str) -> str:
    recs = load(mesh)
    lines = [
        "| arch | shape | status | compile | HLO GFLOPs/dev | HBM GB/dev | "
        "coll GB/dev | temp GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | skip | — | — | — | "
                         f"— | — |")
            continue
        rl = r["roofline"]
        chips = rl["chips"]
        mem = r.get("memory_analysis", {})
        temp = mem.get("temp_size_in_bytes", 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.1f}s | "
            f"{rl['flops']/chips/1e9:.1f} | "
            f"{rl['hbm_bytes']/chips/1e9:.1f} | "
            f"{rl['coll_bytes_total']/chips/1e9:.2f} | {temp:.1f} |")
    return "\n".join(lines)


def bottleneck_summary(mesh: str) -> str:
    recs = [r for r in load(mesh) if r["status"] == "ok"]
    out = []
    for r in recs:
        rl = r["roofline"]
        out.append(f"- **{r['arch']} × {r['shape']}** ({r['kind']}): "
                   f"{rl['bottleneck']}-bound; {advice(rl['bottleneck'], r['kind'])}.")
    return "\n".join(out)


def worst_pairs(mesh: str, n=5):
    recs = [r for r in load(mesh) if r["status"] == "ok"]
    def frac(r):
        rl = r["roofline"]
        dom = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        return rl["t_compute_s"] / dom if dom else 0
    recs.sort(key=frac)
    return [(r["arch"], r["shape"], round(frac(r), 3)) for r in recs[:n]]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="singlepod")
    args = ap.parse_args()
    print("### Dry-run\n")
    print(dryrun_table(args.mesh))
    print("\n### Roofline\n")
    print(roofline_table(args.mesh))
    print("\n### Bottlenecks\n")
    print(bottleneck_summary(args.mesh))
    print("\nworst compute-fraction pairs:", worst_pairs(args.mesh))


if __name__ == "__main__":
    main()
