"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs   / (chips * peak_FLOP/s)
  memory     = HLO_bytes   / (chips * HBM_bw)
  collective = coll_bytes  / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``;
collective bytes are parsed out of the optimized HLO text (sum of the output
buffer sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops), since XLA's cost model does not expose them.

Hardware constants (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ARRAY_RE = re.compile(r"(pred|[suf]\d+|bf16|f16|f32|f64|c64|c128)\[([\d,]*)\]")


def _array_bytes(text: str) -> int:
    """Sum sizes of all array literals in an HLO type string."""
    total = 0
    for dt, dims in _ARRAY_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind output bytes of communication ops in optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    start_re = re.compile(
        r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+(" +
        "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
    seen_done = set()
    for line in hlo_text.splitlines():
        m = start_re.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        # avoid double counting async pairs: count -start, skip -done;
        # count sync form normally
        if f"{kind}-done(" in line:
            continue
        out[kind] += _array_bytes(type_str)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # total HLO flops (whole program)
    hbm_bytes: float             # total bytes accessed
    coll_bytes: Dict[str, int]   # per collective kind (global)
    chips: int
    model_flops: float = 0.0     # 6*N*D (analytic)

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.total_coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        if not self.flops:
            return float("nan")
        return self.model_flops / self.flops

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_bytes_total": self.total_coll_bytes,
            "chips": self.chips, "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def from_compiled(compiled, chips: int, model_flops: float = 0.0,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Roofline terms from a compiled executable.

    Primary source is the loop-aware HLO analyzer (hlo_costs.analyze) —
    XLA:CPU's cost_analysis counts while bodies once, which under-reports
    scanned-layer models by ~num_layers x.  Totals below are global
    (per-device analyzer output x chips).
    """
    from repro.analysis import hlo_costs
    text = hlo_text if hlo_text is not None else compiled.as_text()
    costs = hlo_costs.analyze(text)
    flops = costs.flops * chips
    hbm = costs.bytes_accessed * chips
    coll = {k: int(v * chips) for k, v in costs.coll_bytes.items()}
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll, chips=chips,
                    model_flops=model_flops)


def model_flops_for(mcfg, shape, kind: str) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*tokens for train, 2*N_active*tokens
    for inference forward passes."""
    n_active = mcfg.param_count(active_only=True)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
