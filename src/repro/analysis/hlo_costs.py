"""Loop-aware cost extraction from optimized HLO text.

XLA's ``compiled.cost_analysis()`` on the CPU backend counts each ``while``
body **once**, which under-reports scanned-layer models by ~num_layers x.
This module parses the HLO module text, builds the call graph
(while/fusion/call/conditional), extracts per-computation costs, and rolls
them up with loop trip counts:

* flops            — 2*M*N*K per ``dot`` (batch dims included),
* collective bytes — output buffer sizes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute,
* hbm bytes        — sum of operand + output buffer sizes of non-trivial ops
                     (a "bytes accessed" proxy at fusion granularity).

Trip counts are recovered from the loop condition's integer constant.
All numbers are per-device (HLO is the per-device SPMD module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_ARRAY_RE = re.compile(
    r"(pred|token|[suf]\d+|bf16|f16|f8e4m3fn|f8e5m2|c64|c128)\[([\d,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _ARRAY_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*")


def _split_op(line: str):
    """Parse '%name = TYPE opcode(args...' robustly.

    TYPE may be a (possibly huge) tuple containing '=', '/*index=k*/'
    comments, layouts, etc.  We walk the string tracking bracket depth; the
    opcode is the first bare word followed by '(' at depth 0 after the type
    expression begins."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    rest = line[m.end():]
    depth = 0
    i = 0
    n = len(rest)
    while i < n:
        ch = rest[i]
        if ch in "([{":
            # is this a word( at depth 0 (i.e. an opcode call)?
            if ch == "(" and depth == 0:
                j = i - 1
                while j >= 0 and (rest[j].isalnum() or rest[j] in "-_"):
                    j -= 1
                word = rest[j + 1:i]
                if word and word[0].isalpha() and j >= 0:
                    return (m.group(1), rest[:j + 1].strip(), word,
                            rest[i + 1:])
            depth += 1
        elif ch in ")]}":
            depth -= 1
        i += 1
    return None


_OP_RE = None  # replaced by _split_op
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")


@dataclasses.dataclass
class OpInfo:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpInfo]


def parse_computations(hlo: str) -> Dict[str, Computation]:
    """Indentation-based parse: computation headers start at column 0
    (``%name (params...) -> type {``, possibly wrapping over several lines);
    op lines are indented; a bare ``}`` at column 0 closes the computation."""
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if not line:
            continue
        if line[0] not in " \t":
            if line.strip() == "}":
                cur = None
                continue
            head = line
            if head.startswith("ENTRY"):
                head = head[len("ENTRY"):].lstrip()
            m = re.match(r"%?([\w.\-]+)\s*\(", head)
            if m and not head.startswith(("HloModule", "FileNames",
                                          "FunctionNames")):
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        mo = _split_op(line)
        if mo:
            cur.ops.append(OpInfo(mo[0], mo[1], mo[2], line))
    return comps


def _dot_flops(op: OpInfo, types: Dict[str, str]) -> float:
    """2 * (product of output dims) * (product of rhs contracting dims).

    Operand types are resolved through the computation's symbol table
    (operands are bare %names in optimized HLO)."""
    out_dims = _shape_dims(op.type_str)
    out = 1
    for d in out_dims:
        out *= d
    m = re.search(r"rhs_contracting_dims=\{([\d,]*)\}", op.line)
    refs = re.findall(r"%[\w.\-]+", op.line.split("(", 1)[1])
    rhs_type = types.get(refs[1]) if len(refs) >= 2 else None
    if rhs_type is None or not m:
        # inline-typed operands (rare) or missing attrs: best-effort
        inline = _ARRAY_RE.findall(op.line.split("(", 1)[1])
        if inline and inline[0][1]:
            return 2.0 * out * int(inline[0][1].split(",")[-1])
        return 2.0 * out
    rhs_dims = _shape_dims(rhs_type)
    cdims = [int(d) for d in m.group(1).split(",")] if m.group(1) else []
    k = 1
    for c in cdims:
        if c < len(rhs_dims):
            k *= rhs_dims[c]
    return 2.0 * out * k


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition — the bound of the
    canonical `i < N` compare XLA emits for lax.scan/while."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    bytes_once: float = 0.0   # loop-carried accumulators: touched ~once per
                              # loop on TPU (dus/pad+add), so not x trips
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def scaled(self, f: float) -> "Costs":
        return Costs(self.flops * f, self.bytes_accessed * f,
                     self.bytes_once,
                     {k: v * f for k, v in self.coll_bytes.items()})

    def add(self, o: "Costs"):
        self.flops += o.flops
        self.bytes_accessed += o.bytes_accessed
        self.bytes_once += o.bytes_once
        for k in self.coll_bytes:
            self.coll_bytes[k] += o.coll_bytes[k]

    @property
    def total_bytes(self) -> float:
        return self.bytes_accessed + self.bytes_once


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast", "copy",
    "reshape", "while", "call", "conditional", "custom-call", "after-all",
    "partition-id",
    # --- TPU-fusion approximation: XLA:CPU leaves elementwise chains
    # unfused, but on the TPU target these fuse into neighbouring dots /
    # fusions, so their intermediates never touch HBM.  Counting them would
    # wildly overstate the memory-roofline term (measured 60x on qwen-0.5b).
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "clamp", "convert",
    "compare", "select", "and", "or", "not", "xor", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "broadcast", "iota",
    "transpose", "reverse", "pad", "rng", "rng-bit-generator",
    "rng-get-and-update-state", "cosine", "sine", "tan", "atan2", "erf",
    "is-finite", "reduce-precision", "real", "imag", "remainder",
}


def _fusion_bytes(op: OpInfo, types: Dict[str, str],
                  comps: Dict[str, Computation]) -> float:
    """Real memory traffic of a fusion op.

    Loop bodies carry big stacked tensors (remat-saved activations, KV
    caches) that fusions only *slice*: counting the full operand per
    iteration overstates traffic ~num_layers x.  We look inside the fused
    computation: a parameter consumed **only** by dynamic-slice contributes
    its slice size; a root dynamic-update-slice writes only the update."""
    m = re.search(r"calls=%?([\w.\-]+)", op.line)
    out_b = _type_bytes(op.type_str)
    arg_str = op.line.split("(", 1)[1]
    refs = re.findall(r"%[\w.\-]+", arg_str)
    # drop trailing attribute refs (calls=..., metadata) — operands come first
    operand_refs = []
    for r in refs:
        if r[1:] == (m.group(1) if m else ""):
            break
        operand_refs.append(r)
    operand_bytes = [_type_bytes(types.get(r, "")) for r in operand_refs]

    if not m or m.group(1) not in comps:
        return out_b + float(sum(operand_bytes)), 0.0

    fc = comps[m.group(1)]
    body_ops = {o.opcode for o in fc.ops if o.opcode != "parameter"}
    if body_ops <= {"convert", "bitcast", "copy", "reshape"}:
        # pure dtype/layout chain: on TPU this fuses into the consumer's
        # MXU op (bf16 operands convert in-register) — no HBM round-trip
        return 0.0, 0.0
    # map parameter index -> internal name; find ds-only params & root dus
    param_of: Dict[str, int] = {}
    consumers: Dict[str, List[OpInfo]] = {}
    ftypes = {o.name: o.type_str for o in fc.ops}
    for o in fc.ops:
        if o.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", o.line)
            if pm:
                param_of[o.name] = int(pm.group(1))
        for r in re.findall(r"%[\w.\-]+", o.line.split("(", 1)[1]):
            consumers.setdefault(r, []).append(o)
    total = 0.0
    once = 0.0
    out_dims = _shape_dims(op.type_str)
    root = next((o for o in fc.ops if "ROOT" in o.line), None)
    dus_ops = [o for o in fc.ops
               if o.opcode in ("dynamic-update-slice", "scatter")]
    # a dus whose buffer has the fusion's output shape = an in-place update
    # of the carried buffer (XLA:CPU may wrap it in dtype converts; on TPU
    # it aliases) — only the update slice is real traffic
    aliasing_dus = [o for o in dus_ops if _shape_dims(o.type_str) == out_dims]
    acc_root = False
    for pname, idx in param_of.items():
        if idx >= len(operand_bytes):
            continue
        cons = consumers.get(pname, [])
        p_dims = _shape_dims(ftypes.get(pname, ""))
        if cons and all(c.opcode == "dynamic-slice" for c in cons):
            total += sum(_type_bytes(c.type_str) for c in cons)
        elif cons and all(c.opcode in ("dynamic-update-slice", "scatter")
                          for c in cons):
            pass  # aliased in-place buffer: write counted via the root below
        elif p_dims == out_dims and aliasing_dus:
            pass  # the carried buffer itself: aliased in-place on TPU
        elif p_dims == out_dims and any(c.opcode == "add" for c in cons):
            # pad+add accumulator over a loop-carried buffer: on TPU this is
            # a dus touching one slice/iteration; whole buffer ~once per loop
            once += operand_bytes[idx]
            acc_root = True
        else:
            total += operand_bytes[idx]
    if aliasing_dus:
        op0 = aliasing_dus[0]
        upd = re.findall(r"%[\w.\-]+", op0.line.split("(", 1)[1])
        upd_idx = 2 if op0.opcode == "scatter" else 1
        if len(upd) > upd_idx:
            total += 2 * _type_bytes(ftypes.get(upd[upd_idx], ""))
        else:
            total += out_b
    elif acc_root:
        once += out_b
    else:
        total += out_b
    return total, once


def analyze(hlo: str) -> Costs:
    comps = parse_computations(hlo)
    # operand type lookup per computation: name -> type
    memo: Dict[str, Costs] = {}

    entry_name = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry_name = m.group(1)
            break
    if entry_name is None or entry_name not in comps:
        # fall back: the computation with the most ops
        entry_name = max(comps, key=lambda c: len(comps[c].ops))

    def cost_of(name: str, stack=()) -> Costs:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return Costs()
        comp = comps[name]
        total = Costs()
        types: Dict[str, str] = {op.name: op.type_str for op in comp.ops}
        for op in comp.ops:
            if op.opcode == "while":
                mb, mc = _BODY_RE.search(op.line), _COND_RE.search(op.line)
                if mb:
                    body_cost = cost_of(mb.group(1), stack + (name,))
                    trips = _trip_count(comps[mc.group(1)]) if mc and \
                        mc.group(1) in comps else 1
                    total.add(body_cost.scaled(trips))
                continue
            if op.opcode in ("call", "conditional"):
                for callee in _CALLS_RE.findall(op.line):
                    if callee in comps and callee != name:
                        total.add(cost_of(callee, stack + (name,)))
            elif op.opcode in ("fusion", "custom-call", "map", "reduce",
                               "sort", "scatter", "reduce-window",
                               "select-and-scatter", "all-reduce"):
                # flops live in the fused computation; bytes are the fusion's
                # own operands+outputs (counted below) — avoids double count
                for callee in _CALLS_RE.findall(op.line):
                    if callee in comps and callee != name:
                        total.flops += cost_of(callee, stack + (name,)).flops
            if op.opcode == "dot":
                total.flops += _dot_flops(op, types)
            if op.opcode == "convolution":
                total.flops += 2.0 * _type_bytes(op.type_str)  # rough
            for kind in COLLECTIVES:
                if op.opcode.startswith(kind):
                    if op.opcode.endswith("-done"):
                        break
                    total.coll_bytes[kind] += _type_bytes(op.type_str)
                    break
            if op.opcode == "fusion":
                fb, fo = _fusion_bytes(op, types, comps)
                total.bytes_accessed += fb
                total.bytes_once += fo
            elif op.opcode in ("dynamic-update-slice",):
                # in-place slice write: traffic = the update, not the buffer
                ups = re.findall(r"%[\w.\-]+", op.line.split("(", 1)[1])
                upd_t = types.get(ups[1], "") if len(ups) >= 2 else ""
                total.bytes_accessed += 2 * _type_bytes(upd_t)
            elif op.opcode == "dynamic-slice":
                total.bytes_accessed += 2 * _type_bytes(op.type_str)
            elif op.opcode == "scatter":
                ups = re.findall(r"%[\w.\-]+", op.line.split("(", 1)[1])
                upd_t = types.get(ups[2], "") if len(ups) >= 3 else ""
                total.bytes_accessed += 2 * _type_bytes(upd_t)
            elif op.opcode not in _SKIP_BYTES_OPS:
                out_b = _type_bytes(op.type_str)
                opnd_b = 0
                # operands listed by name; resolve via local symbol table
                arg_str = op.line.split("(", 1)[1]
                for ref in re.findall(r"%([\w.\-]+)", arg_str):
                    t = types.get("%" + ref)
                    if t:
                        opnd_b += _type_bytes(t)
                # HLO may also inline operand types directly
                if opnd_b == 0:
                    opnd_b = _type_bytes(arg_str)
                total.bytes_accessed += out_b + opnd_b
        memo[name] = total
        return total

    return cost_of(entry_name)


def top_contributors(hlo: str, n: int = 15):
    """Per-op scaled byte contributions (same rules as analyze()) — the
    dry-run 'profiler' used by the §Perf iterations."""
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            entry = re.match(r"ENTRY\s+%?([\w.\-]+)", line).group(1)
            break
    mult = {entry: 1}
    stack = [entry]
    while stack:
        nm = stack.pop()
        for op in comps[nm].ops:
            if op.opcode == "while":
                mb = _BODY_RE.search(op.line)
                mc = _COND_RE.search(op.line)
                if mb:
                    t = _trip_count(comps[mc.group(1)]) if mc and \
                        mc.group(1) in comps else 1
                    mult[mb.group(1)] = mult.get(nm, 1) * t
                    stack.append(mb.group(1))
    rows = []
    for nm, m in mult.items():
        comp = comps[nm]
        types = {o.name: o.type_str for o in comp.ops}
        for op in comp.ops:
            if op.opcode == "while":
                continue
            b = 0.0
            if op.opcode == "fusion":
                t_, o_ = _fusion_bytes(op, types, comps)
                b = t_ + o_
            elif op.opcode == "dynamic-update-slice":
                ups = re.findall(r"%[\w.\-]+", op.line.split("(", 1)[1])
                b = 2 * _type_bytes(types.get(ups[1], "")) if len(ups) >= 2 \
                    else 0
            elif op.opcode == "dynamic-slice":
                b = 2 * _type_bytes(op.type_str)
            elif op.opcode == "scatter":
                ups = re.findall(r"%[\w.\-]+", op.line.split("(", 1)[1])
                b = 2 * _type_bytes(types.get(ups[2], "")) if len(ups) >= 3 \
                    else 0
            elif op.opcode not in _SKIP_BYTES_OPS:
                b = _type_bytes(op.type_str)
                arg = op.line.split("(", 1)[1]
                opnd = 0
                for ref in re.findall(r"%([\w.\-]+)", arg):
                    t_ = types.get("%" + ref)
                    if t_:
                        opnd += _type_bytes(t_)
                b += opnd
            if b:
                rows.append((b * m, m, op.opcode, op.name, nm))
    rows.sort(reverse=True)
    return rows[:n]
