"""Best-config table produced by the kernel autotune harness
(``tools/autotune_kernels.py``) and consulted by kernel dispatch
(``kernels/ops.py``).

The harness sweeps the static block/grid knobs of the three paged kernels
(decode-attention ``block_k``; paged-GMM ``block_c``/``block_f``; the
block/mixed kernels' recommended pool block size), times each candidate and
compares achieved HBM throughput against the ``analysis/roofline.py``
memory-bound model, then persists the winners as a small JSON table.  At
serve time a kernel call that does not pin its block sizes explicitly picks
them up from here — so a one-off offline sweep feeds the hot path without
any runtime tuning machinery.

Resolution order for the table path:
1. ``REPRO_AUTOTUNE_CONFIG`` env var (CI points this at the dry-run output),
2. ``tools/autotune_best.json`` in the repo (the checked-in sweep result).

Missing/invalid tables degrade to "no overrides" — the kernels keep their
built-in MXU-aligned defaults.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, Optional

#: knobs each kernel exposes to the tuner; anything else in a table entry is
#: reporting metadata (achieved_gbps etc.) and is ignored by dispatch
TUNABLE_KEYS = {
    "paged_decode_attention": ("block_k",),
    "paged_gmm": ("block_c", "block_f"),
    "paged_expert_ffn": ("block_c", "block_f"),
    "block_paged_decode_attention": (),     # block size == pool bs (layout)
    "mixed_block_paged_attention": (),
}

_cache: Optional[Dict[str, Dict[str, int]]] = None
_cache_key: Optional[str] = None


def config_path() -> Path:
    env = os.environ.get("REPRO_AUTOTUNE_CONFIG")
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[3] / "tools" / "autotune_best.json"


def load_best_configs(path: Optional[Path] = None,
                      refresh: bool = False) -> Dict[str, Dict[str, int]]:
    """Load (and memoize) the best-config table: kernel name -> {knob: int}.

    Accepts either the raw harness report (``{"kernels": {name: {"best":
    {...}}}}``) or a flat ``{name: {...}}`` mapping; only integer-valued
    tunable knobs survive filtering.  Returns {} when no table exists.
    """
    global _cache, _cache_key
    p = Path(path) if path is not None else config_path()
    key = str(p)
    if not refresh and _cache is not None and _cache_key == key:
        return _cache
    table: Dict[str, Dict[str, int]] = {}
    try:
        raw = json.loads(p.read_text())
        entries = raw.get("kernels", raw) if isinstance(raw, dict) else {}
        for name, entry in entries.items():
            if not isinstance(entry, dict):
                continue
            best = entry.get("best", entry)
            if not isinstance(best, dict):
                continue
            knobs = {k: int(v) for k, v in best.items()
                     if k in TUNABLE_KEYS.get(name, ()) and
                     isinstance(v, (int, float)) and int(v) > 0}
            if knobs:
                table[name] = knobs
    except (OSError, ValueError):
        table = {}
    _cache, _cache_key = table, key
    return table


def best_config(kernel: str) -> Dict[str, int]:
    """Tuned knob overrides for ``kernel`` ({} if none recorded)."""
    return dict(load_best_configs().get(kernel, {}))
