"""Minimal-cost scaling plans (paper §4.4, Fig. 6).

Given (old ElasticConfig | None, new ElasticConfig) and the model's logical
tensors, produce a per-shard plan with one of:

* ``ZERO_COPY`` — the device already holds the bytes; the new instance maps
  them via a reference handle (Ascend IPC in the paper; buffer aliasing via
  ``make_array_from_single_device_arrays`` here).
* ``P2P``       — copy from a device that holds identical bytes, over the
  fast fabric (HCCL isend/irecv there; ``jax.device_put`` here).
* ``DISK``      — load from storage (only at first boot, or in baselines).
* ``HOST``      — stream from the pinned-host cold-expert tier (DESIGN.md
  §10): a demoted expert that must move is read back over H2D instead of
  P2P — zero interconnect bytes for the cold set at scale events.
* ``INIT``      — fresh allocation of *state* (KV cache on new devices).
* ``FREE``      — release after switchover (scale-down / migrated experts).

The planner's objective (paper: "maximize zero-copy reuse, minimize the
relatively slower P2P transfers") falls out of the fixed-TP design: every
shard that exists anywhere is preferred zero-copy > p2p > disk.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.topology import ElasticConfig, TensorDesc, expert_owner


class Op(enum.Enum):
    ZERO_COPY = "zero_copy"
    P2P = "p2p"
    DISK = "disk"
    HOST = "host"
    INIT = "init"
    FREE = "free"


@dataclasses.dataclass(frozen=True)
class ShardKey:
    """Identifies shard *content* (not placement)."""
    tensor: str
    part: int        # tp_rank for 'tp', 0 for replicated/expert, dp_rank for kv


@dataclasses.dataclass(frozen=True)
class PlanStep:
    op: Op
    key: ShardKey
    nbytes: int
    dst: int                    # device id
    src: Optional[int] = None   # device id for P2P


@dataclasses.dataclass
class ScalingPlan:
    steps: List[PlanStep]
    old: Optional[ElasticConfig]
    new: ElasticConfig

    def bytes_by_op(self) -> Dict[Op, int]:
        out: Dict[Op, int] = defaultdict(int)
        for s in self.steps:
            out[s.op] += s.nbytes
        return dict(out)

    def count_by_op(self) -> Dict[Op, int]:
        out: Dict[Op, int] = defaultdict(int)
        for s in self.steps:
            out[s.op] += 1
        return dict(out)

    def p2p_in_bytes_per_device(self) -> Dict[int, int]:
        out: Dict[int, int] = defaultdict(int)
        for s in self.steps:
            if s.op == Op.P2P:
                out[s.dst] += s.nbytes
        return dict(out)

    def p2p_out_bytes_per_device(self) -> Dict[int, int]:
        out: Dict[int, int] = defaultdict(int)
        for s in self.steps:
            if s.op == Op.P2P and s.src is not None:
                out[s.src] += s.nbytes
        return dict(out)

    def disk_bytes_per_device(self) -> Dict[int, int]:
        out: Dict[int, int] = defaultdict(int)
        for s in self.steps:
            if s.op == Op.DISK:
                out[s.dst] += s.nbytes
        return dict(out)

    def host_bytes_per_device(self) -> Dict[int, int]:
        out: Dict[int, int] = defaultdict(int)
        for s in self.steps:
            if s.op == Op.HOST:
                out[s.dst] += s.nbytes
        return dict(out)


# ---------------------------------------------------------------- placement

def placement(tensors: Sequence[TensorDesc],
              cfg: ElasticConfig,
              expert_assignment: Optional[Dict[Tuple[int, int], int]] = None
              ) -> Dict[int, Dict[ShardKey, int]]:
    """device -> {shard_key -> nbytes} under ``cfg``.

    ``expert_assignment``: optional {(layer, expert) -> device} from the
    virtual page table (min-move placement); defaults to the contiguous
    ``expert_owner`` layout the dense-array execution path uses."""
    num_experts = 1 + max((t.expert for t in tensors if t.kind == "expert"),
                          default=0)
    out: Dict[int, Dict[ShardKey, int]] = {d: {} for d in cfg.devices}
    for t in tensors:
        if t.kind == "replicated":
            for d in cfg.devices:
                out[d][ShardKey(t.name, 0)] = t.nbytes
        elif t.kind == "tp":
            for d in cfg.devices:
                out[d][ShardKey(t.name, cfg.tp_rank(d))] = t.nbytes
        elif t.kind == "expert":
            if expert_assignment is not None:
                d = expert_assignment[(t.layer, t.expert)]
            else:
                d = expert_owner(t.expert, num_experts, cfg)
            out[d][ShardKey(t.name, 0)] = t.nbytes
        elif t.kind == "kv":
            for d in cfg.devices:
                out[d][ShardKey(t.name, cfg.dp_rank(d) * cfg.tp
                                + cfg.tp_rank(d))] = t.nbytes
        else:
            raise ValueError(t.kind)
    return out


# ------------------------------------------------------------------ planner

def plan_elastic(tensors: Sequence[TensorDesc],
                 old: Optional[ElasticConfig],
                 new: ElasticConfig,
                 expert_assignment_old=None,
                 expert_assignment_new=None,
                 host_resident: Optional[set] = None) -> ScalingPlan:
    """ElasticMoE's planner: zero-copy > P2P > disk; KV reused or INIT'd.

    Pass page-table assignments (min-move) for the paper-faithful expert
    remap; default is the contiguous layout of the dense execution path.

    ``host_resident``: (layer, expert) keys parked in the pinned-host cold
    tier (DESIGN.md §10).  A host-backed expert that must move streams H2D
    (``Op.HOST``) instead of P2P — matching ``HMM._migrate_pool_bank``,
    which always prefers the host copy: cold experts cost zero interconnect
    bytes and add no load on the source devices at scale events."""
    assert old is None or old.tp == new.tp, \
        "ElasticMoE scales via DP/EP only; TP is fixed (paper §4.1)"
    new_place = placement(tensors, new, expert_assignment_new)
    old_place = placement(tensors, old, expert_assignment_old) if old else {}
    kv_names = {t.name for t in tensors if t.kind == "kv"}
    # expert shard-content name -> (layer, expert), for the host-tier check
    host_names = set()
    if host_resident:
        host_names = {t.name for t in tensors if t.kind == "expert"
                      and (t.layer, t.expert) in host_resident}

    # content -> devices holding it under the old config
    holders: Dict[ShardKey, List[int]] = defaultdict(list)
    for d, shards in old_place.items():
        for key in shards:
            holders[key].append(d)

    steps: List[PlanStep] = []
    rr: Dict[ShardKey, int] = defaultdict(int)  # round-robin source pick
    for d, shards in new_place.items():
        for key, nbytes in shards.items():
            if d in old_place and key in old_place[d]:
                steps.append(PlanStep(Op.ZERO_COPY, key, nbytes, dst=d))
            elif key.tensor in kv_names:
                steps.append(PlanStep(Op.INIT, key, nbytes, dst=d))
            elif key.tensor in host_names:
                steps.append(PlanStep(Op.HOST, key, nbytes, dst=d))
            elif holders.get(key):
                srcs = holders[key]
                src = srcs[rr[key] % len(srcs)]
                rr[key] += 1
                steps.append(PlanStep(Op.P2P, key, nbytes, dst=d, src=src))
            else:
                steps.append(PlanStep(Op.DISK, key, nbytes, dst=d))

    # frees: anything held before but not needed after (applied post-switch)
    for d, shards in old_place.items():
        for key, nbytes in shards.items():
            if d not in new_place or key not in new_place[d]:
                steps.append(PlanStep(Op.FREE, key, nbytes, dst=d))
    return ScalingPlan(steps, old, new)


# ------------------------------------------------------- baseline strategies

def plan_cold_restart(tensors, old, new) -> ScalingPlan:
    """Tear down, then disk-load everything (downtime = full boot)."""
    steps: List[PlanStep] = []
    if old:
        for d, shards in placement(tensors, old).items():
            for key, nbytes in shards.items():
                steps.append(PlanStep(Op.FREE, key, nbytes, dst=d))
    kv_names = {t.name for t in tensors if t.kind == "kv"}
    for d, shards in placement(tensors, new).items():
        for key, nbytes in shards.items():
            op = Op.INIT if key.tensor in kv_names else Op.DISK
            steps.append(PlanStep(op, key, nbytes, dst=d))
    return ScalingPlan(steps, old, new)


def plan_extravagant(tensors, old, new) -> ScalingPlan:
    """New instance on *fresh* devices, old keeps running until ready.

    ``new.devices`` must be disjoint from ``old.devices``."""
    assert old is None or not set(old.devices) & set(new.devices)
    kv_names = {t.name for t in tensors if t.kind == "kv"}
    steps: List[PlanStep] = []
    for d, shards in placement(tensors, new).items():
        for key, nbytes in shards.items():
            op = Op.INIT if key.tensor in kv_names else Op.DISK
            steps.append(PlanStep(op, key, nbytes, dst=d))
    if old:
        for d, shards in placement(tensors, old).items():
            for key, nbytes in shards.items():
                steps.append(PlanStep(Op.FREE, key, nbytes, dst=d))
    return ScalingPlan(steps, old, new)


def plan_colocated(tensors, old, new) -> ScalingPlan:
    """New instance disk-loads onto (a superset of) the same devices while
    the old copy stays resident -> double weights on shared devices."""
    kv_names = {t.name for t in tensors if t.kind == "kv"}
    steps: List[PlanStep] = []
    for d, shards in placement(tensors, new).items():
        for key, nbytes in shards.items():
            op = Op.INIT if key.tensor in kv_names else Op.DISK
            steps.append(PlanStep(op, key, nbytes, dst=d))
    if old:
        for d, shards in placement(tensors, old).items():
            for key, nbytes in shards.items():
                steps.append(PlanStep(Op.FREE, key, nbytes, dst=d))
    return ScalingPlan(steps, old, new)


def plan_horizontal(tensors, old, new_replica: ElasticConfig) -> ScalingPlan:
    """Add an independent full replica on fresh devices (old untouched)."""
    assert old is None or not set(old.devices) & set(new_replica.devices)
    kv_names = {t.name for t in tensors if t.kind == "kv"}
    steps = []
    for d, shards in placement(tensors, new_replica).items():
        for key, nbytes in shards.items():
            op = Op.INIT if key.tensor in kv_names else Op.DISK
            steps.append(PlanStep(op, key, nbytes, dst=d))
    return ScalingPlan(steps, old, new_replica)


def plan_unpark(tensors, new: ElasticConfig) -> ScalingPlan:
    """Whole-model cold start from the pinned-host tier (scale-to-zero,
    DESIGN.md §12): every weight shard streams H2D (``Op.HOST`` — priced at
    ``hw.h2d_bw``, one parallel lane per destination device), KV state is a
    fresh ``INIT``.  No disk, no P2P: a parked model holds its complete
    snapshot pinned host-side, so unpark is bounded by the H2D bus, not
    storage — the cold-start limit case of the elastic planner."""
    kv_names = {t.name for t in tensors if t.kind == "kv"}
    steps: List[PlanStep] = []
    for d, shards in placement(tensors, new).items():
        for key, nbytes in shards.items():
            op = Op.INIT if key.tensor in kv_names else Op.HOST
            steps.append(PlanStep(op, key, nbytes, dst=d))
    return ScalingPlan(steps, None, new)


STRATEGIES = {
    "elastic": plan_elastic,
    "cold_restart": plan_cold_restart,
    "extravagant": plan_extravagant,
    "colocated": plan_colocated,
    "horizontal": plan_horizontal,
}


def plan_elastic_paged(tensors, old, new, page_table,
                       first_k_dense: int = 0) -> ScalingPlan:
    """Paper-faithful elastic plan using the virtual page table's min-move
    expert placement.  Stages the remap on ``page_table`` (caller commits or
    aborts after executing the plan).  Experts the table holds in its
    pinned-host tier plan as ``Op.HOST`` when they must move (zero P2P for
    the cold set — the rebalancer's scale-event payoff)."""
    host = {(l + first_k_dense, e) for (l, e) in page_table.host}
    page_table.stage_remap(new)
    a_old, a_new = {}, {}
    for (l, e), ref in page_table.staged.items():
        a_new[(l + first_k_dense, e)] = ref.device
        # an expert kept in place via ANY resident copy (primary or
        # replica) was already on its staged device — report that device
        # as the old home so the planner prices it zero-copy, exactly as
        # HMM._migrate_pool_bank accounts it
        resident = {page_table.active[(l, e)]}
        resident.update(page_table.replicas.get((l, e), ()))
        a_old[(l + first_k_dense, e)] = (
            ref.device if ref in resident
            else page_table.active[(l, e)].device)
    return plan_elastic(tensors, old, new,
                        expert_assignment_old=a_old,
                        expert_assignment_new=a_new,
                        host_resident=host)


def plan_elastic_min_move(tensors, old: ElasticConfig, new: ElasticConfig,
                          mcfg) -> ScalingPlan:
    """``plan_elastic_paged`` from a *fresh* contiguous placement at ``old``
    — the shared recipe for cost projections (driver/simulator) and
    benchmarks that have no live page table to consult: assume the server
    booted at ``old`` (contiguous ``initial_place``) and cost the min-move
    remap to ``new``."""
    from repro.core.expert_pages import ExpertPageTable
    table = ExpertPageTable(mcfg.num_layers - mcfg.first_k_dense,
                            mcfg.num_experts)
    table.initial_place(old)
    return plan_elastic_paged(tensors, old, new, table,
                              first_k_dense=mcfg.first_k_dense)
