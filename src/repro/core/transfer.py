"""Background transfer engine — the async half of the HMM (DESIGN.md §3).

``HMM.begin_scale`` emits its per-tensor / per-page staging work list as
independent :class:`TransferOp` s; with ``staging="overlap"`` they execute on
this bounded thread pool while the serving thread keeps running decode ticks.
Staging only *reads* immutable live weights (weights never mutate during
serving; the KV cache is untouched until commit), so ticks concurrent with
in-flight ops are safe by construction — the paper's "scaling steps proceed
concurrently with serving" (§4.4–§4.5) as real off-thread ``jax.device_put``
traffic instead of tick-interleaved slices.

The op list is a trivially parallel graph: every op stages one parameter
tensor (or pool bank / index array) and the only join point is the final
tree assembly, performed on the serving thread by ``HMM.poll_staging`` once
every op has finished.  ``TransferSession.cancel`` is the abort barrier:
pending ops never start, running ops are joined — after it returns no worker
can touch HMM state, so ``ExpertPageTable.abort`` may safely unwind.

JAX note: the CPU/TPU PJRT clients are thread-safe; compiled decode steps on
the serving thread only donate the KV cache, never params, so concurrent
reads of param shards from worker threads race with nothing.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import wait as _futures_wait
from typing import Any, Callable, List, Optional

from repro import obs


@dataclasses.dataclass
class TransferOp:
    """One independent unit of staging work (one tensor, pool bank, or index
    array).  ``fn`` must be self-contained: it reads only immutable inputs
    captured at creation time and returns the staged result."""
    index: int
    label: str
    fn: Callable[[], Any]
    state: str = "pending"      # pending | running | done | failed | cancelled
    result: Any = None
    error: Optional[BaseException] = None
    seconds: float = 0.0        # execution time of fn (0 if never ran)
    t_done: float = 0.0         # perf_counter() when fn returned


class TransferSession:
    """A submitted batch of ops, polled/joined/cancelled as a unit."""

    def __init__(self, ops: List[TransferOp]):
        self.ops = ops
        self.futures: List[Future] = []
        self.cancelled = threading.Event()

    def finished(self) -> bool:
        """Non-blocking: True once every op has run (or been cancelled)."""
        return all(f.done() for f in self.futures)

    def remaining(self) -> int:
        return sum(1 for f in self.futures if not f.done())

    def join(self, timeout: Optional[float] = None) -> bool:
        """Block until every op has finished; returns ``finished()``."""
        _futures_wait(self.futures, timeout=timeout)
        return self.finished()

    def cancel(self) -> None:
        """Cancel-or-join barrier: ops that have not started never will;
        ops already running are joined.  On return no worker thread holds a
        reference into the caller's state."""
        self.cancelled.set()
        for f in self.futures:
            f.cancel()
        _futures_wait(self.futures)
        for op, f in zip(self.ops, self.futures):
            if f.cancelled():
                op.state = "cancelled"

    def failed_ops(self) -> List[TransferOp]:
        return [op for op in self.ops if op.state == "failed"]

    @property
    def op_seconds(self) -> float:
        """Σ per-op execution time — the serial-equivalent transfer work.
        Compared against the session's wall-clock this is the overlap
        efficiency reported by ``metrics.summarize``.

        Only ops that actually *executed* count: a cancelled op did zero
        transfer work, so including it (even at ``seconds == 0``) would be
        wrong twice over — it can't dilute the numerator, and if a stray
        timestamp ever landed on a skipped op it must not inflate it
        either.  The state filter pins that contract structurally rather
        than relying on cancelled ops never being timed."""
        return sum(op.seconds for op in self.ops
                   if op.state in ("done", "failed"))

    @property
    def last_done_t(self) -> float:
        return max((op.t_done for op in self.ops if op.t_done), default=0.0)


class TransferEngine:
    """Bounded worker pool issuing staging ops off the serving thread.

    One engine per HMM, persistent across scaling sessions (threads are
    reused, not churned per scale event).  ``max_workers`` bounds HBM/link
    contention with the serving hot path — the knob the cost model's
    ``overlap_contention`` constant projects to paper scale."""

    def __init__(self, max_workers: int = 4):
        self.max_workers = max(1, int(max_workers))
        self._pool = ThreadPoolExecutor(max_workers=self.max_workers,
                                        thread_name_prefix="hmm-transfer")

    def submit(self, ops: List[TransferOp]) -> TransferSession:
        session = TransferSession(ops)
        session.futures = [self._pool.submit(self._run, session, op)
                           for op in ops]
        return session

    @staticmethod
    def _run(session: TransferSession, op: TransferOp) -> None:
        if session.cancelled.is_set():
            # Skipped entirely: no span, no timing.  Emitting a complete()
            # here (state "cancelled", seconds≈0) would pollute the trace
            # timeline and the op_seconds / overlap-efficiency denominators
            # with ops that did zero transfer work — the span below is
            # reserved for ops that actually executed fn().
            op.state = "cancelled"
            return
        op.state = "running"
        t0 = time.perf_counter()
        try:
            op.result = op.fn()
            op.state = "done"
        except BaseException as e:  # noqa: BLE001 — surfaced via failed_ops
            op.error = e
            op.state = "failed"
        finally:
            op.t_done = time.perf_counter()
            op.seconds = op.t_done - t0
            # span lands on the worker thread's lane (obs captures the
            # "hmm-transfer-*" thread name lazily); timestamps are the
            # already-measured perf_counter interval, not re-clocked
            obs.get_tracer().complete(op.label, t0, op.t_done,
                                      cat="transfer",
                                      args={"state": op.state,
                                            "index": op.index})

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)
