"""ElasticServer — ties the Coordinator, HMM and IMM to the serving engine.

The serving lifecycle (paper §5):
* ``boot(cfg)`` — HMM loads weights once, IMM compiles + attaches, engine
  starts taking requests.
* ``scale_to(cfg')`` — concurrent scaling: HMM stages the minimal-cost
  reconfiguration (zero-copy + P2P + expert-page remap) and the IMM prepares
  the target instance, **while the active instance keeps serving**
  (tick() remains callable throughout).  ``switchover()`` retargets traffic:
  surviving decode slots continue on the *same* KV cache rows — zero
  downtime, zero token divergence (asserted in tests).
* scale-down (paged KV, ``scaledown="migrate"``, default): live sequences
  in doomed slots MIGRATE — their KV blocks device-copy onto survivor
  partitions in the background (MIGRATING phase) and devices release as
  soon as the copies land, instead of waiting out the longest in-flight
  sequence.  ``scaledown="drain"`` (and the dense layout) keeps the
  legacy drain of evicted slots.

For closed-loop operation, ``ElasticServer`` implements the
``ServingBackend`` protocol (serving/driver.py): ``start_scale`` returns an
``EngineScalingTask`` whose ``advance`` is a non-blocking poll.  With the
default ``staging="serial"`` each poll performs one per-tensor HMM reshard
(tick-interleaved staging); with ``staging="overlap"`` the whole work list
runs on the HMM's background ``TransferEngine`` while real decode ticks
proceed concurrently and the IMM AOT compile overlaps the transfer window
(DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import math
import time
from functools import partial
from typing import Dict, List, Optional

import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.coordinator import LoadEstimator, ScalingPolicy
from repro.core.hmm import HMM, TransferStats
from repro.core.imm import IMM
from repro.core.topology import ElasticConfig
from repro.serving.driver import ScalePhase, admission_during_scale
from repro.serving.engine import InferenceEngine
from repro.serving.rebalance import RebalancePolicy
from repro.serving.workload import Request


@dataclasses.dataclass
class ScaleEvent:
    t: float
    src: str
    dst: str
    stats: TransferStats
    compile_hit: bool
    stage_s: float
    switch_s: float
    # serve-loop time blocked on staging/compile work (the decode-stall
    # during scaling): ~= stage_s on the blocking/serial paths, near-zero
    # with the background TransferEngine (staging="overlap")
    stall_s: float = 0.0
    staging: str = "serial"
    # staging wall-clock frozen at record time: ``stats`` aliases
    # ``hmm.last_stats``, whose wall_s later grows by the commit/KV-grow
    # time at switchover — overlap-efficiency ratios must use this snapshot
    stage_wall_s: float = 0.0
    # zero-drain scale-down: live KV blocks device-copied off doomed
    # partitions during the MIGRATING phase (0 for scale-up / drain mode)
    migrated_blocks: int = 0
    migration_bytes: int = 0


class EngineScalingTask:
    """Resumable scale transition over the real JAX engine (driver.ScalingTask).

    ``advance`` is a non-blocking completion poll; what runs inside it
    depends on the HMM's staging mode:

    * ``staging="serial"`` — one per-tensor HMM reshard per ``advance``,
      then a COMPILING advance (IMM pre-init; LRU hit makes it ~free),
    * ``staging="overlap"`` — the transfers already run on the background
      ``TransferEngine`` (submitted at ``start_scale``); the first
      ``advance`` runs the IMM AOT compile on the serve thread *while* the
      transfers proceed (STAGING ∥ COMPILING, DESIGN.md §3) and every later
      ``advance`` just polls completion.

    Scale-down continues into MIGRATING (``scaledown="migrate"``, paged
    KV: live sequences' blocks device-copy onto survivor partitions as
    per-block ops on the HMM's TransferEngine while decode ticks proceed —
    the doomed devices release as soon as the copies land) or DRAINING
    (``scaledown="drain"`` / dense KV: evicted slots run to completion).
    Either way the phases continue -> COMMITTING (switchover, a barrier
    that joins any in-flight ops) -> DONE, and the engine's ``tick()`` is
    legal — and expected — between every ``advance`` call.
    """

    def __init__(self, server: "ElasticServer", target: ElasticConfig):
        # scale events take priority over background rebalancing: an
        # in-flight rebalance is aborted (its staged pages freed) before
        # the remap is staged — the page table forbids both at once
        server._preempt_rebalance()
        self.server = server
        self.target = target
        self.phase = ScalePhase.STAGING
        self.staging_mode = server.hmm.staging_mode
        self.increments_total = server.hmm.begin_scale(target) + 1  # +compile
        self.increments_done = 0
        self.stats: TransferStats = server.hmm._stage_stats
        # staging-only snapshot, frozen when STAGING completes (``stats``
        # keeps accumulating: commit merges the KV handover bytes into it)
        self.stage_stats: Optional[TransferStats] = None
        self.event: Optional[ScaleEvent] = None
        self.stall_s = 0.0      # serve-loop time spent inside advance()
        self._compile_hit: Optional[bool] = None
        self._down = target.ndev < server.engine.cfg.ndev
        self._keep = target.dp * server.engine.batch_per_replica
        self._migrate = self._down and server.scaledown_mode == "migrate"
        # in-flight KV migrations: (MigrationJob, TransferSession)
        self._mig_inflight: List = []
        self._mig_warm = False
        self.migrated_blocks = 0
        self.migration_bytes = 0
        if self._down:
            # stop admitting into doomed slots right away so the drain
            # overlaps the staging increments instead of following them
            server.engine.admit_limit = self._keep
        server._active_task = self

    @property
    def phase(self) -> ScalePhase:
        return self._phase

    @phase.setter
    def phase(self, new: ScalePhase) -> None:
        """Every phase transition emits one ``scale.<PHASE>`` span on the
        "scale" lane — the per-ScalePhase timeline of the trace layer.
        Captures ABORTED unwinds too, since those also assign here."""
        tr = obs.get_tracer()
        now = tr.now()
        old = getattr(self, "_phase", None)
        self._phase = new
        if old is not None and old is not new:
            tr.complete(f"scale.{old.name}", self._phase_t0, now,
                        cat="scale", tid="scale",
                        args={"target": self.target.describe(),
                              "next": new.name})
        self._phase_t0 = now

    @property
    def done(self) -> bool:
        return self.phase.terminal

    @property
    def overlap_efficiency(self) -> Optional[float]:
        """Σ transfer-op time / staging wall-clock (>1 = real overlap);
        None until staging completed (driver event log, metrics)."""
        st = self.stage_stats
        if st is None or st.wall_s <= 0 or st.op_s <= 0:
            return None
        return st.op_s / st.wall_s

    def _finish_staging(self):
        """STAGING complete: freeze the staging snapshot, record the event
        (IMM compile is a hit by now on the overlapped path) and move on."""
        self.stage_stats = dataclasses.replace(self.stats)
        self.event = self.server._record_stage(self.target,
                                               self.stats.wall_s)
        if self._compile_hit is not None:
            self.event.compile_hit = self._compile_hit
        self.phase = self._scaledown_phase()

    def _scaledown_phase(self) -> ScalePhase:
        if not self._down:
            return ScalePhase.COMMITTING
        return (ScalePhase.MIGRATING if self._migrate
                else ScalePhase.DRAINING)

    def _unwind_failed(self):
        """A staging/compile step raised: release every piece of task state
        so the server keeps serving on the still-active config (the HMM
        session itself is aborted — poll_staging already did for overlap
        failures; abort() is idempotent either way)."""
        self.server.hmm.abort()
        if self._down:
            self.server.engine.admit_limit = None
        self.server._staged_cfg = None
        self.server._active_task = None
        self.phase = ScalePhase.ABORTED

    def advance(self, now: float) -> ScalePhase:
        ph = self.phase
        if ph is ScalePhase.STAGING:
            t0 = time.perf_counter()
            try:
                if self.staging_mode == "overlap":
                    if self._compile_hit is None:
                        # the AOT compile runs on the serve thread while the
                        # TransferEngine moves bytes in the background — the
                        # overlapped pipeline's COMPILING ∥ STAGING
                        self._compile_hit = self.server.imm.has(self.target)
                        self.server.imm.preinitialize(self.target)
                    if self.server.hmm.poll_staging():
                        self.increments_done = self.increments_total
                        self._finish_staging()
                    else:
                        self.increments_done = (
                            self.increments_total - 1
                            - self.server.hmm.staging_remaining)
                else:
                    more = self.server.hmm.stage_increment()
                    self.increments_done += 1
                    if not more:
                        self.stage_stats = dataclasses.replace(self.stats)
                        self.phase = ScalePhase.COMPILING
            except BaseException:
                self._unwind_failed()
                raise
            self.stall_s += time.perf_counter() - t0
        elif ph is ScalePhase.COMPILING:
            t0 = time.perf_counter()
            self.increments_done += 1
            # staging time = the HMM's tracked staging work, NOT wall time
            # since task creation (which would count the decode ticks that
            # ran between increments); _record_stage adds the compile time
            try:
                self.event = self.server._record_stage(
                    self.target, self.stats.wall_s)
            except BaseException:
                self._unwind_failed()
                raise
            self.phase = self._scaledown_phase()
            self.stall_s += time.perf_counter() - t0
        elif ph is ScalePhase.MIGRATING:
            t0 = time.perf_counter()
            try:
                if self._advance_migration():
                    self.phase = ScalePhase.COMMITTING
            except BaseException:
                self._cancel_migrations()
                self._unwind_failed()
                raise
            self.stall_s += time.perf_counter() - t0
        elif ph is ScalePhase.DRAINING:
            if self.server.engine.drained(self._keep):
                self.phase = ScalePhase.COMMITTING
        elif ph is ScalePhase.COMMITTING:
            self.server.switchover()
            self.phase = ScalePhase.DONE
            self.server._active_task = None
        if self.event is not None:
            self.event.stall_s = self.stall_s
        return self.phase

    def _advance_migration(self) -> bool:
        """One MIGRATING poll: harvest finished per-block copy sessions
        (cut the slots over), submit new component moves, and report
        whether every doomed partition is evacuated.  The copies run as
        TransferOps on the HMM's background TransferEngine, so decode
        ticks between polls overlap them exactly like overlapped staging
        (DESIGN.md §3 — migration is asynchronous in every staging mode)."""
        eng = self.server.engine
        for job, sess in list(self._mig_inflight):
            if not sess.finished():
                continue
            self._mig_inflight.remove((job, sess))
            failed = sess.failed_ops()
            if failed:
                self._cancel_one(job)
                raise RuntimeError(
                    f"KV migration copy op {failed[0].label!r} failed "
                    f"({len(failed)} op(s)); scale-down aborted"
                ) from failed[0].error
            eng.finish_migration(job)
            self.migrated_blocks += job.ticket.num_blocks
            self.migration_bytes += (job.ticket.num_blocks
                                     * eng.block_nbytes())
            if self.event is not None:
                # per-harvest, not only at completion: components already
                # committed are permanent even if a later abort lands
                self.event.migrated_blocks = self.migrated_blocks
                self.event.migration_bytes = self.migration_bytes
        while True:
            job = eng.plan_migration()
            if job is None:
                break
            if not self._mig_warm:
                # compile the block-copy executable on the serve thread so
                # no worker ever compiles concurrently with serving
                eng.prewarm_block_copy()
                self._mig_warm = True
            from repro.core.transfer import TransferOp
            ops = [TransferOp(index=i, label=f"kvmig:{s}->{d}",
                              fn=partial(eng.copy_block, s, d))
                   for i, (s, d) in enumerate(job.ticket.pairs)]
            sess = self.server.hmm.transfer_engine().submit(ops)
            self._mig_inflight.append((job, sess))
        if self._mig_inflight:
            # bounded yield to the copy workers — the same GIL courtesy as
            # HMM.poll_staging: with every doomed sequence paused and the
            # survivors idle, the serve loop degenerates into a pure Python
            # busy-loop that would otherwise starve the copies
            self._mig_inflight[0][1].join(timeout=0.002)
        return not self._mig_inflight and not eng.doomed_active_slots()

    def _cancel_one(self, job) -> None:
        self.server.engine.cancel_migration(job)

    def _cancel_migrations(self):
        """Abort barrier for in-flight migrations: cancel-or-join every
        copy session FIRST (no worker may touch the cache afterwards),
        then unwind tickets/slots — tables were never flipped, so the
        paused sequences simply resume where they were."""
        for job, sess in self._mig_inflight:
            sess.cancel()
            self._cancel_one(job)
        self._mig_inflight = []

    def abort(self):
        assert self.phase in (ScalePhase.STAGING, ScalePhase.COMPILING,
                              ScalePhase.MIGRATING, ScalePhase.DRAINING)
        self._cancel_migrations()
        self.server.hmm.abort()
        if self._down:
            # re-open the slots we stopped admitting into in __init__
            self.server.engine.admit_limit = None
        self.server._staged_cfg = None
        self.server._active_task = None
        self.phase = ScalePhase.ABORTED


class UnparkTask:
    """Resumable cold start from the pinned-host tier (driver.ScalingTask).

    The scale-from-zero twin of ``EngineScalingTask``: ``begin_unpark``
    opened an HMM staging session that streams the whole parked snapshot
    back to devices.  With ``staging="overlap"`` the first ``advance``
    runs the IMM AOT compile on the calling thread *while* the
    ``TransferEngine`` moves the snapshot (the same STAGING ∥ COMPILING
    discipline as a scale event — the H2D window hides the compile);
    serial mode streams one unit per ``advance`` then compiles.
    COMMITTING allocates a fresh KV cache/block pool and binds the
    engine; the first post-commit ``tick()`` serves.  There is no
    MIGRATING/DRAINING arm — a parked model has no live sequences by
    construction.  Every phase transition emits an ``unpark.<PHASE>``
    span on the scale lane, so park→unpark shows up on the same timeline
    as ordinary scale events.
    """

    def __init__(self, server: "ElasticServer", target: ElasticConfig):
        assert server.hmm.parked, "unpark requires a parked server"
        self.server = server
        self.target = target
        self.phase = ScalePhase.STAGING
        self.staging_mode = server.hmm.staging_mode
        self.increments_total = server.hmm.begin_unpark(target) + 1
        self.increments_done = 0
        self.stats: TransferStats = server.hmm._stage_stats
        self.stage_stats: Optional[TransferStats] = None
        self.event: Optional[ScaleEvent] = None
        self.stall_s = 0.0
        self._compile_hit: Optional[bool] = None
        server._active_task = self

    @property
    def phase(self) -> ScalePhase:
        return self._phase

    @phase.setter
    def phase(self, new: ScalePhase) -> None:
        tr = obs.get_tracer()
        now = tr.now()
        old = getattr(self, "_phase", None)
        self._phase = new
        if old is not None and old is not new:
            tr.complete(f"unpark.{old.name}", self._phase_t0, now,
                        cat="scale", tid="scale",
                        args={"target": self.target.describe(),
                              "next": new.name})
        self._phase_t0 = now

    @property
    def done(self) -> bool:
        return self.phase.terminal

    def _unwind_failed(self):
        """A staging step raised: abort the HMM session.  The parked
        snapshot itself survives (``abort`` leaves ``_parked`` intact), so
        a later ``start_unpark`` can retry the cold start."""
        self.server.hmm.abort()
        self.server._active_task = None
        self.phase = ScalePhase.ABORTED

    def advance(self, now: float) -> ScalePhase:
        ph = self.phase
        if ph is ScalePhase.STAGING:
            t0 = time.perf_counter()
            try:
                if self.staging_mode == "overlap":
                    if self._compile_hit is None:
                        # AOT compile on the calling thread while the
                        # TransferEngine streams the snapshot; the explicit
                        # span is the trace-level witness that the unpark
                        # H2D window hid the compile
                        tr = obs.get_tracer()
                        c0 = tr.now()
                        self._compile_hit = self.server.imm.has(self.target)
                        self.server.imm.preinitialize(self.target)
                        tr.complete("unpark.compile", c0, tr.now(),
                                    cat="scale", tid="scale",
                                    args={"hit": self._compile_hit,
                                          "target": self.target.describe()})
                    if self.server.hmm.poll_staging():
                        self.increments_done = self.increments_total
                        self.stage_stats = dataclasses.replace(self.stats)
                        self.phase = ScalePhase.COMMITTING
                    else:
                        self.increments_done = (
                            self.increments_total - 1
                            - self.server.hmm.staging_remaining)
                else:
                    more = self.server.hmm.stage_increment()
                    self.increments_done += 1
                    if not more:
                        self.stage_stats = dataclasses.replace(self.stats)
                        self.phase = ScalePhase.COMPILING
            except BaseException:
                self._unwind_failed()
                raise
            self.stall_s += time.perf_counter() - t0
        elif ph is ScalePhase.COMPILING:
            t0 = time.perf_counter()
            self.increments_done += 1
            try:
                self._compile_hit = self.server.imm.has(self.target)
                self.server.imm.preinitialize(self.target)
            except BaseException:
                self._unwind_failed()
                raise
            self.phase = ScalePhase.COMMITTING
            self.stall_s += time.perf_counter() - t0
        elif ph is ScalePhase.COMMITTING:
            self.server._unpark_switchover(self)
            self.phase = ScalePhase.DONE
            self.server._active_task = None
        return self.phase

    def abort(self):
        assert self.phase in (ScalePhase.STAGING, ScalePhase.COMPILING)
        self._unwind_failed()


@dataclasses.dataclass
class RebalanceEvent:
    """One completed (or aborted) rebalance pass (DESIGN.md §10)."""
    t: float
    actions: int
    replicated: int = 0
    demoted: int = 0
    dropped: int = 0
    promoted: int = 0
    stats: Optional[TransferStats] = None
    aborted: bool = False


class RebalanceTask:
    """Resumable background expert rebalance (DESIGN.md §10).

    Same two-phase discipline as ``EngineScalingTask`` but much smaller:
    STAGING (replica/demotion rows stream on the HMM's background
    ``TransferEngine`` while tick() keeps serving) -> COMMITTING (pool
    banks gain the replica rows, the pooled index tables are swapped in
    place, the host tier absorbs demoted rows) -> DONE.  ``abort()``
    at any point before commit frees every staged page and leaves the
    serving layout untouched — tick() is legal between every ``advance``.

    Unlike a scale event a rebalance never pauses admission: the serving
    assignment only changes at commit, and commit is atomic with respect
    to the single-threaded serve loop."""

    def __init__(self, server: "ElasticServer", actions: List,
                 load=None):
        self.server = server
        self.actions = list(actions)
        self.event: Optional[RebalanceEvent] = None
        self.stats: Optional[TransferStats] = None
        self._load = load
        self.phase = ScalePhase.STAGING
        try:
            self.ops_total = server.hmm.begin_rebalance(actions, load=load)
        except BaseException:
            self.phase = ScalePhase.ABORTED
            raise
        server._rebalance_task = self

    @property
    def phase(self) -> ScalePhase:
        return self._phase

    @phase.setter
    def phase(self, new: ScalePhase) -> None:
        """Phase transitions emit ``rebalance.<PHASE>`` spans on their own
        trace lane, parallel to the scale lane's ``scale.<PHASE>``."""
        tr = obs.get_tracer()
        now = tr.now()
        old = getattr(self, "_phase", None)
        self._phase = new
        if old is not None and old is not new:
            tr.complete(f"rebalance.{old.name}", self._phase_t0, now,
                        cat="rebalance", tid="rebalance",
                        args={"actions": len(self.actions),
                              "next": new.name})
        self._phase_t0 = now

    @property
    def done(self) -> bool:
        return self.phase.terminal

    def advance(self, now: float) -> ScalePhase:
        ph = self.phase
        if ph is ScalePhase.STAGING:
            try:
                if self.server.hmm.poll_rebalance():
                    self.phase = ScalePhase.COMMITTING
            except BaseException:
                # poll_rebalance already aborted the HMM session on a
                # failed op; just release the task slot
                self.server._rebalance_task = None
                self.phase = ScalePhase.ABORTED
                raise
        elif ph is ScalePhase.COMMITTING:
            try:
                self.stats = self.server.hmm.commit_rebalance(
                    load=self._load)
            except BaseException:
                self.server._rebalance_task = None
                self.phase = ScalePhase.ABORTED
                raise
            # the histogram described the OLD placement — restart it so
            # the next policy pass sees post-rebalance traffic only
            # (same staleness fix as scale-event switchover)
            self.server.engine.reset_routing_stats()
            self.event = self._record(now)
            self.server._rebalance_task = None
            self.phase = ScalePhase.DONE
        return self.phase

    def _record(self, now: float) -> RebalanceEvent:
        kinds = [a[0] for a in self.actions]
        ev = RebalanceEvent(t=now, actions=len(self.actions),
                            replicated=kinds.count("replicate"),
                            demoted=kinds.count("demote"),
                            dropped=kinds.count("drop_replica"),
                            promoted=kinds.count("promote"),
                            stats=self.stats)
        self.server.rebalance_events.append(ev)
        return ev

    def abort(self):
        assert self.phase in (ScalePhase.STAGING, ScalePhase.COMMITTING)
        self.server.hmm.abort_rebalance()
        self.server._rebalance_task = None
        self.server.rebalance_events.append(
            RebalanceEvent(t=time.time(), actions=len(self.actions),
                           aborted=True))
        self.phase = ScalePhase.ABORTED


class ElasticServer:
    def __init__(self, mcfg: ModelConfig, *, tp: int, batch_per_replica: int,
                 max_len: int, prefill_buckets=(64,), all_devices=None,
                 policy: Optional[ScalingPolicy] = None, seed: int = 0,
                 kv_mode: str = "dense", kv_block_size: int = 16,
                 kv_blocks_per_replica: Optional[int] = None,
                 expert_mode: str = "dense",
                 expert_pool_pages: Optional[int] = None,
                 staging: str = "serial", transfer_workers: int = 4,
                 scaledown: str = "migrate",
                 prefill_chunk: int = 0,
                 prefill_budget: Optional[int] = None,
                 routing_sample_every: int = 0,
                 rebalance: Optional[RebalancePolicy] = None,
                 expert_slot_slack: Optional[int] = None,
                 expert_host_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None,
                 expert_dtype: Optional[str] = None,
                 imm_cache=None):
        self.mcfg = mcfg
        self.kv_mode = kv_mode
        # quantized storage (ISSUE 9): 'int8' stores the paged KV pool /
        # pooled expert pages as int8 with f32 scale sidecars (HMM owns the
        # layout; kernels fuse the dequant).  The driver's cost projections
        # adopt these through the ``kv_dtype``/``expert_dtype`` attributes —
        # halved KV-migration and expert P2P/H2D bytes show up in plan_cost.
        self.kv_dtype = kv_dtype
        self.expert_dtype = expert_dtype
        # continuous batching: prefill_chunk > 0 splits prompt processing
        # into fixed-size token chunks interleaved with decode ticks under
        # a per-tick budget (serving/scheduler.py); 0 keeps the monolithic
        # prefill-at-admission path
        self.prefill_chunk = prefill_chunk
        # scale-down policy: 'migrate' (paged KV only — live sequences'
        # blocks device-copy onto survivor partitions, devices release in
        # seconds) or 'drain' (evicted slots run to completion; latency
        # bounded by the longest in-flight sequence).  The dense layout has
        # no block indirection to rewrite, so it always drains.
        assert scaledown in ("migrate", "drain")
        self.scaledown_mode = scaledown if kv_mode == "paged" else "drain"
        # 'pooled': expert weights live as page pools + tables, so an EP
        # scale event migrates only the min-move page set and commit only
        # rewrites tables (DESIGN.md §2); the driver's cost projections
        # adopt this through the ``expert_mode`` attribute
        self.expert_mode = expert_mode
        # 'overlap': staging transfers run on the HMM's background
        # TransferEngine while tick() keeps serving; the driver's cost
        # projections adopt this through the ``staging_mode`` attribute
        self.staging_mode = staging
        # skew-aware rebalancing (DESIGN.md §10): a RebalancePolicy turns
        # routing histograms into replicate/demote actions that tick()
        # drives through a background RebalanceTask.  Replication needs
        # spare compiled table width, so enabling it defaults the slot
        # slack to 1 (each rank can serve one extra expert copy); 0 keeps
        # the legacy byte-identical table shapes.
        self.rebalance_policy = rebalance
        if expert_slot_slack is None:
            expert_slot_slack = 1 if rebalance is not None else 0
        self.hmm = HMM(mcfg, tp, batch_per_replica=batch_per_replica,
                       max_len=max_len, all_devices=all_devices, seed=seed,
                       kv_mode=kv_mode, kv_block_size=kv_block_size,
                       kv_blocks_per_replica=kv_blocks_per_replica,
                       expert_mode=expert_mode,
                       expert_pool_pages=expert_pool_pages,
                       staging=staging, transfer_workers=transfer_workers,
                       expert_slot_slack=expert_slot_slack,
                       expert_host_pages=expert_host_pages,
                       kv_dtype=kv_dtype, expert_dtype=expert_dtype)
        # routing telemetry: every Nth decode tick runs the counts-emitting
        # executable and accumulates per-(layer, expert) histograms
        # (models/moe.py; exposed via routing_stats()).  0 disables — no
        # extra executable is compiled, the decode path is untouched.
        self.routing_sample_every = routing_sample_every
        # ``imm_cache``: an OrderedDict shared across a fleet's servers so
        # the standby-executable LRU is bounded once globally (IMM keys
        # carry the full model identity, so entries can never collide)
        self.imm = IMM(mcfg, self.hmm, batch_per_replica=batch_per_replica,
                       max_len=max_len, prefill_buckets=prefill_buckets,
                       prefill_chunk=prefill_chunk,
                       collect_routing=routing_sample_every > 0,
                       shared_cache=imm_cache)
        self.engine = InferenceEngine(mcfg, batch_per_replica=batch_per_replica,
                                      max_len=max_len,
                                      prefill_bucket=min(prefill_buckets),
                                      prefill_chunk=prefill_chunk,
                                      prefill_budget=prefill_budget,
                                      routing_sample_every=routing_sample_every)
        self.estimator = LoadEstimator(policy) if policy else None
        self.queue: List[Request] = []
        self.requests: Dict[int, Request] = {}
        self.events: List[ScaleEvent] = []
        self.rebalance_events: List[RebalanceEvent] = []
        self._staged_cfg: Optional[ElasticConfig] = None
        self._active_task: Optional[EngineScalingTask] = None
        self._rebalance_task: Optional[RebalanceTask] = None

    # ------------------------------------------------------------ lifecycle
    def boot(self, cfg: ElasticConfig):
        self.hmm.boot(cfg)
        inst, params, cache, _ = self.imm.activate(cfg)
        self.hmm.cache = None  # ownership moves to the engine (donated steps)
        self.engine.bind(cfg, inst.mesh, params, cache, inst.compiled,
                         kv=self.hmm.kv_blocks)

    def preinitialize(self, cfg: ElasticConfig):
        """Warm the IMM cache for an anticipated configuration."""
        self.imm.preinitialize(cfg)

    def scale_to(self, new_cfg: ElasticConfig) -> ScaleEvent:
        """Stage + switchover.  The engine remains serveable between the two
        phases; tests interleave tick() calls to prove zero downtime."""
        ev = self.stage_scale(new_cfg)
        self.switchover()
        return ev

    def stage_scale(self, new_cfg: ElasticConfig) -> ScaleEvent:
        """Monolithic staging (all increments back-to-back).  The
        incremental path is ``start_scale`` + ``task.advance``; both funnel
        into the same ``_record_stage`` bookkeeping."""
        self._preempt_rebalance()
        t0 = time.perf_counter()
        self.hmm.scale(new_cfg)                  # weights only; serving free
        return self._record_stage(new_cfg, time.perf_counter() - t0)

    def _record_stage(self, new_cfg: ElasticConfig, stage_s: float
                      ) -> ScaleEvent:
        hit = self.imm.has(new_cfg)
        t0 = time.perf_counter()
        self.imm.preinitialize(new_cfg)          # no-op if pre-initialized
        stage_s += time.perf_counter() - t0      # cold compile counts as stage
        self._staged_cfg = new_cfg
        if new_cfg.ndev < self.engine.cfg.ndev:
            # scale-down: stop admitting into slots that will be evicted
            self.engine.admit_limit = new_cfg.dp * self.engine.batch_per_replica
        ev = ScaleEvent(t=time.time(),
                        src=self.hmm.active_cfg.describe(),
                        dst=new_cfg.describe(), stats=self.hmm.last_stats,
                        compile_hit=hit,
                        stage_s=stage_s, switch_s=0.0,
                        # blocking callers stall for the whole stage; the
                        # incremental task overwrites with its measured poll
                        # time (near-zero when overlapped)
                        stall_s=stage_s, staging=self.staging_mode,
                        stage_wall_s=self.hmm.last_stats.wall_s)
        self.events.append(ev)
        return ev

    def switchover(self):
        assert self._staged_cfg is not None
        t0 = time.perf_counter()
        new_cfg = self._staged_cfg
        self.hmm.commit(live_cache=self.engine.cache)
        inst, params, cache, hit = self.imm.activate(new_cfg)
        self.hmm.cache = None
        self.engine.bind(new_cfg, inst.mesh, params, cache, inst.compiled,
                         kv=self.hmm.kv_blocks)
        # the routing histogram described the OLD placement; carrying it
        # across the commit would bias the first post-scale rebalance /
        # autoscale decisions toward experts that may no longer be hot
        # (or may now live elsewhere), so restart accumulation here
        self.engine.reset_routing_stats()
        self.engine.admit_limit = None
        self._staged_cfg = None
        if self.events:
            self.events[-1].switch_s = time.perf_counter() - t0
            self.events[-1].compile_hit = hit

    # -------------------------------------------------------- scale-to-zero
    @property
    def parked(self) -> bool:
        return self.hmm.parked

    def park(self) -> TransferStats:
        """Scale to ZERO devices (DESIGN.md §12): snapshot every weight
        bank into the pinned-host tier, unbind the engine and drop all
        device state.  Legal only when fully idle — empty queue, no active
        sequences, no scale/rebalance in flight — so parking never kills a
        request.  ``submit`` stays legal while parked (requests queue); the
        fleet driver answers the queue with ``start_unpark``."""
        assert self._active_task is None or self._active_task.done, \
            "cannot park during a scale event"
        self._preempt_rebalance()
        assert not self.queue and self.engine.active_count() == 0, \
            "park requires a drained server (queue empty, no live slots)"
        stats = self.hmm.park()
        # the engine's old handles would pin the freed device buffers
        self.engine.unbind()
        self._staged_cfg = None
        return stats

    def start_unpark(self, target: ElasticConfig) -> UnparkTask:
        """Open a resumable cold start from the pinned-host tier (the
        scale-from-zero twin of ``start_scale``); the driver advances it
        once per tick until DONE, after which ``tick()`` serves again."""
        return UnparkTask(self, target)

    def _unpark_switchover(self, task: UnparkTask):
        """Commit tail of an unpark: adopt the streamed weights, fresh KV,
        bind the engine — the ``switchover`` analogue for cold starts."""
        t0 = time.perf_counter()
        target = task.target
        self.hmm.commit()
        inst, params, cache, hit = self.imm.activate(target)
        self.hmm.cache = None
        self.engine.bind(target, inst.mesh, params, cache, inst.compiled,
                         kv=self.hmm.kv_blocks)
        self.engine.reset_routing_stats()
        self.engine.admit_limit = None
        ev = ScaleEvent(t=time.time(), src="parked", dst=target.describe(),
                        stats=self.hmm.last_stats,
                        compile_hit=(task._compile_hit
                                     if task._compile_hit is not None
                                     else hit),
                        stage_s=task.stats.wall_s,
                        switch_s=time.perf_counter() - t0,
                        stall_s=task.stall_s, staging=self.staging_mode,
                        stage_wall_s=(task.stage_stats.wall_s
                                      if task.stage_stats else 0.0))
        self.events.append(ev)
        task.event = ev

    # -------------------------------------------------------------- serving
    def submit(self, req: Request):
        kv = self.hmm.kv_blocks
        if kv is not None:
            # fail fast on a request no partition can EVER hold (its final
            # footprint is prompt + output tokens): admission is FIFO
            # head-of-line, so letting it queue would stall serving forever
            need = kv.blocks_needed(req.prompt_len + req.output_len)
            if need > kv.blocks_per_partition:
                raise ValueError(
                    f"request {req.rid} needs {need} KV blocks at completion"
                    f" but a partition holds {kv.blocks_per_partition}")
        self.requests[req.rid] = req
        self.queue.append(req)

    def tick(self, now: float) -> List[int]:
        """One engine tick: admit queued requests into free slots, then one
        decode step.  Returns rids finished this tick.

        While a ScalingTask is in flight the shared gating policy applies —
        the SAME ``admission_during_scale`` the simulator uses — so elastic
        transitions pause *new* admissions until switchover (paper §C)
        while in-flight decodes continue.

        Paged KV: admission is additionally gated by free blocks in the
        target slot's partition (FIFO: the head request tries every free
        slot before admission stalls), and sequences preempted under pool
        pressure re-enter at the *front* of the queue."""
        if self.parked:
            # zero devices: nothing serves, the queue simply accrues until
            # the driver cold-starts us (a tick is legal, not an error —
            # fleet loops tick every backend uniformly)
            return []
        tr = obs.get_tracer()
        admitting = True
        if self._active_task is not None \
                and not self._active_task.phase.terminal:
            _, admitting = admission_during_scale("elastic")
        free = self.engine.free_slots()
        while admitting and self.queue and free:
            req = self.queue[0]
            # prefix-cache-aware placement: try slots whose partition
            # already holds the longest registered prefix of this prompt
            slot = next((s for s in
                         self.engine.preferred_slots(req, req.prompt, free)
                         if self.engine.can_admit(req, req.prompt, s)), None)
            if slot is None:
                break                   # head-of-line blocks; no skipping
            free.remove(slot)
            self.queue.pop(0)
            tr.instant("req.admit", cat="req",
                       args={"rid": req.rid, "slot": slot})
            first = self.engine.start_request(req, req.prompt, slot)
            if first is None:
                continue    # chunked: first token arrives from decode_tick
            if req.first_token_s is None:
                req.first_token_s = now
                req.token_times = [now]
                tr.instant("req.first_token", cat="req",
                           args={"rid": req.rid})
            elif req.token_times is not None:   # preemption resume
                req.token_times.append(now)
        finished = []
        for rid in self.engine.drain_finished_at_admission():
            req = self.requests[rid]
            req.finish_s = now
            finished.append(rid)
            tr.instant("req.finish", cat="req", args={"rid": rid})
            if self.estimator:
                self.estimator.record(req)
        for rid, tok, fin in self.engine.decode_tick():
            req = self.requests[rid]
            if req.first_token_s is None:
                # chunked prefill: the final chunk's token is the TTFT mark
                req.first_token_s = now
                req.token_times = [now]
                tr.instant("req.first_token", cat="req", args={"rid": rid})
            elif req.token_times is not None:
                req.token_times.append(now)
            if fin:
                req.finish_s = now
                finished.append(rid)
                tr.instant("req.finish", cat="req", args={"rid": rid})
                if self.estimator:
                    self.estimator.record(req)
        preempted = self.engine.drain_preempted()
        if preempted:
            self.queue[:0] = [self.requests[r] for r in preempted]
        # background skew rebalance (DESIGN.md §10): advance an in-flight
        # session or let the policy open one — transfers run on the HMM's
        # TransferEngine so this never blocks the tick
        self._drive_rebalance(now)
        return finished

    # ------------------------------------------------------------ decisions
    def autoscale_decision(self, now: float) -> Optional[str]:
        if not self.estimator:
            return None
        return self.estimator.decide(now, len(self.queue), self.utilization())

    # --------------------------------------------- ServingBackend protocol
    def step(self, now: float) -> List[Request]:
        """One driver quantum == one engine tick; returns finished Requests."""
        return [self.requests[rid] for rid in self.tick(now)]

    def queue_depth(self) -> int:
        return len(self.queue)

    def utilization(self) -> float:
        return 0.0 if self.parked else self.engine.utilization()

    def kv_stats(self):
        """Block-pool stats (None in dense mode); serving/metrics.py."""
        return self.engine.kv_stats()

    def routing_stats(self) -> Optional[dict]:
        """Per-expert routing histogram accumulated from sampled decode
        ticks (None when sampling is off or no sample has landed yet);
        serving/metrics.py, DESIGN.md §9."""
        return self.engine.routing_stats()

    def scaling_summary(self) -> Optional[dict]:
        """Aggregate staging-overlap metrics over completed scale events
        (None before the first one); consumed by ``metrics.summarize``:

        * ``decode_stall_s`` — total serve-loop time blocked on staging
          work across all events,
        * ``overlap_efficiency`` — mean Σ-op-time / staging-wall-clock
          (>1 = transfers genuinely overlapped serving)."""
        if not self.events:
            return None
        effs = [ev.stats.op_s / ev.stage_wall_s for ev in self.events
                if ev.stage_wall_s > 0 and ev.stats.op_s > 0]
        return {"staging_mode": self.staging_mode,
                "scaledown_mode": self.scaledown_mode,
                "decode_stall_s": sum(ev.stall_s for ev in self.events),
                "overlap_efficiency":
                    sum(effs) / len(effs) if effs else None,
                "migrated_blocks": sum(ev.migrated_blocks
                                       for ev in self.events),
                "migration_bytes": sum(ev.migration_bytes
                                       for ev in self.events)}

    def current_config(self) -> Optional[ElasticConfig]:
        """Active configuration, or None while parked (zero devices)."""
        return self.hmm.active_cfg

    def start_scale(self, target: ElasticConfig) -> EngineScalingTask:
        """Open a resumable scaling task (the driver advances it one
        increment per tick; ``scale_to`` remains the blocking equivalent)."""
        return EngineScalingTask(self, target)

    # ---------------------------------------------------- expert rebalance
    def _preempt_rebalance(self) -> None:
        """Abort an in-flight rebalance (scale events take priority; the
        page table forbids a remap and a rebalance being staged at once)."""
        task = self._rebalance_task
        if task is not None and not task.done:
            task.abort()

    def start_rebalance(self, actions: List, load=None) -> RebalanceTask:
        """Open a resumable rebalance session over explicit
        ``stage_rebalance`` actions; tick() advances it to completion."""
        assert self._rebalance_task is None or self._rebalance_task.done
        return RebalanceTask(self, actions, load=load)

    def maybe_rebalance(self, now: float) -> Optional[RebalanceTask]:
        """One policy pass: feed the routing histogram to the
        ``RebalancePolicy`` and open a ``RebalanceTask`` if it emits
        actions.  A pool-exhausted staging attempt is skipped, not fatal —
        the policy retries after its cooldown with fresh stats."""
        if self.rebalance_policy is None or self.expert_mode != "pooled":
            return None
        stats = self.engine.routing_stats()
        cfg = self.hmm.active_cfg
        elm = (math.ceil(self.mcfg.num_experts / cfg.ndev)
               + self.hmm.expert_slot_slack)
        actions = self.rebalance_policy.decide(
            stats, self.hmm.page_table, cfg, now, slots_per_rank=elm)
        if not actions:
            return None
        try:
            return self.start_rebalance(actions, load=stats["counts"])
        except MemoryError as err:
            obs.get_tracer().instant(
                "rebalance.skip", cat="rebalance",
                args={"reason": str(err)})
            return None

    def _drive_rebalance(self, now: float) -> None:
        """Per-tick rebalance pump: advance the in-flight task, else ask
        the policy — never while a scale event is in flight."""
        task = self._rebalance_task
        if task is not None and not task.done:
            task.advance(now)
            return
        if self.rebalance_policy is None:
            return
        if self._active_task is not None \
                and not self._active_task.phase.terminal:
            return
        self.maybe_rebalance(now)

    def rebalance_summary(self) -> Optional[dict]:
        """Aggregate rebalance telemetry (None before the first pass);
        consumed by ``metrics.summarize`` and ``benchmarks/expert_skew``."""
        if not self.rebalance_events:
            return None
        done = [ev for ev in self.rebalance_events if not ev.aborted]
        return {"passes": len(done),
                "aborted": len(self.rebalance_events) - len(done),
                "replicated": sum(ev.replicated for ev in done),
                "demoted": sum(ev.demoted for ev in done),
                "dropped": sum(ev.dropped for ev in done),
                "promoted": sum(ev.promoted for ev in done),
                "replica_bytes": sum(ev.stats.expert_replica_bytes
                                     for ev in done if ev.stats),
                "d2h_bytes": sum(ev.stats.expert_d2h_bytes
                                 for ev in done if ev.stats),
                "host_tier_bytes": self.hmm.host_tier_bytes()}

    def prewarm(self, target: ElasticConfig) -> None:
        self.preinitialize(target)

    def capacity(self, cfg: ElasticConfig) -> int:
        return cfg.dp * self.engine.batch_per_replica
