"""ElasticServer — ties the Coordinator, HMM and IMM to the serving engine.

The serving lifecycle (paper §5):
* ``boot(cfg)`` — HMM loads weights once, IMM compiles + attaches, engine
  starts taking requests.
* ``scale_to(cfg')`` — concurrent scaling: HMM stages the minimal-cost
  reconfiguration (zero-copy + P2P + expert-page remap) and the IMM prepares
  the target instance, **while the active instance keeps serving**
  (tick() remains callable throughout).  ``switchover()`` retargets traffic:
  surviving decode slots continue on the *same* KV cache rows — zero
  downtime, zero token divergence (asserted in tests).
* ``scale_down`` drains only the slots being evicted.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.coordinator import LoadEstimator, ScalingPolicy
from repro.core.hmm import HMM, TransferStats
from repro.core.imm import IMM
from repro.core.topology import ElasticConfig
from repro.serving.engine import InferenceEngine
from repro.serving.workload import Request


@dataclasses.dataclass
class ScaleEvent:
    t: float
    src: str
    dst: str
    stats: TransferStats
    compile_hit: bool
    stage_s: float
    switch_s: float


class ElasticServer:
    def __init__(self, mcfg: ModelConfig, *, tp: int, batch_per_replica: int,
                 max_len: int, prefill_buckets=(64,), all_devices=None,
                 policy: Optional[ScalingPolicy] = None, seed: int = 0):
        self.mcfg = mcfg
        self.hmm = HMM(mcfg, tp, batch_per_replica=batch_per_replica,
                       max_len=max_len, all_devices=all_devices, seed=seed)
        self.imm = IMM(mcfg, self.hmm, batch_per_replica=batch_per_replica,
                       max_len=max_len, prefill_buckets=prefill_buckets)
        self.engine = InferenceEngine(mcfg, batch_per_replica=batch_per_replica,
                                      max_len=max_len,
                                      prefill_bucket=min(prefill_buckets))
        self.estimator = LoadEstimator(policy) if policy else None
        self.queue: List[Request] = []
        self.requests: Dict[int, Request] = {}
        self.events: List[ScaleEvent] = []
        self._staged_cfg: Optional[ElasticConfig] = None

    # ------------------------------------------------------------ lifecycle
    def boot(self, cfg: ElasticConfig):
        self.hmm.boot(cfg)
        inst, params, cache, _ = self.imm.activate(cfg)
        self.hmm.cache = None  # ownership moves to the engine (donated steps)
        self.engine.bind(cfg, inst.mesh, params, cache, inst.compiled)

    def preinitialize(self, cfg: ElasticConfig):
        """Warm the IMM cache for an anticipated configuration."""
        self.imm.preinitialize(cfg)

    def scale_to(self, new_cfg: ElasticConfig) -> ScaleEvent:
        """Stage + switchover.  The engine remains serveable between the two
        phases; tests interleave tick() calls to prove zero downtime."""
        ev = self.stage_scale(new_cfg)
        self.switchover()
        return ev

    def stage_scale(self, new_cfg: ElasticConfig) -> ScaleEvent:
        t0 = time.perf_counter()
        stats = self.hmm.scale(new_cfg)          # weights only; serving free
        inst = self.imm.preinitialize(new_cfg)   # no-op if pre-initialized
        self._staged_cfg = new_cfg
        if new_cfg.ndev < self.engine.cfg.ndev:
            # scale-down: stop admitting into slots that will be evicted
            self.engine.admit_limit = new_cfg.dp * self.engine.batch_per_replica
        ev = ScaleEvent(t=time.time(),
                        src=self.hmm.active_cfg.describe(),
                        dst=new_cfg.describe(), stats=stats,
                        compile_hit=inst.compile_s == 0 or inst.activations > 0,
                        stage_s=time.perf_counter() - t0, switch_s=0.0)
        self.events.append(ev)
        return ev

    def switchover(self):
        assert self._staged_cfg is not None
        t0 = time.perf_counter()
        new_cfg = self._staged_cfg
        self.hmm.commit(live_cache=self.engine.cache)
        inst, params, cache, hit = self.imm.activate(new_cfg)
        self.hmm.cache = None
        self.engine.bind(new_cfg, inst.mesh, params, cache, inst.compiled)
        self.engine.admit_limit = None
        self._staged_cfg = None
        if self.events:
            self.events[-1].switch_s = time.perf_counter() - t0
            self.events[-1].compile_hit = hit

    # -------------------------------------------------------------- serving
    def submit(self, req: Request):
        self.requests[req.rid] = req
        self.queue.append(req)

    def tick(self, now: float) -> List[int]:
        """One engine tick: admit queued requests into free slots, then one
        decode step.  Returns rids finished this tick."""
        for slot in self.engine.free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            self.engine.start_request(req, req.prompt, slot)
            req.first_token_s = now
            req.token_times = [now]
        finished = []
        for rid, tok, fin in self.engine.decode_tick():
            req = self.requests[rid]
            if req.token_times is not None:
                req.token_times.append(now)
            if fin:
                req.finish_s = now
                finished.append(rid)
                if self.estimator:
                    self.estimator.record(req)
        return finished

    # ------------------------------------------------------------ decisions
    def autoscale_decision(self, now: float) -> Optional[str]:
        if not self.estimator:
            return None
        util = (self.engine.active_count() / max(self.engine.num_slots, 1))
        return self.estimator.decide(now, len(self.queue), util)
