"""ElasticServer — ties the Coordinator, HMM and IMM to the serving engine.

The serving lifecycle (paper §5):
* ``boot(cfg)`` — HMM loads weights once, IMM compiles + attaches, engine
  starts taking requests.
* ``scale_to(cfg')`` — concurrent scaling: HMM stages the minimal-cost
  reconfiguration (zero-copy + P2P + expert-page remap) and the IMM prepares
  the target instance, **while the active instance keeps serving**
  (tick() remains callable throughout).  ``switchover()`` retargets traffic:
  surviving decode slots continue on the *same* KV cache rows — zero
  downtime, zero token divergence (asserted in tests).
* ``scale_down`` drains only the slots being evicted.

For closed-loop operation, ``ElasticServer`` implements the
``ServingBackend`` protocol (serving/driver.py): ``start_scale`` returns an
``EngineScalingTask`` that performs the same transition as ``scale_to`` but
as resumable increments — one per-tensor HMM reshard per ``advance`` call —
so a ``ClusterDriver`` interleaves real decode ticks with staging work.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.coordinator import LoadEstimator, ScalingPolicy
from repro.core.hmm import HMM, TransferStats
from repro.core.imm import IMM
from repro.core.topology import ElasticConfig
from repro.serving.driver import ScalePhase, admission_during_scale
from repro.serving.engine import InferenceEngine
from repro.serving.workload import Request


@dataclasses.dataclass
class ScaleEvent:
    t: float
    src: str
    dst: str
    stats: TransferStats
    compile_hit: bool
    stage_s: float
    switch_s: float


class EngineScalingTask:
    """Resumable scale transition over the real JAX engine (driver.ScalingTask).

    Phases: STAGING (one per-tensor HMM reshard per ``advance``) ->
    COMPILING (IMM pre-init; LRU hit makes this ~free) -> DRAINING
    (scale-down only) -> COMMITTING (switchover) -> DONE.  The engine's
    ``tick()`` is legal — and expected — between every ``advance`` call.
    """

    def __init__(self, server: "ElasticServer", target: ElasticConfig):
        self.server = server
        self.target = target
        self.phase = ScalePhase.STAGING
        self.increments_total = server.hmm.begin_scale(target) + 1  # +compile
        self.increments_done = 0
        self.stats: TransferStats = server.hmm._stage_stats
        # staging-only snapshot, frozen when STAGING completes (``stats``
        # keeps accumulating: commit merges the KV handover bytes into it)
        self.stage_stats: Optional[TransferStats] = None
        self.event: Optional[ScaleEvent] = None
        self._down = target.ndev < server.engine.cfg.ndev
        self._keep = target.dp * server.engine.batch_per_replica
        if self._down:
            # stop admitting into doomed slots right away so the drain
            # overlaps the staging increments instead of following them
            server.engine.admit_limit = self._keep
        server._active_task = self

    @property
    def done(self) -> bool:
        return self.phase.terminal

    def advance(self, now: float) -> ScalePhase:
        ph = self.phase
        if ph is ScalePhase.STAGING:
            more = self.server.hmm.stage_increment()
            self.increments_done += 1
            if not more:
                self.stage_stats = dataclasses.replace(self.stats)
                self.phase = ScalePhase.COMPILING
        elif ph is ScalePhase.COMPILING:
            self.increments_done += 1
            # staging time = the HMM's tracked staging work, NOT wall time
            # since task creation (which would count the decode ticks that
            # ran between increments); _record_stage adds the compile time
            self.event = self.server._record_stage(
                self.target, self.stats.wall_s)
            self.phase = (ScalePhase.DRAINING if self._down
                          else ScalePhase.COMMITTING)
        elif ph is ScalePhase.DRAINING:
            if self.server.engine.drained(self._keep):
                self.phase = ScalePhase.COMMITTING
        elif ph is ScalePhase.COMMITTING:
            self.server.switchover()
            self.phase = ScalePhase.DONE
            self.server._active_task = None
        return self.phase

    def abort(self):
        assert self.phase in (ScalePhase.STAGING, ScalePhase.COMPILING,
                              ScalePhase.DRAINING)
        self.server.hmm.abort()
        if self._down:
            # re-open the slots we stopped admitting into in __init__
            self.server.engine.admit_limit = None
        self.server._staged_cfg = None
        self.server._active_task = None
        self.phase = ScalePhase.ABORTED


class ElasticServer:
    def __init__(self, mcfg: ModelConfig, *, tp: int, batch_per_replica: int,
                 max_len: int, prefill_buckets=(64,), all_devices=None,
                 policy: Optional[ScalingPolicy] = None, seed: int = 0,
                 kv_mode: str = "dense", kv_block_size: int = 16,
                 kv_blocks_per_replica: Optional[int] = None,
                 expert_mode: str = "dense",
                 expert_pool_pages: Optional[int] = None):
        self.mcfg = mcfg
        self.kv_mode = kv_mode
        # 'pooled': expert weights live as page pools + tables, so an EP
        # scale event migrates only the min-move page set and commit only
        # rewrites tables (DESIGN.md §2); the driver's cost projections
        # adopt this through the ``expert_mode`` attribute
        self.expert_mode = expert_mode
        self.hmm = HMM(mcfg, tp, batch_per_replica=batch_per_replica,
                       max_len=max_len, all_devices=all_devices, seed=seed,
                       kv_mode=kv_mode, kv_block_size=kv_block_size,
                       kv_blocks_per_replica=kv_blocks_per_replica,
                       expert_mode=expert_mode,
                       expert_pool_pages=expert_pool_pages)
        self.imm = IMM(mcfg, self.hmm, batch_per_replica=batch_per_replica,
                       max_len=max_len, prefill_buckets=prefill_buckets)
        self.engine = InferenceEngine(mcfg, batch_per_replica=batch_per_replica,
                                      max_len=max_len,
                                      prefill_bucket=min(prefill_buckets))
        self.estimator = LoadEstimator(policy) if policy else None
        self.queue: List[Request] = []
        self.requests: Dict[int, Request] = {}
        self.events: List[ScaleEvent] = []
        self._staged_cfg: Optional[ElasticConfig] = None
        self._active_task: Optional[EngineScalingTask] = None

    # ------------------------------------------------------------ lifecycle
    def boot(self, cfg: ElasticConfig):
        self.hmm.boot(cfg)
        inst, params, cache, _ = self.imm.activate(cfg)
        self.hmm.cache = None  # ownership moves to the engine (donated steps)
        self.engine.bind(cfg, inst.mesh, params, cache, inst.compiled,
                         kv=self.hmm.kv_blocks)

    def preinitialize(self, cfg: ElasticConfig):
        """Warm the IMM cache for an anticipated configuration."""
        self.imm.preinitialize(cfg)

    def scale_to(self, new_cfg: ElasticConfig) -> ScaleEvent:
        """Stage + switchover.  The engine remains serveable between the two
        phases; tests interleave tick() calls to prove zero downtime."""
        ev = self.stage_scale(new_cfg)
        self.switchover()
        return ev

    def stage_scale(self, new_cfg: ElasticConfig) -> ScaleEvent:
        """Monolithic staging (all increments back-to-back).  The
        incremental path is ``start_scale`` + ``task.advance``; both funnel
        into the same ``_record_stage`` bookkeeping."""
        t0 = time.perf_counter()
        self.hmm.scale(new_cfg)                  # weights only; serving free
        return self._record_stage(new_cfg, time.perf_counter() - t0)

    def _record_stage(self, new_cfg: ElasticConfig, stage_s: float
                      ) -> ScaleEvent:
        hit = self.imm.has(new_cfg)
        t0 = time.perf_counter()
        self.imm.preinitialize(new_cfg)          # no-op if pre-initialized
        stage_s += time.perf_counter() - t0      # cold compile counts as stage
        self._staged_cfg = new_cfg
        if new_cfg.ndev < self.engine.cfg.ndev:
            # scale-down: stop admitting into slots that will be evicted
            self.engine.admit_limit = new_cfg.dp * self.engine.batch_per_replica
        ev = ScaleEvent(t=time.time(),
                        src=self.hmm.active_cfg.describe(),
                        dst=new_cfg.describe(), stats=self.hmm.last_stats,
                        compile_hit=hit,
                        stage_s=stage_s, switch_s=0.0)
        self.events.append(ev)
        return ev

    def switchover(self):
        assert self._staged_cfg is not None
        t0 = time.perf_counter()
        new_cfg = self._staged_cfg
        self.hmm.commit(live_cache=self.engine.cache)
        inst, params, cache, hit = self.imm.activate(new_cfg)
        self.hmm.cache = None
        self.engine.bind(new_cfg, inst.mesh, params, cache, inst.compiled,
                         kv=self.hmm.kv_blocks)
        self.engine.admit_limit = None
        self._staged_cfg = None
        if self.events:
            self.events[-1].switch_s = time.perf_counter() - t0
            self.events[-1].compile_hit = hit

    # -------------------------------------------------------------- serving
    def submit(self, req: Request):
        kv = self.hmm.kv_blocks
        if kv is not None:
            # fail fast on a request no partition can EVER hold (its final
            # footprint is prompt + output tokens): admission is FIFO
            # head-of-line, so letting it queue would stall serving forever
            need = kv.blocks_needed(req.prompt_len + req.output_len)
            if need > kv.blocks_per_partition:
                raise ValueError(
                    f"request {req.rid} needs {need} KV blocks at completion"
                    f" but a partition holds {kv.blocks_per_partition}")
        self.requests[req.rid] = req
        self.queue.append(req)

    def tick(self, now: float) -> List[int]:
        """One engine tick: admit queued requests into free slots, then one
        decode step.  Returns rids finished this tick.

        While a ScalingTask is in flight the shared gating policy applies —
        the SAME ``admission_during_scale`` the simulator uses — so elastic
        transitions pause *new* admissions until switchover (paper §C)
        while in-flight decodes continue.

        Paged KV: admission is additionally gated by free blocks in the
        target slot's partition (FIFO: the head request tries every free
        slot before admission stalls), and sequences preempted under pool
        pressure re-enter at the *front* of the queue."""
        admitting = True
        if self._active_task is not None \
                and not self._active_task.phase.terminal:
            _, admitting = admission_during_scale("elastic")
        free = self.engine.free_slots()
        while admitting and self.queue and free:
            req = self.queue[0]
            slot = next((s for s in free
                         if self.engine.can_admit(req, req.prompt, s)), None)
            if slot is None:
                break                   # head-of-line blocks; no skipping
            free.remove(slot)
            self.queue.pop(0)
            self.engine.start_request(req, req.prompt, slot)
            if req.first_token_s is None:
                req.first_token_s = now
                req.token_times = [now]
            elif req.token_times is not None:   # preemption resume
                req.token_times.append(now)
        finished = []
        for rid in self.engine.drain_finished_at_admission():
            req = self.requests[rid]
            req.finish_s = now
            finished.append(rid)
            if self.estimator:
                self.estimator.record(req)
        for rid, tok, fin in self.engine.decode_tick():
            req = self.requests[rid]
            if req.token_times is not None:
                req.token_times.append(now)
            if fin:
                req.finish_s = now
                finished.append(rid)
                if self.estimator:
                    self.estimator.record(req)
        preempted = self.engine.drain_preempted()
        if preempted:
            self.queue[:0] = [self.requests[r] for r in preempted]
        return finished

    # ------------------------------------------------------------ decisions
    def autoscale_decision(self, now: float) -> Optional[str]:
        if not self.estimator:
            return None
        return self.estimator.decide(now, len(self.queue), self.utilization())

    # --------------------------------------------- ServingBackend protocol
    def step(self, now: float) -> List[Request]:
        """One driver quantum == one engine tick; returns finished Requests."""
        return [self.requests[rid] for rid in self.tick(now)]

    def queue_depth(self) -> int:
        return len(self.queue)

    def utilization(self) -> float:
        return self.engine.utilization()

    def kv_stats(self):
        """Block-pool stats (None in dense mode); serving/metrics.py."""
        return self.engine.kv_stats()

    def current_config(self) -> ElasticConfig:
        return self.hmm.active_cfg

    def start_scale(self, target: ElasticConfig) -> EngineScalingTask:
        """Open a resumable scaling task (the driver advances it one
        increment per tick; ``scale_to`` remains the blocking equivalent)."""
        return EngineScalingTask(self, target)

    def prewarm(self, target: ElasticConfig) -> None:
        self.preinitialize(target)

    def capacity(self, cfg: ElasticConfig) -> int:
        return cfg.dp * self.engine.batch_per_replica
