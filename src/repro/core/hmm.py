"""HBM Management Module (paper §4.4) — the decoupled memory layer.

The HMM owns model weights and KV caches *independently of inference
instances*.  Weights live as per-device buffers; an instance receives
assembled global ``jax.Array`` views built with
``jax.make_array_from_single_device_arrays``, which **aliases** the existing
per-device buffers — the JAX-native zero-copy handle (Ascend IPC in the
paper).

``scale()`` implements the paper's minimal-cost reconfiguration:
* shards whose (content, device) are unchanged are *reused* (zero-copy),
* shards that exist on another device are moved with ``jax.device_put``
  (device-to-device DMA — the p2p-copy primitive),
* expert banks are re-grouped at page (single-expert) granularity so only
  migrated experts cross devices — and with ``expert_mode='pooled'`` the
  page pools + tables ARE the weight representation: scaling migrates
  exactly the min-move Migration list and commit only swaps tables
  (vpage-remap; see expert_pages.py for the O(1) table mechanics and
  DESIGN.md §2 for the pooled store / dense-buffer history),
* KV caches of surviving DP replicas are reused as-is; new replicas get
  zero-initialized state.

Byte accounting (zero_copy / p2p / local / init) is exact and is asserted
against the logical planner (scaling_plan.py) in tests.

Staging runs in one of two modes (DESIGN.md §3): ``staging="serial"`` (the
default) moves one tensor per ``stage_increment`` call on the caller's
thread; ``staging="overlap"`` submits the whole work list to a background
``TransferEngine`` (core/transfer.py) at ``begin_scale`` and the caller
polls completion with ``poll_staging`` — same bytes, field-by-field equal
``TransferStats``, strictly less wall-clock because decode ticks run
concurrently with the transfers instead of between them.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.expert_pages import ExpertPageTable
from repro.core.topology import ElasticConfig


def _idx_key(index) -> tuple:
    return tuple((s.start, s.stop, s.step) for s in index)


@dataclasses.dataclass
class TransferStats:
    zero_copy_bytes: int = 0
    p2p_bytes: int = 0
    local_bytes: int = 0
    init_bytes: int = 0
    zero_copy_count: int = 0
    p2p_count: int = 0
    wall_s: float = 0.0
    # Σ per-transfer-op execution time.  Serial staging: ~= the transfer
    # share of wall_s.  Overlapped staging: ops run concurrently on the
    # TransferEngine, so op_s / wall_s > 1 is the measured overlap
    # efficiency (metrics.summarize).  Timing fields (wall_s, op_s) are
    # excluded from the serial-vs-overlap byte-equality assertions.
    op_s: float = 0.0
    # expert-weight sub-accounting (included in the totals above): what the
    # vpage remap moved vs reused — pooled mode asserts expert_p2p_bytes ==
    # sum of Migration page sizes, and commit adds zero to it
    expert_p2p_bytes: int = 0
    expert_zero_copy_bytes: int = 0
    expert_local_bytes: int = 0
    # skew-rebalance sub-accounting (DESIGN.md §10).  replica: bytes copied
    # to create extra device copies of hot experts; d2h: cold-expert bytes
    # demoted into the pinned-host tier; h2d: host-tier bytes streamed back
    # to devices at scale events (replaces expert P2P for the cold set —
    # these rows are deliberately NOT counted in p2p_bytes).
    expert_replica_bytes: int = 0
    expert_d2h_bytes: int = 0
    expert_h2d_bytes: int = 0
    # whole-model pinned-host tier (scale-to-zero, DESIGN.md §12).  d2h:
    # bytes park() snapshots host-side; h2d: bytes unpark streams back to
    # devices.  The expert_* fields above remain the per-page
    # sub-accounting; these count every bank — attention, dense MLP,
    # embeddings, experts — of the parked model.
    d2h_bytes: int = 0
    h2d_bytes: int = 0

    #: the additive byte/count fields that must agree exactly between
    #: staging="serial" and staging="overlap" (same reshard calls, same
    #: bytes — only wall-clock differs); tests iterate this list
    BYTE_FIELDS = ("zero_copy_bytes", "p2p_bytes", "local_bytes",
                   "init_bytes", "zero_copy_count", "p2p_count",
                   "expert_p2p_bytes", "expert_zero_copy_bytes",
                   "expert_local_bytes", "expert_replica_bytes",
                   "expert_d2h_bytes", "expert_h2d_bytes",
                   "d2h_bytes", "h2d_bytes")

    def merge(self, o: "TransferStats"):
        self.zero_copy_bytes += o.zero_copy_bytes
        self.p2p_bytes += o.p2p_bytes
        self.local_bytes += o.local_bytes
        self.init_bytes += o.init_bytes
        self.zero_copy_count += o.zero_copy_count
        self.p2p_count += o.p2p_count
        self.wall_s += o.wall_s
        self.op_s += o.op_s
        self.expert_p2p_bytes += o.expert_p2p_bytes
        self.expert_zero_copy_bytes += o.expert_zero_copy_bytes
        self.expert_local_bytes += o.expert_local_bytes
        self.expert_replica_bytes += o.expert_replica_bytes
        self.expert_d2h_bytes += o.expert_d2h_bytes
        self.expert_h2d_bytes += o.expert_h2d_bytes
        self.d2h_bytes += o.d2h_bytes
        self.h2d_bytes += o.h2d_bytes


def make_instance_mesh(cfg: ElasticConfig, all_devices=None) -> Mesh:
    devs = all_devices or jax.devices()
    grid = np.array([devs[i] for i in cfg.devices]).reshape(cfg.dp, cfg.tp)
    return Mesh(grid, ("dp", "tp"))


# --------------------------------------------------------- reshard-with-reuse

def reshard_with_reuse(arr: jax.Array, new_sharding: NamedSharding,
                       stats: TransferStats,
                       expert_dim: Optional[int] = None) -> jax.Array:
    """Rebuild ``arr`` under ``new_sharding`` reusing existing per-device
    buffers wherever the required shard already lives on the right device.

    ``expert_dim``: if set, allows piecewise assembly along that dim at
    single-row ("page") granularity when slice boundaries change.
    """
    shape = arr.shape
    old = {}
    for sh in arr.addressable_shards:
        old.setdefault(_idx_key(sh.index), []).append((sh.device, sh.data))

    target = new_sharding.devices_indices_map(shape)
    out = []
    for dev in new_sharding.addressable_devices:
        index = target[dev]
        key = _idx_key(index)
        holders = old.get(key, [])
        same = [d for d in holders if d[0] == dev]
        if same:
            data = same[0][1]
            stats.zero_copy_bytes += data.nbytes
            stats.zero_copy_count += 1
        elif holders:
            src_dev, src_data = holders[0]
            data = jax.device_put(src_data, dev)
            stats.p2p_bytes += src_data.nbytes
            stats.p2p_count += 1
        elif expert_dim is not None:
            data = _assemble_rows(arr, index, expert_dim, dev, stats)
        else:
            raise ValueError(f"no source for shard {key} of {shape}")
        out.append(data)
    return jax.make_array_from_single_device_arrays(shape, new_sharding, out)


def _assemble_rows(arr, index, dim, dev, stats: TransferStats):
    """Piecewise (per-page) assembly of one target shard along ``dim``.

    Pure memory ops only: pieces are sliced/concatenated host-side with
    numpy and shipped with one ``jax.device_put`` — no jit-compiled
    primitives (slice/concatenate executables), so this is safe on
    TransferEngine worker threads concurrently with main-thread tracing
    and compilation (core/transfer.py).  Byte accounting is unchanged:
    the same sub-slices are counted local vs p2p."""
    want = index[dim]
    lo = want.start or 0
    hi = want.stop if want.stop is not None else arr.shape[dim]
    pieces = []
    for sh in arr.addressable_shards:
        s = sh.index[dim]
        slo = s.start or 0
        shi = s.stop if s.stop is not None else arr.shape[dim]
        olo, ohi = max(lo, slo), min(hi, shi)
        if olo >= ohi:
            continue
        data = np.asarray(sh.data)
        if (olo - slo, ohi - slo) != (0, shi - slo):
            sub = data[(slice(None),) * dim
                       + (slice(olo - slo, ohi - slo),)]
        else:
            sub = data
        if sh.device == dev:
            stats.local_bytes += sub.nbytes
        else:
            stats.p2p_bytes += sub.nbytes
            stats.p2p_count += 1
        pieces.append((olo, sub))
    pieces.sort(key=lambda t: t[0])
    out = pieces[0][1] if len(pieces) == 1 else \
        np.concatenate([p for _, p in pieces], axis=dim)
    return jax.device_put(out, dev)


# ---------------------------------------------------------------------- HMM

class HMM:
    """Holds weights + KV caches; instances attach via zero-copy handles.

    ``kv_mode='paged'``: the KV cache is a block pool ``[L, NB, bs, ...]``
    partitioned per DP replica (block axis sharded over 'dp'), and the HMM
    also owns the host-side :class:`~repro.serving.kv_blocks.KVBlockManager`.
    ``commit`` grows/shrinks the pool by whole partitions: surviving
    partitions' shards are reused zero-copy (same device, same shard index),
    so every live block table stays valid verbatim across the scale event —
    the KV-side vpage-remap (DESIGN.md §7).

    ``expert_mode='pooled'`` (MoE models; DESIGN.md §2): each expert weight
    bank lives as a per-device page *pool* — one global array
    ``[ndev * pages_per_device, D, F]``, page axis sharded one fixed-size
    slice per device — plus the ``ExpertPageTable``-derived index arrays
    (``core/expert_pages.pooled_layout``) that the pooled MoE execution path
    consumes.  Scaling then migrates exactly the ``stage_remap(min_move=
    True)`` Migration list (one ``jax.device_put`` per page, accounted in
    ``TransferStats.expert_p2p_bytes``) and ``commit`` is an O(table) swap:
    no expert-bank reshard, no ``_assemble_rows`` concatenation, no weight
    bytes at switchover.  The dense layout stays the default.
    """

    def __init__(self, mcfg: ModelConfig, tp: int, *,
                 batch_per_replica: int, max_len: int,
                 all_devices=None, seed: int = 0,
                 kv_mode: str = "dense", kv_block_size: int = 16,
                 kv_blocks_per_replica: Optional[int] = None,
                 expert_mode: str = "dense",
                 expert_pool_pages: Optional[int] = None,
                 expert_slot_slack: int = 0,
                 expert_host_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None,
                 expert_dtype: Optional[str] = None,
                 staging: str = "serial", transfer_workers: int = 4):
        self.mcfg = mcfg
        self.tp = tp
        self.batch_per_replica = batch_per_replica
        self.max_len = max_len
        self.all_devices = list(all_devices or jax.devices())
        self.seed = seed
        assert kv_mode in ("dense", "paged")
        assert expert_mode in ("dense", "pooled")
        # staging="serial": stage_increment() moves one tensor per call on
        # the caller's thread (byte-exact legacy path, the default).
        # staging="overlap": begin_scale submits the whole work list to a
        # background TransferEngine and callers poll_staging()/join_staging()
        # — same bytes, less wall-clock (DESIGN.md §3).
        assert staging in ("serial", "overlap")
        self.staging_mode = staging
        self.transfer_workers = transfer_workers
        self._transfer = None            # TransferEngine, created lazily
        self._stage_session = None       # TransferSession (overlap only)
        self._stage_lock = threading.Lock()
        self._stage_t0 = 0.0
        if expert_mode == "pooled":
            assert mcfg.is_moe, \
                f"{mcfg.name}: expert_mode='pooled' requires a MoE model"
        self.expert_mode = expert_mode
        # Quantized storage knobs (ISSUE 9).  ``kv_dtype='int8'`` stores the
        # paged KV pool as int8 entries plus per-token-row f32 scale pools
        # that ride the same block axis (remap/migration correctness by
        # construction); ``expert_dtype='int8'`` stores the pooled expert
        # banks as int8 pages plus per-page f32 scale banks addressed by the
        # same page table.  None keeps the model dtype (f32 parity oracle).
        assert kv_dtype in (None, "int8"), kv_dtype
        assert expert_dtype in (None, "int8"), expert_dtype
        if kv_dtype is not None:
            assert kv_mode == "paged", \
                "kv_dtype='int8' requires kv_mode='paged' (block-wise scales)"
        if expert_dtype is not None:
            assert expert_mode == "pooled", \
                "expert_dtype='int8' requires expert_mode='pooled' " \
                "(per-page scales live beside the pool banks)"
        self.kv_dtype = kv_dtype
        self.expert_dtype = expert_dtype
        # per-device pool capacity in pages ((layer, expert) granularity,
        # one free list per device); None resolves at boot to twice the boot
        # config's per-device expert load — headroom for staging (active +
        # migrated-in pages coexist until commit) and for scaling down to
        # half the boot device count.  Scaling below that raises a clear
        # MemoryError from the page allocator: pass a larger value here.
        self.expert_pool_pages: Optional[int] = expert_pool_pages
        # extra compiled table-width slots per rank beyond ceil(E/ndev):
        # replication headroom for the skew rebalancer (DESIGN.md §10).
        # The width is AOT-baked into every pooled executable, so it is
        # fixed for the HMM's lifetime; 0 keeps shapes byte-identical to
        # the pre-rebalance layout (and forbids net replica skew that
        # would overflow a rank's table).
        self.expert_slot_slack = int(expert_slot_slack)
        # pinned-host cold tier capacity in pages (None: ExpertPageTable
        # default — every (layer, expert) once, the scale-to-zero limit)
        self.expert_host_pages = expert_host_pages
        # host-side bytes of demoted experts: (layer, expert) -> {bank: row}.
        # The page table accounts the tier; this dict holds the bytes.
        self._expert_host_pool: Dict[Tuple[int, int],
                                     Dict[str, np.ndarray]] = {}
        # whole-model pinned-host tier (scale-to-zero, DESIGN.md §12):
        # park() snapshots every bank here and releases the devices;
        # begin_unpark() streams it back through the staging session.
        self._parked: Optional[Dict[str, Any]] = None
        self._unpark = False                  # current session is an unpark
        self._unpark_table = None             # fresh page table for unpark
        # rebalance session state (begin_rebalance/.../abort_rebalance)
        self._rebalance_ops = None       # List[RebalanceOp]
        self._rebalance_session = None   # TransferSession
        self._rebalance_stats: Optional[TransferStats] = None
        self._rebalance_load = None      # [L_moe, E] routing snapshot
        self._rebalance_t0 = 0.0
        self.last_rebalance_stats: Optional[TransferStats] = None
        self.kv_mode = kv_mode
        self.kv_block_size = kv_block_size
        if kv_mode == "paged":
            from repro.models.model import paged_cache_supported
            assert paged_cache_supported(mcfg), \
                f"{mcfg.name} does not support the paged KV layout"
            assert max_len % kv_block_size == 0
            # dense-equivalent capacity by default; pressure experiments
            # pass a smaller pool to force preemption
            self.kv_blocks_per_replica = (
                kv_blocks_per_replica
                or batch_per_replica * (max_len // kv_block_size))
        else:
            self.kv_blocks_per_replica = 0
        self.kv_blocks = None  # KVBlockManager, created at boot (paged only)
        self.active_cfg: Optional[ElasticConfig] = None
        self.params: Any = None
        self.cache: Any = None
        self.staged: Optional[Tuple] = None
        if mcfg.is_moe:
            self.page_table = ExpertPageTable(
                mcfg.num_layers - mcfg.first_k_dense, mcfg.num_experts,
                pool_pages_per_device=(self.expert_pool_pages or 0
                                       if expert_mode == "pooled" else 0),
                host_pool_pages=self.expert_host_pages)
        else:
            self.page_table = None
        self.last_stats: Optional[TransferStats] = None
        self.last_migrations: Optional[List] = None  # pooled: last staged set
        # incremental staging session (begin_scale / stage_increment)
        self._stage_work: Optional[List[Tuple]] = None
        self._stage_cursor = 0
        self._stage_out: List[Any] = []
        self._stage_treedef = None
        self._stage_target: Optional[Tuple] = None
        self._stage_stats: Optional[TransferStats] = None
        self._stage_layout: Optional[Dict[str, np.ndarray]] = None

    # ----------------------------------------------------------- shardings
    def param_shardings(self, params, mesh: Mesh):
        """TP over 'tp'; experts over ('dp','tp') = EP; rest replicated over
        'dp' (attention replicas)."""
        def spec(path_tuple, leaf):
            path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path_tuple)
            shape = leaf.shape
            stacked = 1 if ("blocks/" in path or "cross_blocks/" in path) else 0
            ntp = mesh.shape["tp"]
            nep = mesh.shape["dp"] * mesh.shape["tp"]
            s = [None] * len(shape)
            import re
            if re.search(r"moe/w[igo]$", path):
                if shape[stacked] % nep == 0:
                    s[stacked] = ("dp", "tp")
                return P(*s)
            # pooled expert store: page pools carved one slice per device
            # (quantized scale banks shard the same page axis); per-layer
            # kernel tables one row per device; the other index arrays
            # (edest/eslot/gtable) replicated like the router
            if re.search(r"moe_pool/w[igo](_scale)?$", path):
                if shape[0] % nep == 0:
                    s[0] = ("dp", "tp")
                return P(*s)
            if re.search(r"moe/tables$", path):
                if shape[stacked] % nep == 0:
                    s[stacked] = ("dp", "tp")
                return P(*s)
            if re.search(r"moe/(edest|eslot|gtable)$", path):
                return P(*s)
            rules = [
                (r"attn/q/w$|attn/q_up/w$|xattn/q/w$", stacked + 1),
                (r"attn/(k|v)/w$|xattn/(k|v)/w$", stacked + 1),
                (r"attn/o/w$|xattn/o/w$", stacked + 0),
                (r"attn/(k|v)_up/w$", stacked + 1),
                (r"(mlp|shared)/(up|gate)/w$", stacked + 1),
                (r"(mlp|shared)/down/w$", stacked + 0),
                (r"lm_head/w$", 1),
                (r"embed$", 0),
            ]
            for pat, dim in rules:
                if re.search(pat, path) and dim < len(shape) \
                        and shape[dim] % ntp == 0 and shape[dim] >= ntp:
                    s[dim] = "tp"
                    return P(*s)
            return P(*s)
        specs = jax.tree_util.tree_map_with_path(spec, params)
        return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs)

    def cache_shardings(self, cache, mesh: Mesh):
        def spec(path_tuple, leaf):
            # [L, B, ...]: batch over 'dp'
            s = [None] * leaf.ndim
            if leaf.ndim >= 2 and leaf.shape[1] % mesh.shape["dp"] == 0:
                s[1] = "dp"
            return P(*s)
        specs = jax.tree_util.tree_map_with_path(spec, cache)
        return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs)

    def make_cache(self, cfg: ElasticConfig):
        """Freshly initialized decode cache for ``cfg`` (dense rows or the
        paged block pool, per ``kv_mode``)."""
        from repro.models.model import init_cache, init_paged_cache
        if self.kv_mode == "paged":
            return init_paged_cache(
                self.mcfg, cfg.dp * self.kv_blocks_per_replica,
                self.kv_block_size, kv_dtype=self.kv_dtype)
        return init_cache(self.mcfg, cfg.dp * self.batch_per_replica,
                          self.max_len)

    def cache_template(self, cfg: ElasticConfig):
        """Shape/dtype pytree of the cache for ``cfg`` (no allocation)."""
        return jax.eval_shape(lambda: self.make_cache(cfg))

    # -------------------------------------------------- pooled expert store
    @property
    def _n_moe_layers(self) -> int:
        return self.mcfg.num_layers - self.mcfg.first_k_dense

    def expert_page_nbytes(self) -> int:
        """Bytes of ONE (layer, expert) page across all three banks — the
        unit of vpage migration accounting.  Quantized pools count the int8
        entries plus the three per-page f32 scales that travel with them."""
        from repro.core.costmodel import dtype_bytes
        bpe = dtype_bytes(self.expert_dtype or self.mcfg.dtype)
        scale = 3 * 4 if self.expert_dtype is not None else 0
        return 3 * self.mcfg.d_model * self.mcfg.moe_d_ff * bpe + scale

    def _pooled_index_arrays(self, table, cfg: ElasticConfig,
                             replicas=None, load=None):
        """Host index arrays for the pooled MoE path from a page-table dict.
        ``replicas``/``load``: least-loaded replica-aware serving assignment
        (expert_pages.pooled_layout); scale staging passes neither — the
        staged table already names each expert's kept copy."""
        import math as _math
        from repro.core.expert_pages import pooled_layout
        elm = (_math.ceil(self.mcfg.num_experts / cfg.ndev)
               + self.expert_slot_slack)
        return pooled_layout(table, cfg, self._n_moe_layers,
                             self.mcfg.num_experts, self.expert_pool_pages,
                             replicas=replicas, load=load,
                             slots_per_rank=elm)

    def _pooled_host_params(self, params, cfg: ElasticConfig):
        """Convert freshly initialized dense params to the pooled layout:
        scatter each (layer, expert) bank into its ``initial_place`` page and
        replace the dense [L, E, D, F] banks with index arrays + one global
        pool per bank.  Host-side; the caller device_puts the result."""
        moe = params["blocks"]["moe"]
        banks = {k: np.asarray(moe.pop(k)) for k in ("wi", "wg", "wo")}
        ppd = self.expert_pool_pages
        scales: Dict[str, np.ndarray] = {}
        if self.expert_dtype is not None:
            # symmetric per-page int8: one f32 scale per (layer, expert)
            # page, stored in sidecar banks addressed by the same table
            from repro.kernels.quant import quantize_rows
            for k in list(banks):
                q, s = quantize_rows(jnp.asarray(banks[k]), (-2, -1))
                banks[k] = np.asarray(q)
                scales[k] = np.asarray(s, np.float32)
        pools = {k: np.zeros((cfg.ndev * ppd,) + b.shape[2:], b.dtype)
                 for k, b in banks.items()}
        for k in scales:
            pools[k + "_scale"] = np.zeros((cfg.ndev * ppd,), np.float32)
        for (l, e), ref in self.page_table.active.items():
            row = cfg.slot(ref.device) * ppd + ref.page
            for k in banks:
                pools[k][row] = banks[k][l, e]
            for k in scales:
                pools[k + "_scale"][row] = scales[k][l, e]
        moe.update(self._pooled_index_arrays(self.page_table.active, cfg))
        params["moe_pool"] = pools
        return params

    def params_template(self, cfg: ElasticConfig):
        """Shape/dtype pytree of the parameters an instance for ``cfg``
        binds (dense layout, or the pooled expert store) — what the IMM
        AOT-compiles against, no allocation."""
        from repro.models.model import init_params
        dense = jax.eval_shape(
            lambda: init_params(self.mcfg, jax.random.PRNGKey(0),
                                jnp.dtype(self.mcfg.dtype)))
        if self.expert_mode != "pooled":
            return dense
        if self.expert_pool_pages is None:
            raise RuntimeError(
                "pooled parameter shapes are fixed by the boot config's "
                "pool size — boot() the HMM (or pass expert_pool_pages) "
                "before pre-initializing instances")
        import math as _math
        mcfg = self.mcfg
        moe = dense["blocks"]["moe"]
        shapes = {k: moe.pop(k).shape for k in ("wi", "wg", "wo")}
        dt = jnp.dtype(mcfg.dtype)
        ppd = self.expert_pool_pages
        L, E = self._n_moe_layers, mcfg.num_experts
        elm = _math.ceil(E / cfg.ndev) + self.expert_slot_slack
        i32 = jnp.dtype(jnp.int32)
        moe["tables"] = jax.ShapeDtypeStruct((L, cfg.ndev, elm), i32)
        for k in ("edest", "eslot", "gtable"):
            moe[k] = jax.ShapeDtypeStruct((L, E), i32)
        if self.expert_dtype is not None:
            dt = jnp.dtype(self.expert_dtype)
        dense["moe_pool"] = {
            k: jax.ShapeDtypeStruct((cfg.ndev * ppd,) + shapes[k][2:], dt)
            for k in shapes}
        if self.expert_dtype is not None:
            for k in shapes:
                dense["moe_pool"][k + "_scale"] = jax.ShapeDtypeStruct(
                    (cfg.ndev * ppd,), jnp.dtype(jnp.float32))
        return dense

    # ----------------------------------------------------------------- boot
    @obs.traced("hmm.boot", cat="hmm")
    def boot(self, cfg: ElasticConfig) -> TransferStats:
        """First boot: 'disk load' = host init + device_put (counted as disk
        bytes by the caller's cost model)."""
        from repro.models.model import init_params
        t0 = time.perf_counter()
        assert cfg.tp == self.tp
        mesh = make_instance_mesh(cfg, self.all_devices)
        params = init_params(self.mcfg, jax.random.PRNGKey(self.seed),
                             jnp.dtype(self.mcfg.dtype))
        if self.expert_mode == "pooled" and self.expert_pool_pages is None:
            # fixed for the HMM's lifetime: page indices and pool shapes
            # must agree across every later scale event
            per_dev = self._n_moe_layers * (
                -(-self.mcfg.num_experts // cfg.ndev))
            self.expert_pool_pages = min(
                2 * per_dev, self._n_moe_layers * self.mcfg.num_experts)
            self.page_table.pool_pages = self.expert_pool_pages
        if self.page_table is not None and not self.page_table.active:
            self.page_table.initial_place(cfg)
        if self.expert_mode == "pooled":
            params = self._pooled_host_params(params, cfg)
        shardings = self.param_shardings(params, mesh)
        self.params = jax.tree.map(
            lambda x, s: jax.device_put(x, s), params, shardings)
        cache = self.make_cache(cfg)
        cshard = self.cache_shardings(cache, mesh)
        self.cache = jax.tree.map(lambda x, s: jax.device_put(x, s),
                                  cache, cshard)
        self.active_cfg = cfg
        if self.kv_mode == "paged" and self.kv_blocks is None:
            from repro.serving.kv_blocks import KVBlockManager
            self.kv_blocks = KVBlockManager(cfg.dp,
                                            self.kv_blocks_per_replica,
                                            self.kv_block_size)
        st = TransferStats(wall_s=time.perf_counter() - t0)
        self.last_stats = st
        return st

    # ---------------------------------------------------------------- scale
    def scale(self, new_cfg: ElasticConfig) -> TransferStats:
        """Stage the new configuration's *weights* while the old instance
        keeps serving (the expensive, concurrent part: zero-copy reuse +
        P2P transfers + expert-page remap).  KV-cache growth is deferred to
        ``commit`` — the cache keeps being written by the live instance and,
        per the paper (§5.2), is handed over *shared*, never copied.

        Monolithic wrapper over the incremental/async API (``begin_scale``
        then ``stage_increment`` loop or ``join_staging``, per the staging
        mode).  Byte accounting is identical either way — the same reshard
        calls execute, only the thread they run on differs (asserted in
        tests).

        Returns transfer stats; staged params are attached by the IMM via
        ``attach_staged`` and made active by ``commit``."""
        self.begin_scale(new_cfg)
        if self.staging_mode == "overlap":
            self.join_staging()
        else:
            while self.stage_increment():
                pass
        return self.last_stats

    @obs.traced("hmm.begin_scale", cat="hmm")
    def begin_scale(self, new_cfg: ElasticConfig) -> int:
        """Open a staging session toward ``new_cfg``.

        Builds the per-tensor work list (one unit per parameter leaf — the
        per-layer chunk analogue under this repo's stacked-block layout) and
        returns the number of work units.

        * ``staging="serial"``: no bytes move yet; drive the units with
          ``stage_increment`` — the engine may run decode ticks between
          calls (the legacy tick-interleaved path).
        * ``staging="overlap"``: every unit is submitted to the background
          ``TransferEngine`` immediately and starts moving bytes off-thread;
          drive completion with the non-blocking ``poll_staging`` (or block
          on ``join_staging``).  Staging only *reads* immutable live
          weights, so serving ticks concurrent with in-flight ops are safe
          by construction (core/transfer.py).

        Pooled expert mode stages the page remap here (``stage_remap(
        min_move=True)``) so the pool-bank work units know the exact
        Migration list; each pool bank then moves only those pages.
        """
        assert self.active_cfg is not None
        assert self._stage_work is None, "staging already in progress"
        assert new_cfg.tp == self.tp, "TP is fixed during scaling (§4.1)"
        import re
        t0 = time.perf_counter()
        mesh = make_instance_mesh(new_cfg, self.all_devices)
        if self.expert_mode == "pooled":
            self.last_migrations = self.page_table.stage_remap(
                new_cfg, min_move=True)
            # one layout pass per session; the index work units each pick
            # their array out of it
            self._stage_layout = self._pooled_index_arrays(
                self.page_table.staged, new_cfg)
        shardings = self.param_shardings(self.params, mesh)
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.params)
        shard_leaves = jax.tree.leaves(shardings)
        work = []
        for (path_tuple, leaf), sh in zip(flat, shard_leaves):
            path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path_tuple)
            kind, expert_dim = "reshard", None
            if re.search(r"moe/w[igo]$", path):
                stacked = 1 if "blocks/" in path else 0
                expert_dim = stacked  # regroup experts at page granularity
                kind = "expert_bank"
            elif re.search(r"moe_pool/(w[igo](?:_scale)?)$", path):
                kind = "pool:" + path.rsplit("/", 1)[1]
            elif re.search(r"moe/(tables|edest|eslot|gtable)$", path):
                kind = "index:" + path.rsplit("/", 1)[1]
            work.append((path, leaf, sh, expert_dim, kind))
        self._stage_work = work
        self._stage_cursor = 0
        self._stage_out = []
        self._stage_treedef = treedef
        self._stage_target = (new_cfg, mesh)
        # prep (mesh + shardings + tree walk) counts toward staged wall time,
        # matching the pre-incremental scale() accounting
        self._stage_stats = TransferStats(wall_s=time.perf_counter() - t0)
        if self.staging_mode == "overlap":
            from repro.core.transfer import TransferOp
            self._stage_t0 = t0
            ops = [TransferOp(index=i, label=path,
                              fn=self._make_stage_op(leaf, sh, expert_dim,
                                                     kind, new_cfg, mesh))
                   for i, (path, leaf, sh, expert_dim, kind)
                   in enumerate(work)]
            self._stage_session = self.transfer_engine().submit(ops)
        return len(work)

    def transfer_engine(self):
        """The HMM's background TransferEngine (created lazily, persistent
        across scale events).  Staging ops ride it with
        ``staging="overlap"``; the engine's live KV-block migration copies
        ride it in EVERY staging mode — migration is asynchronous by
        design (DESIGN.md §3, §7)."""
        if self._transfer is None:
            from repro.core.transfer import TransferEngine
            self._transfer = TransferEngine(self.transfer_workers)
        return self._transfer

    @property
    def staging_remaining(self) -> int:
        if self._stage_work is None:
            return 0
        if self._stage_session is not None:
            return self._stage_session.remaining()
        return len(self._stage_work) - self._stage_cursor

    @property
    def staging_in_flight(self) -> bool:
        """True while an overlapped session has transfer ops still pending
        or running on the background engine."""
        return (self._stage_session is not None
                and not self._stage_session.finished())

    def _stage_unit(self, leaf, sh, expert_dim, kind,
                    new_cfg: ElasticConfig, mesh, stats: TransferStats):
        """Execute ONE unit of staging work; returns the staged leaf and
        accumulates byte/count accounting into ``stats``.  Shared verbatim
        by the serial path (caller thread) and the overlapped path
        (TransferEngine workers) so the two modes cannot drift."""
        if kind == "unpark":
            # whole-model cold start: the leaf is a pinned-host array —
            # stream it to its device placement (the H2D lane, priced at
            # hw.h2d_bw by the cost model)
            return self._put_host_leaf(leaf, sh, stats)
        if kind.startswith("pool:"):
            return self._migrate_pool_bank(leaf, new_cfg, mesh, stats,
                                           bank=kind.split(":", 1)[1])
        if kind.startswith("index:"):
            # O(table): the staged index arrays were rebuilt once in
            # begin_scale — no weight bytes move here (host numpy ->
            # device_put, no compiled primitives: worker-thread safe)
            name = kind.split(":", 1)[1]
            arr = np.asarray(self._stage_layout[name], np.int32)
            spec = (P(None, ("dp", "tp"), None) if name == "tables"
                    else P())
            return jax.device_put(arr, NamedSharding(mesh, spec))
        if kind == "expert_bank":
            # dense mode: piecewise regroup; track the expert sub-bytes
            # so dense-reshard vs pooled-remap is directly comparable
            sub = TransferStats()
            out = reshard_with_reuse(leaf, sh, sub, expert_dim=expert_dim)
            sub.expert_p2p_bytes = sub.p2p_bytes
            sub.expert_zero_copy_bytes = sub.zero_copy_bytes
            sub.expert_local_bytes = sub.local_bytes
            stats.merge(sub)
            return out
        return reshard_with_reuse(leaf, sh, stats, expert_dim=expert_dim)

    def _make_stage_op(self, leaf, sh, expert_dim, kind,
                       new_cfg: ElasticConfig, mesh):
        """Closure for one background TransferOp: runs ``_stage_unit`` with
        a private TransferStats, then merges it into the session stats under
        the lock (thread-safe accumulation; addition commutes, so the final
        totals are byte-identical to the serial order)."""
        session_stats = self._stage_stats

        def run():
            sub = TransferStats()
            t0 = time.perf_counter()
            out = self._stage_unit(leaf, sh, expert_dim, kind, new_cfg,
                                   mesh, sub)
            sub.op_s = time.perf_counter() - t0
            with self._stage_lock:
                session_stats.merge(sub)
            return out

        return run

    @obs.traced("hmm.stage_increment", cat="hmm")
    def stage_increment(self, max_tensors: int = 1) -> bool:
        """Serial mode: reshard up to ``max_tensors`` parameter tensors
        toward the target opened by ``begin_scale``.  Safe to interleave
        with serving: staging only *reads* live params (weights are
        immutable during serving; the KV cache is not touched until
        ``commit``).

        Returns True while more increments remain; on the last increment the
        staged tree is assembled, the expert-page remap is staged, and
        ``attach_staged``/``commit`` become legal.

        With ``staging="overlap"`` the work already runs on the background
        TransferEngine — use ``poll_staging``/``join_staging`` instead."""
        assert self._stage_work is not None, "no staging session open"
        if self._stage_session is not None:
            raise RuntimeError(
                "staging session is overlapped (background TransferEngine); "
                "drive it with poll_staging()/join_staging(), not "
                "stage_increment()")
        t0 = time.perf_counter()
        stats = self._stage_stats
        new_cfg, mesh = self._stage_target
        end = min(self._stage_cursor + max(1, max_tensors),
                  len(self._stage_work))
        for path, leaf, sh, expert_dim, kind in self._stage_work[
                self._stage_cursor:end]:
            u0 = time.perf_counter()
            self._stage_out.append(
                self._stage_unit(leaf, sh, expert_dim, kind, new_cfg, mesh,
                                 stats))
            stats.op_s += time.perf_counter() - u0
        self._stage_cursor = end
        stats.wall_s += time.perf_counter() - t0
        if self._stage_cursor < len(self._stage_work):
            return True
        self._finalize_staging()
        return False

    def poll_staging(self) -> bool:
        """Overlap mode: bounded completion poll (<= ~2 ms).  Returns True
        once every background transfer op has finished AND the staged tree
        has been assembled (``attach_staged``/``commit`` legal); False
        while ops are still in flight.  A failed op aborts the whole
        session (staged pages unwound) and re-raises.

        The poll donates a tiny bounded wait rather than returning
        instantly: a serve loop spinning on an *idle* engine is a pure
        Python busy-loop that would otherwise starve the worker threads of
        the GIL (real decode ticks release it inside XLA, so a busy engine
        needs no such courtesy)."""
        if self._stage_work is None:
            return self.staged is not None
        if self._stage_session is None:
            raise RuntimeError(
                "staging session is serial; drive it with stage_increment()")
        sess = self._stage_session
        if not sess.finished():
            sess.join(timeout=0.002)   # bounded yield to the workers
            if not sess.finished():
                return False
        failed = sess.failed_ops()
        if failed:
            err = failed[0].error
            self.abort()
            raise RuntimeError(
                f"staging transfer op {failed[0].label!r} failed "
                f"({len(failed)} op(s) total); session aborted") from err
        self._stage_out = [op.result for op in sess.ops]
        # overlap wall-clock = begin_scale() -> last op completion: the
        # staging *window* the background engine shrinks (op_s holds the
        # serial-equivalent Σ of per-op times for the efficiency ratio)
        self._stage_stats.wall_s = max(sess.last_done_t - self._stage_t0,
                                       self._stage_stats.wall_s)
        self._finalize_staging()
        return True

    def join_staging(self) -> bool:
        """Overlap mode: block until the session completes, then finalize
        (the COMMITTING/monolithic barrier).  Returns True if a staged tree
        is ready, False if no session was open and nothing is staged."""
        if self._stage_work is None:
            return self.staged is not None
        if self._stage_session is None:
            raise RuntimeError(
                "staging session is serial; drive it with stage_increment()")
        self._stage_session.join()
        return self.poll_staging()

    def _finalize_staging(self):
        """Assemble the staged tree + stage the page remap (dense
        bookkeeping only — pooled staged it in begin_scale; dense arrays
        take the contiguous expert_owner layout, so the table records
        min_move=False placement to stay truthful)."""
        t0 = time.perf_counter()
        stats = self._stage_stats
        new_cfg, mesh = self._stage_target
        new_params = jax.tree_util.tree_unflatten(
            self._stage_treedef, self._stage_out)
        if (self.page_table is not None and self.page_table.staged is None
                and not self._unpark):
            # unpark built a FRESH table (initial_place at the target) in
            # begin_unpark — there is no live placement to remap from
            self.page_table.stage_remap(new_cfg, min_move=False)
        self.staged = (new_cfg, mesh, new_params)
        stats.wall_s += time.perf_counter() - t0
        self.last_stats = stats
        self._reset_stage_session()

    def _migrate_pool_bank(self, leaf, new_cfg: ElasticConfig, mesh,
                           stats: TransferStats, bank: str = ""):
        """Rebuild one pooled weight bank for ``new_cfg``: surviving devices'
        pool slices are reused (migrated-in pages written at their staged
        slots), new devices start from zeros, and exactly the staged
        Migration list crosses devices — one ``jax.device_put`` per page,
        the paper's p2p-copy primitive at vpage granularity.  A migration
        whose ``src`` lives in the pinned-host tier (``src.device == HOST``)
        reads its row from the HMM host pool instead: those bytes ride the
        H2D path and are accounted in ``expert_h2d_bytes``, NOT
        ``p2p_bytes`` — the cold set costs zero expert P2P (DESIGN.md §10).

        Pure memory ops only (host numpy assembly + device_put, no compiled
        scatter/stack): worker-thread safe on the TransferEngine.  A device
        slice that receives no migrated pages keeps its live buffer — the
        zero-copy alias is preserved."""
        from repro.core.expert_pages import HOST
        ppd = self.expert_pool_pages
        row_shape = leaf.shape[1:]
        row_bytes = int(np.prod(row_shape)) * leaf.dtype.itemsize
        # keyed by physical device object: page-table/config device ints are
        # LOGICAL indices into all_devices, which need not be jax.devices()
        old_shard = {sh.device: sh.data for sh in leaf.addressable_shards}
        migs_by_dst: Dict[int, List] = defaultdict(list)
        for m in self.last_migrations:
            migs_by_dst[m.dst.device].append(m)
        # pages that stay put are this bank's zero-copy reuse — an expert
        # kept in place via any already-resident copy (primary OR replica)
        staged, active = self.page_table.staged, self.page_table.active
        replicas = self.page_table.replicas
        unchanged = sum(
            1 for k, r in active.items()
            if staged.get(k) == r or staged.get(k) in replicas.get(k, ()))
        stats.zero_copy_bytes += unchanged * row_bytes
        stats.zero_copy_count += unchanged
        stats.expert_zero_copy_bytes += unchanged * row_bytes

        shape = (new_cfg.ndev * ppd,) + row_shape
        sharding = NamedSharding(mesh, P(("dp", "tp"), *([None] *
                                                         len(row_shape))))
        target = sharding.devices_indices_map(shape)
        src_rows: Dict[int, np.ndarray] = {}   # host view of source slices

        def rows_of(logical_dev: int) -> np.ndarray:
            if logical_dev not in src_rows:
                src_rows[logical_dev] = np.asarray(
                    old_shard[self.all_devices[logical_dev]])
            return src_rows[logical_dev]

        out = []
        for dev in sharding.addressable_devices:
            rank = (target[dev][0].start or 0) // ppd
            logical = new_cfg.devices[rank]    # dev == all_devices[logical]
            local = old_shard.get(dev)
            migs = migs_by_dst.get(logical)
            if migs:
                base = (np.array(local) if local is not None
                        else np.zeros((ppd,) + row_shape, leaf.dtype))
                for m in migs:
                    if m.src.device == HOST:
                        base[m.dst.page] = \
                            self._expert_host_pool[(m.layer, m.expert)][bank]
                        stats.expert_h2d_bytes += row_bytes
                    else:
                        base[m.dst.page] = rows_of(m.src.device)[m.src.page]
                        stats.p2p_bytes += row_bytes
                        stats.p2p_count += 1
                        stats.expert_p2p_bytes += row_bytes
                local = jax.device_put(base, dev)
            elif local is None:
                local = jax.device_put(
                    np.zeros((ppd,) + row_shape, leaf.dtype), dev)
            out.append(local)
        return jax.make_array_from_single_device_arrays(shape, sharding, out)

    def _put_host_leaf(self, arr: np.ndarray, sh: NamedSharding,
                       stats: TransferStats):
        """Stream ONE pinned-host array to devices under ``sh`` — the unpark
        work unit.  Pure memory ops (numpy slicing + one ``jax.device_put``
        per device shard, no compiled primitives), so it is safe on
        TransferEngine worker threads concurrently with the IMM's AOT
        compile on the serve thread (STAGING ∥ COMPILING)."""
        arr = np.asarray(arr)
        shape = arr.shape
        target = sh.devices_indices_map(shape)
        out = []
        for dev in sh.addressable_devices:
            sub = np.ascontiguousarray(arr[target[dev]])
            stats.h2d_bytes += sub.nbytes
            out.append(jax.device_put(sub, dev))
        return jax.make_array_from_single_device_arrays(shape, sh, out)

    def _reset_stage_session(self):
        self._stage_work = None
        self._stage_cursor = 0
        self._stage_out = []
        self._stage_treedef = None
        self._stage_target = None
        self._stage_layout = None
        self._stage_session = None

    def _grow_cache(self, new_cfg: ElasticConfig, mesh: Mesh,
                    stats: TransferStats):
        """Reuse surviving replicas' KV shards; zero-init new replicas.

        Works unchanged for both layouts: dense rows shard the batch axis,
        the paged pool shards the block axis — either way surviving shards
        keep their (index, device) key and are adopted zero-copy."""
        template = self.cache_template(new_cfg)
        cshard = self.cache_shardings(template, mesh)

        def grow(old_leaf, tmpl, sh):
            shape = tmpl.shape
            target = sh.devices_indices_map(shape)
            old_by_idx = {}
            for s in old_leaf.addressable_shards:
                old_by_idx.setdefault(_idx_key(s.index), []).append(
                    (s.device, s.data))
            out = []
            for dev in sh.addressable_devices:
                key = _idx_key(target[dev])
                holders = old_by_idx.get(key, [])
                same = [h for h in holders if h[0] == dev]
                if same and same[0][1].shape == tuple(
                        (i.stop or shape[n]) - (i.start or 0)
                        for n, i in enumerate(target[dev])):
                    data = same[0][1]
                    stats.zero_copy_bytes += data.nbytes
                    stats.zero_copy_count += 1
                else:
                    shard_shape = tuple(
                        (i.stop if i.stop is not None else shape[n])
                        - (i.start or 0)
                        for n, i in enumerate(target[dev]))
                    data = jax.device_put(
                        jnp.zeros(shard_shape, tmpl.dtype), dev)
                    stats.init_bytes += data.nbytes
                out.append(data)
            return jax.make_array_from_single_device_arrays(shape, sh, out)

        return jax.tree.map(grow, self.cache, template, cshard)

    # --------------------------------------------------------------- attach
    def attach_staged(self):
        """Zero-copy handles for the staged instance (IMM open_tensor)."""
        assert self.staged is not None
        new_cfg, mesh, params = self.staged
        return new_cfg, mesh, params, self.cache

    def attach_active(self):
        return (self.active_cfg,
                make_instance_mesh(self.active_cfg, self.all_devices),
                self.params, self.cache)

    @obs.traced("hmm.commit", cat="hmm")
    def commit(self, live_cache=None) -> TransferStats:
        """Switchover: staged weights become active, and the *live* KV cache
        (surviving slots' buffers reused as-is, new slots zero-init) is grown
        to the new slot count.  Old-only buffers become unreferenced — the
        paper's deferred FREE.

        Overlap mode: committing is a barrier — any transfer ops still in
        flight are joined (and the tree finalized) before the switchover."""
        if self._stage_session is not None:
            self.join_staging()
        assert self.staged is not None
        new_cfg, mesh, params = self.staged
        stats = TransferStats()
        t0 = time.perf_counter()
        if self._unpark:
            return self._commit_unpark(new_cfg, mesh, params, stats, t0)
        if live_cache is not None:
            self.cache = live_cache
        self.cache = self._grow_cache(new_cfg, mesh, stats)
        if self.kv_blocks is not None:
            # pool partitions track DP replicas; block ids of survivors are
            # unchanged, so live block tables need no translation.  Shrink
            # is only legal once scale-down evacuation is complete (live
            # blocks migrated onto survivors or drained) — the manager
            # refuses while partitions hold blocks or migrations are
            # pending, so commit cannot strand a live sequence.
            if new_cfg.dp >= self.kv_blocks.num_partitions:
                self.kv_blocks.grow_partitions(new_cfg.dp)
            else:
                self.kv_blocks.shrink_partitions(new_cfg.dp)
        self.active_cfg = new_cfg
        self.params = params
        self.staged = None
        if self.page_table is not None and self.page_table.staged is not None:
            self.page_table.commit()
        stats.wall_s = time.perf_counter() - t0
        if self.last_stats is not None:
            self.last_stats.merge(stats)
        return stats

    @obs.traced("hmm.abort", cat="hmm")
    def abort(self):
        """Abandon any staged state — including a staging session with
        transfer ops still in flight on the background engine.

        Cancel-or-join: pending ops never start, running ops are joined
        *before* the page table unwinds, so no worker can observe the
        post-abort table.  Idempotent; leaves zero staged-page leaks
        (``ExpertPageTable.abort`` frees staged-only pages exactly once)."""
        if self._stage_session is not None:
            self._stage_session.cancel()
        self.staged = None
        self.last_migrations = None
        self._reset_stage_session()
        self._unpark = False
        self._unpark_table = None
        if self.page_table is not None:
            self.page_table.abort()

    # -------------------------------------------------------- scale-to-zero
    @obs.traced("hmm.park", cat="hmm")
    def park(self) -> TransferStats:
        """Scale to ZERO devices: snapshot EVERY weight bank into the
        pinned-host tier and drop all device state — the whole-model
        generalization of the PR-8 cold-expert host pool (DESIGN.md §12).

        Dense banks are pulled back as full logical host arrays; pooled
        expert banks are snapshotted per (layer, expert) page (already-
        demoted host-tier experts are absorbed from ``_expert_host_pool``
        without re-copying), so unpark can rebuild the pools at ANY target
        device count.  The KV cache is DISCARDED — park is only legal once
        in-flight sequences have drained (asserted by the callers); unpark
        allocates a fresh pool.

        Requires no staging/rebalance session in flight.  Returns stats
        with the snapshot accounted in ``d2h_bytes``."""
        assert self.active_cfg is not None, "nothing to park"
        assert self._stage_work is None and self.staged is None, \
            "park is mutually exclusive with scale staging"
        assert self._rebalance_ops is None, \
            "park is mutually exclusive with rebalancing"
        from repro.core.expert_pages import HOST
        t0 = time.perf_counter()
        stats = TransferStats()
        cfg = self.active_cfg
        pooled = self.expert_mode == "pooled"
        pages: Optional[Dict[Tuple[int, int], Dict[str, np.ndarray]]] = None
        if pooled:
            # per-page extraction: only live rows cross D2H, never the pool
            # zeros (accounting mirrors _make_rebalance_fetch: one
            # expert_page_nbytes per device-resident page)
            pages = {}
            ppd = self.expert_pool_pages
            pools = self.params["moe_pool"]
            shards = {k: {sh.device: sh.data for sh in l.addressable_shards}
                      for k, l in pools.items()}
            host_view: Dict[Tuple[str, int], np.ndarray] = {}

            def bank_rows(k: str, logical: int) -> np.ndarray:
                if (k, logical) not in host_view:
                    host_view[(k, logical)] = np.asarray(
                        shards[k][self.all_devices[logical]])
                return host_view[(k, logical)]

            page_bytes = self.expert_page_nbytes()
            for (l, e), ref in self.page_table.active.items():
                if ref.device == HOST:
                    pages[(l, e)] = {k: np.array(v) for k, v
                                     in self._expert_host_pool[(l, e)].items()}
                else:
                    pages[(l, e)] = {
                        k: np.array(bank_rows(k, ref.device)[ref.page])
                        for k in shards}
                    stats.d2h_bytes += page_bytes
                    stats.expert_d2h_bytes += page_bytes
            host_tree = {k: v for k, v in self.params.items()
                         if k != "moe_pool"}
        else:
            host_tree = self.params
        host_tree = jax.tree.map(np.asarray, host_tree)
        for leaf in jax.tree.leaves(host_tree):
            stats.d2h_bytes += leaf.nbytes
        total = (sum(leaf.nbytes for leaf in jax.tree.leaves(host_tree))
                 + (sum(r.nbytes for p in pages.values() for r in p.values())
                    if pages else 0))
        self._parked = {"tree": host_tree, "pages": pages, "cfg": cfg,
                        "bytes": total}
        self.params = None
        self.cache = None
        self.kv_blocks = None
        self.active_cfg = None
        self._expert_host_pool = {}
        if self.page_table is not None:
            # reset to an empty table: no device placement exists while
            # parked; unpark initial_places a fresh one at the target
            self.page_table = ExpertPageTable(
                self._n_moe_layers, self.mcfg.num_experts,
                pool_pages_per_device=(self.expert_pool_pages or 0
                                       if pooled else 0),
                host_pool_pages=self.expert_host_pages)
        stats.wall_s = time.perf_counter() - t0
        self.last_stats = stats
        return stats

    @property
    def parked(self) -> bool:
        return self._parked is not None

    def parked_bytes(self) -> int:
        """Pinned-host bytes held by the whole-model parked snapshot."""
        return self._parked["bytes"] if self._parked is not None else 0

    @obs.traced("hmm.begin_unpark", cat="hmm")
    def begin_unpark(self, cfg: ElasticConfig) -> int:
        """Open a staging session that streams the parked snapshot back to
        devices (cold start from the pinned-host tier).  Exactly the
        ``begin_scale`` discipline — serial mode drives it with
        ``stage_increment``, overlap mode submits every unit to the
        background ``TransferEngine`` and polls with ``poll_staging`` while
        the IMM's AOT compile runs on the serve thread (STAGING ∥
        COMPILING) — so the whole-model H2D window hides the compile.

        ``commit`` then allocates a fresh KV cache/block pool and the model
        is live again; tokens are bit-identical to a never-parked run (the
        snapshot round-trips every byte).  Returns the work-unit count."""
        assert self._parked is not None, "not parked"
        assert self.active_cfg is None
        assert self._stage_work is None, "staging already in progress"
        assert cfg.tp == self.tp, "TP is fixed across park/unpark (§4.1)"
        t0 = time.perf_counter()
        mesh = make_instance_mesh(cfg, self.all_devices)
        snap = self._parked
        # fresh container copy; leaves stay shared with the host snapshot
        params = jax.tree.map(lambda x: x, snap["tree"])
        table = None
        pooled = self.expert_mode == "pooled"
        if self.page_table is not None:
            table = ExpertPageTable(
                self._n_moe_layers, self.mcfg.num_experts,
                pool_pages_per_device=(self.expert_pool_pages or 0
                                       if pooled else 0),
                host_pool_pages=self.expert_host_pages)
            table.initial_place(cfg)
        if pooled:
            pages = snap["pages"]
            ppd = self.expert_pool_pages
            sample = next(iter(pages.values()))
            pools = {k: np.zeros((cfg.ndev * ppd,) + row.shape, row.dtype)
                     for k, row in sample.items()}
            for (l, e), ref in table.active.items():
                row = cfg.slot(ref.device) * ppd + ref.page
                for k in pools:
                    pools[k][row] = pages[(l, e)][k]
            params["moe_pool"] = pools
            moe = params["blocks"]["moe"]
            for name, arr in self._pooled_index_arrays(
                    table.active, cfg).items():
                moe[name] = np.asarray(arr, np.int32)
        shardings = self.param_shardings(params, mesh)
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        shard_leaves = jax.tree.leaves(shardings)
        work = []
        for (path_tuple, leaf), sh in zip(flat, shard_leaves):
            path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path_tuple)
            work.append((path, leaf, sh, None, "unpark"))
        self._stage_work = work
        self._stage_cursor = 0
        self._stage_out = []
        self._stage_treedef = treedef
        self._stage_target = (cfg, mesh)
        self._unpark = True
        self._unpark_table = table
        self._stage_stats = TransferStats(wall_s=time.perf_counter() - t0)
        if pooled:
            # expert sub-accounting: the live-page share of the pool H2D
            # stream (h2d_bytes counts whole pool slices, zeros included —
            # that is what actually crosses the bus)
            self._stage_stats.expert_h2d_bytes += (
                len(snap["pages"]) * self.expert_page_nbytes())
        if self.staging_mode == "overlap":
            from repro.core.transfer import TransferOp
            self._stage_t0 = t0
            ops = [TransferOp(index=i, label=f"unpark:{path}",
                              fn=self._make_stage_op(leaf, sh, expert_dim,
                                                     kind, cfg, mesh))
                   for i, (path, leaf, sh, expert_dim, kind)
                   in enumerate(work)]
            self._stage_session = self.transfer_engine().submit(ops)
        return len(work)

    def _commit_unpark(self, new_cfg: ElasticConfig, mesh, params,
                       stats: TransferStats, t0: float) -> TransferStats:
        """Commit tail of an unpark session: adopt the streamed weights,
        allocate a FRESH KV cache/block pool (nothing survived the park —
        the INIT lane of the cost model), and swap in the fresh page
        table built at ``begin_unpark``."""
        cache = self.make_cache(new_cfg)
        cshard = self.cache_shardings(cache, mesh)
        self.cache = jax.tree.map(lambda x, s: jax.device_put(x, s),
                                  cache, cshard)
        for leaf in jax.tree.leaves(self.cache):
            stats.init_bytes += leaf.nbytes
        if self.kv_mode == "paged":
            from repro.serving.kv_blocks import KVBlockManager
            self.kv_blocks = KVBlockManager(new_cfg.dp,
                                            self.kv_blocks_per_replica,
                                            self.kv_block_size)
        self.active_cfg = new_cfg
        self.params = params
        self.staged = None
        if self._unpark_table is not None:
            self.page_table = self._unpark_table
        self._unpark = False
        self._unpark_table = None
        self._parked = None
        stats.wall_s = time.perf_counter() - t0
        if self.last_stats is not None:
            self.last_stats.merge(stats)
        return stats

    # ------------------------------------------------------------ rebalance
    def begin_rebalance(self, actions, load=None) -> int:
        """Open a skew-rebalance session (DESIGN.md §10): stage the page
        allocations, then fetch the bytes each replicate/demote op needs on
        the background TransferEngine (D2H row reads of immutable weights —
        safe concurrent with serving, like scale staging).

        ``actions``: see :meth:`ExpertPageTable.stage_rebalance`.
        ``load``: optional [L_moe, E] routing-count snapshot; stored for the
        replica-aware serving assignment rebuilt at commit.

        Returns the number of background transfer ops submitted.  Drive
        with ``poll_rebalance`` then ``commit_rebalance``, or unwind with
        ``abort_rebalance`` — an abort-in-flight conserves both tiers."""
        assert self.expert_mode == "pooled", \
            "rebalance requires expert_mode='pooled'"
        assert self._stage_work is None and self.staged is None, \
            "rebalance is mutually exclusive with scale staging"
        assert self._rebalance_ops is None, "rebalance already in progress"
        from repro.core.transfer import TransferOp
        self._rebalance_t0 = time.perf_counter()
        ops = self.page_table.stage_rebalance(actions)
        self._rebalance_ops = ops
        self._rebalance_load = (np.asarray(load, np.float64)
                                if load is not None else None)
        self._rebalance_stats = TransferStats()
        work = [TransferOp(index=i,
                           label=f"rebalance:{op.kind}:{op.layer}.{op.expert}",
                           fn=self._make_rebalance_fetch(op))
                for i, op in enumerate(ops)
                if op.kind in ("replicate", "demote")]
        self._rebalance_session = (self.transfer_engine().submit(work)
                                   if work else None)
        return len(work)

    def _make_rebalance_fetch(self, op):
        """Closure for one background fetch: D2H-copy the op's source page
        out of every pool bank (pure ``np.asarray`` reads — no compiled
        primitives, worker-thread safe) and return {bank: row}.  Bytes are
        accounted per page (``expert_page_nbytes``), merged under the
        staging lock like scale-staging ops."""
        banks = self.params["moe_pool"]
        page_bytes = self.expert_page_nbytes()
        stats = self._rebalance_stats
        src, kind = op.src, op.kind
        phys = self.all_devices[src.device]

        def run():
            t0 = time.perf_counter()
            rows = {}
            for name, leaf in banks.items():
                shard = next(sh for sh in leaf.addressable_shards
                             if sh.device == phys)
                rows[name] = np.array(np.asarray(shard.data)[src.page])
            sub = TransferStats()
            if kind == "demote":
                sub.expert_d2h_bytes = page_bytes
            else:
                sub.expert_replica_bytes = page_bytes
            sub.op_s = time.perf_counter() - t0
            with self._stage_lock:
                stats.merge(sub)
            return rows

        return run

    @property
    def rebalance_in_flight(self) -> bool:
        return (self._rebalance_session is not None
                and not self._rebalance_session.finished())

    def poll_rebalance(self) -> bool:
        """Bounded completion poll (<= ~2 ms), mirroring ``poll_staging``.
        True once every fetch op has finished (``commit_rebalance`` legal);
        a failed op aborts the session (pools conserved) and re-raises."""
        if self._rebalance_ops is None:
            return False
        sess = self._rebalance_session
        if sess is not None:
            if not sess.finished():
                sess.join(timeout=0.002)
                if not sess.finished():
                    return False
            failed = sess.failed_ops()
            if failed:
                err = failed[0].error
                self.abort_rebalance()
                raise RuntimeError(
                    f"rebalance fetch op {failed[0].label!r} failed "
                    f"({len(failed)} op(s) total); session aborted") from err
        return True

    @obs.traced("hmm.commit_rebalance", cat="hmm")
    def commit_rebalance(self, load=None) -> TransferStats:
        """Serve-thread switchover of a rebalance session: write replica
        rows into the pool banks (one rebuilt slice per receiving device),
        publish demoted rows to the pinned-host pool, free dropped/promoted
        pages, and rebuild the serving index arrays replica-aware
        (least-loaded assignment over ``load`` — defaults to the snapshot
        captured at ``begin_rebalance``).

        The params tree is updated IN PLACE, so the engine bound to it
        picks the new layout up on its next tick; array shapes are
        unchanged (slack-fixed table width), so every AOT-compiled
        executable stays valid.  Every copy is byte-identical, so tokens
        are bit-identical before/after."""
        assert self._rebalance_ops is not None, "no rebalance session open"
        if self._rebalance_session is not None:
            self._rebalance_session.join()
            if not self.poll_rebalance():     # surfaces failed ops
                raise RuntimeError("rebalance session did not finish")
        t0 = time.perf_counter()
        ops = self._rebalance_ops
        results = {}
        if self._rebalance_session is not None:
            for top in self._rebalance_session.ops:
                results[top.index] = top.result
        stats = self._rebalance_stats
        cfg = self.active_cfg
        ppd = self.expert_pool_pages
        if load is None:
            load = self._rebalance_load

        # 0) dry-run the post-commit layout BEFORE mutating anything: a
        # slot-overflow (replication skew beyond the table-width slack)
        # must abort the whole session, never half-commit it
        preview = self.page_table.clone()
        preview.commit_rebalance()
        try:
            layout = self._pooled_index_arrays(
                preview.active, cfg, replicas=preview.replicas, load=load)
        except ValueError:
            self.abort_rebalance()
            raise

        # 1) replica rows -> rebuilt pool-bank slices on receiving devices
        by_dev: Dict[int, List] = defaultdict(list)
        for i, op in enumerate(ops):
            if op.kind == "replicate":
                by_dev[op.dst.device].append((op.dst.page, results[i]))
        if by_dev:
            pools = self.params["moe_pool"]
            for bank in list(pools):
                leaf = pools[bank]
                target = leaf.sharding.devices_indices_map(leaf.shape)
                out = []
                for dev in leaf.sharding.addressable_devices:
                    rank = (target[dev][0].start or 0) // ppd
                    logical = cfg.devices[rank]
                    shard = next(sh.data for sh in leaf.addressable_shards
                                 if sh.device == dev)
                    if logical in by_dev:
                        base = np.array(shard)
                        for page, rows in by_dev[logical]:
                            base[page] = rows[bank]
                        shard = jax.device_put(base, dev)
                    out.append(shard)
                pools[bank] = jax.make_array_from_single_device_arrays(
                    leaf.shape, leaf.sharding, out)

        # 2) demoted bytes -> host pool; promoted entries retire
        for i, op in enumerate(ops):
            if op.kind == "demote":
                self._expert_host_pool[op.key] = results[i]
            elif op.kind == "promote":
                self._expert_host_pool.pop(op.key, None)

        # 3) table switchover (frees drop_replica / promote pages)
        self.page_table.commit_rebalance()

        # 4) replica-aware serving assignment -> fresh index arrays
        # (precomputed in step 0 from the preview table)
        mesh = make_instance_mesh(cfg, self.all_devices)
        moe = self.params["blocks"]["moe"]
        for name, arr in layout.items():
            spec = (P(None, ("dp", "tp"), None) if name == "tables"
                    else P())
            moe[name] = jax.device_put(np.asarray(arr, np.int32),
                                       NamedSharding(mesh, spec))

        sess = self._rebalance_session
        if sess is not None:
            stats.wall_s = max(sess.last_done_t - self._rebalance_t0, 0.0)
        stats.wall_s += time.perf_counter() - t0
        self.last_rebalance_stats = stats
        self._rebalance_ops = None
        self._rebalance_session = None
        self._rebalance_stats = None
        self._rebalance_load = None
        return stats

    @obs.traced("hmm.abort_rebalance", cat="hmm")
    def abort_rebalance(self):
        """Cancel-or-join, then unwind the rebalance session: freshly
        allocated pages return to their pools and no demoted bytes are
        published — device AND host tiers end exactly as before
        ``begin_rebalance``.  Idempotent."""
        if self._rebalance_session is not None:
            self._rebalance_session.cancel()
        self._rebalance_session = None
        self._rebalance_ops = None
        self._rebalance_stats = None
        self._rebalance_load = None
        if self.page_table is not None:
            self.page_table.abort_rebalance()

    def host_tier_bytes(self) -> int:
        """Resident bytes of the pinned-host cold tier: demoted expert
        pages plus, when parked, the whole-model snapshot."""
        return (len(self._expert_host_pool) * self.expert_page_nbytes()
                + self.parked_bytes())

    def update_cache(self, cache):
        """The active instance writes back its KV state after each step."""
        self.cache = cache

    # ------------------------------------------------------------- metrics
    def resident_bytes_per_device(self) -> Dict[int, int]:
        out: Dict[int, int] = defaultdict(int)
        seen = set()
        for tree in (self.params, self.cache):
            if tree is None:
                continue
            for leaf in jax.tree.leaves(tree):
                for sh in leaf.addressable_shards:
                    ptr = sh.data.unsafe_buffer_pointer()
                    if ptr in seen:
                        continue  # aliased buffer counted once
                    seen.add(ptr)
                    out[sh.device.id] += sh.data.nbytes
        return dict(out)
