"""ElasticMoE core: the paper's contribution as a composable JAX module."""
from repro.core.coordinator import LoadEstimator, ScalingPolicy
from repro.core.elastic_engine import ElasticServer, ScaleEvent
from repro.core.expert_pages import ExpertPageTable, Migration, PageRef
from repro.core.hmm import HMM, TransferStats, make_instance_mesh
from repro.core.imm import IMM, StandbyInstance
from repro.core.scaling_plan import (Op, STRATEGIES, ScalingPlan, placement,
                                     plan_elastic, plan_elastic_paged)
from repro.core.topology import (ElasticConfig, TensorDesc, expert_owner,
                                 kv_cache_bytes, model_tensors)

__all__ = [
    "ElasticServer", "ScaleEvent", "HMM", "IMM", "TransferStats",
    "StandbyInstance", "ExpertPageTable", "Migration", "PageRef",
    "LoadEstimator", "ScalingPolicy", "ElasticConfig", "TensorDesc",
    "ScalingPlan", "Op", "STRATEGIES", "plan_elastic", "plan_elastic_paged",
    "placement", "expert_owner", "kv_cache_bytes", "model_tensors",
    "make_instance_mesh",
]
