"""Coordinator (paper §4.3): request routing, SLO-aware load estimation, and
scaling orchestration with drain-free switchover."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional

from repro.serving.metrics import SLO, meets_slo
from repro.serving.workload import Request


@dataclasses.dataclass
class ScalingPolicy:
    """SLO-aware load estimator (§4.3): scale up when windowed attainment
    drops below ``low_watermark``; scale down when it stays above
    ``high_watermark`` with slack capacity.

    ``confirm_s``: the raw up/down signal must persist *continuously* for
    this many seconds before a direction is emitted (0 = act immediately,
    the pre-driver behaviour).  The closed-loop driver polls ``decide``
    every tick, so a count of consecutive calls would be satisfied by a
    momentary blip; wall-clock persistence is the actual anti-flapping
    control (DESIGN.md §6), together with ``cooldown_s``.
    ``idle_utilization``: utilization below which scale-down is considered.
    """
    slo: SLO
    low_watermark: float = 0.90
    high_watermark: float = 0.98
    window: int = 32                  # requests per decision window
    cooldown_s: float = 20.0
    queue_scale_up: int = 8           # also scale up on queue backlog
    confirm_s: float = 0.0
    idle_utilization: float = 0.4


class LoadEstimator:
    def __init__(self, policy: ScalingPolicy):
        self.policy = policy
        self.recent: Deque[bool] = deque(maxlen=policy.window)
        self.last_action_t: float = -1e9
        self._sig_dir: Optional[str] = None
        self._sig_t0: float = 0.0

    def record(self, req: Request):
        ok = meets_slo(req, self.policy.slo)
        if ok is not None:
            self.recent.append(ok)

    def attainment(self) -> Optional[float]:
        if len(self.recent) < max(4, self.policy.window // 4):
            return None
        return sum(self.recent) / len(self.recent)

    def _raw_signal(self, queue_depth: int,
                    utilization: float) -> Optional[str]:
        att = self.attainment()
        if queue_depth >= self.policy.queue_scale_up or \
                (att is not None and att < self.policy.low_watermark):
            return "up"
        if att is not None and att >= self.policy.high_watermark \
                and utilization < self.policy.idle_utilization \
                and queue_depth == 0:
            return "down"
        return None

    def decide(self, now: float, queue_depth: int,
               utilization: float) -> Optional[str]:
        """Returns 'up' | 'down' | None.  A non-None return commits the
        decision: the cooldown starts and the attainment window resets."""
        if now - self.last_action_t < self.policy.cooldown_s:
            # drop any tracked signal: confirm_s demands CONTINUOUS
            # presence, and presence during a cooldown is unobserved — a
            # confirm timer surviving the cooldown would let the first
            # post-cooldown blip instantly satisfy confirm_s even though
            # the signal flapped in between
            self._sig_dir = None
            return None
        sig = self._raw_signal(queue_depth, utilization)
        if sig is None:
            self._sig_dir = None
            return None
        if sig != self._sig_dir:
            self._sig_dir, self._sig_t0 = sig, now
        if now - self._sig_t0 < self.policy.confirm_s:
            return None
        self.last_action_t = now
        self.recent.clear()
        self._sig_dir = None
        return sig
