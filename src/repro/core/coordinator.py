"""Coordinator (paper §4.3): request routing, SLO-aware load estimation, and
scaling orchestration with drain-free switchover."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional

from repro.serving.metrics import SLO, meets_slo
from repro.serving.workload import Request


@dataclasses.dataclass
class ScalingPolicy:
    """SLO-aware load estimator (§4.3): scale up when windowed attainment
    drops below ``low_watermark``; scale down when it stays above
    ``high_watermark`` with slack capacity."""
    slo: SLO
    low_watermark: float = 0.90
    high_watermark: float = 0.98
    window: int = 32                  # requests per decision window
    cooldown_s: float = 20.0
    queue_scale_up: int = 8           # also scale up on queue backlog


class LoadEstimator:
    def __init__(self, policy: ScalingPolicy):
        self.policy = policy
        self.recent: Deque[bool] = deque(maxlen=policy.window)
        self.last_action_t: float = -1e9

    def record(self, req: Request):
        ok = meets_slo(req, self.policy.slo)
        if ok is not None:
            self.recent.append(ok)

    def attainment(self) -> Optional[float]:
        if len(self.recent) < max(4, self.policy.window // 4):
            return None
        return sum(self.recent) / len(self.recent)

    def decide(self, now: float, queue_depth: int,
               utilization: float) -> Optional[str]:
        """Returns 'up' | 'down' | None."""
        if now - self.last_action_t < self.policy.cooldown_s:
            return None
        att = self.attainment()
        if queue_depth >= self.policy.queue_scale_up or \
                (att is not None and att < self.policy.low_watermark):
            self.last_action_t = now
            self.recent.clear()
            return "up"
        if att is not None and att >= self.policy.high_watermark \
                and utilization < 0.4 and queue_depth == 0:
            self.last_action_t = now
            self.recent.clear()
            return "down"
        return None
