"""Hardware cost model: converts scaling plans into projected wall-clock
latency / downtime / peak memory at *paper scale*.

This container has no NPUs/TPUs, so — as recorded in DESIGN.md §2 — all byte
counts (zero-copy / P2P / disk / init) are exact outputs of the planner,
and this model multiplies them by CloudMatrix384-like constants to reproduce
the paper's Figures 7/8/12 and Tables 1/3.  Constants are calibrated once
against Table 1 (DeepSeek-V2-Lite DP3->DP4: ElasticMoE 2.43 s, -HCCL 10.4 s,
-PreInit 62.8 s, -ZeroCopy 67.4 s with 67.4 s downtime) and then reused for
every other experiment unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.scaling_plan import Op, ScalingPlan

#: element sizes for every dtype name the repo's knobs accept — THE single
#: source of byte-per-element truth (ISSUE 9).  topology.model_tensors /
#: kv_cache_bytes, serving.kv_blocks.block_bytes, the HMM's page accounting
#: and the benchmarks all resolve element sizes here instead of scattering
#: hard-coded ``* 2`` / ``* 4`` byte math.
DTYPE_BYTES = {
    "int8": 1, "uint8": 1, "s8": 1, "u8": 1, "float8": 1,
    "bfloat16": 2, "float16": 2, "bf16": 2, "f16": 2,
    "float32": 4, "int32": 4, "f32": 4, "s32": 4,
    "float64": 8, "int64": 8, "f64": 8, "s64": 8,
}


def dtype_bytes(dtype) -> int:
    """Bytes per element of ``dtype`` (a name string or anything numpy's
    dtype constructor accepts).  ``None`` means float32 — the repo's default
    storage dtype."""
    if dtype is None:
        return 4
    name = getattr(dtype, "name", dtype)
    if isinstance(name, str) and name in DTYPE_BYTES:
        return DTYPE_BYTES[name]
    import numpy as np
    return int(np.dtype(dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    # CloudMatrix384-flavoured constants
    disk_bw: float = 0.4e9          # bytes/s per device, disk -> HBM
    p2p_bw: float = 120e9           # bytes/s per link (Unified Bus class)
    p2p_bw_slow: float = 0.8e9      # without HCCL: staged through host
    h2d_bw: float = 25e9            # bytes/s, pinned host -> HBM (DMA over
    # PCIe/host link): the cold-expert tier streams back at this rate and,
    # unlike P2P, adds zero load on the interconnect or source devices
    hbm_init_bw: float = 400e9      # memset for fresh KV allocations
    zero_copy_per_tensor: float = 2e-5   # handle open/import, seconds
    warmup_s: float = 2.0           # model warmup of the target instance
    preinit_boot_s: float = 55.0    # cold instance boot (engine + graphs)
    comm_setup_s: float = 3.0       # communication group (re)init
    kv_alloc_s: float = 1.5         # KV allocator setup on a fresh instance
    device_hbm: float = 64e9        # Ascend 910C HBM per device
    # overlapped staging (DESIGN.md §3): background transfers share links
    # and HBM bandwidth with the serving hot path, so each op runs slower
    # by `overlap_contention`; in exchange the warmup/compile window hides
    # under the transfer window and decode only loses `overlap_stall_frac`
    # of the transfer time to HBM contention instead of blocking a full
    # serve-loop quantum per increment.
    overlap_contention: float = 1.25
    overlap_stall_frac: float = 0.12


DEFAULT_HW = HardwareModel()


@dataclasses.dataclass
class ScalingCost:
    scale_time_s: float
    downtime_s: float
    peak_mem_bytes_per_device: Dict[int, int]
    breakdown: Dict[str, float]
    # modelled decode-stall during the staging window: serial staging blocks
    # the serve loop for the whole transfer time (one increment per tick);
    # overlapped staging only loses the HBM-contention share.  Zero when the
    # transition has downtime (the outage already accounts for it).
    decode_stall_s: float = 0.0
    staging: str = "serial"
    # zero-drain scale-down: live KV blocks device-copied off doomed
    # partitions.  Scale-down time is then bounded by these *bytes* (plus
    # weight staging) instead of by the longest in-flight sequence's drain.
    migration_bytes: int = 0

    @property
    def peak_mem_gb(self) -> float:
        return max(self.peak_mem_bytes_per_device.values()) / 1e9

    @property
    def total_mem_gb(self) -> float:
        return sum(self.peak_mem_bytes_per_device.values()) / 1e9


def plan_cost(plan: ScalingPlan,
              *,
              hw: HardwareModel = DEFAULT_HW,
              preinit: bool = True,
              zero_copy: bool = True,
              hccl: bool = True,
              ipc_safe_alloc: bool = True,
              strategy: str = "elastic",
              resident_bytes_per_device: Optional[Dict[int, int]] = None,
              staging: str = "serial",
              kv_migration_bytes: int = 0
              ) -> ScalingCost:
    """Project a plan onto the hardware model.

    ``resident_bytes_per_device``: bytes already live per device before the
    transition (old instance weights+KV); used for peak-memory accounting.

    ``kv_migration_bytes``: live KV blocks device-copied off doomed
    partitions during a zero-drain scale-down (P2P traffic, concurrent
    with serving like any other transfer) — scale-down cost becomes
    migration *bytes* instead of the drain's
    longest-in-flight-sequence wall time, with the usual staging-mode
    decode-stall share.  No peak-memory term: the copies land inside the
    already-allocated survivor pool.

    ``staging``: "serial" sums transfer + warmup (the tick-interleaved
    legacy path, decode stalled for the whole transfer time); "overlap"
    models the background TransferEngine — transfers slowed by
    ``hw.overlap_contention`` but concurrent with serving AND with the
    warmup/compile window, so scale time is the *max* of the two instead of
    their sum and decode only stalls for the HBM-contention share
    (DESIGN.md §3).  The breakdown's ``op_s`` key holds the
    serial-equivalent Σ of per-op transfer time either way.

    The ablation flags mirror Table 1:
    * ``ipc_safe_alloc=False`` — zero-copy still works but tensors must be
      re-registered through a bounce buffer: adds latency and +1 copy of the
      largest tensor per device to peak memory.
    * ``hccl=False`` — P2P staged through host memory (slow path).
    * ``preinit=False`` — target instance must cold-boot first.
    * ``zero_copy=False`` — every ZERO_COPY step becomes a DISK reload and
      the old instance must be torn down first => downtime.
    """
    steps = plan.steps
    resident = dict(resident_bytes_per_device or {})
    peak = dict(resident)
    live = dict(resident)

    disk_bytes: Dict[int, int] = {}
    p2p_in: Dict[int, int] = {}
    init_bytes: Dict[int, int] = {}
    host_bytes: Dict[int, int] = {}
    n_zero_copy = 0
    zero_copy_bytes = 0

    for s in steps:
        if s.op == Op.FREE:
            continue
        op = s.op
        if op == Op.ZERO_COPY and not zero_copy:
            op = Op.DISK
        if op == Op.ZERO_COPY:
            n_zero_copy += 1
            zero_copy_bytes += s.nbytes
            continue  # no new bytes: aliases existing memory
        if op == Op.DISK:
            disk_bytes[s.dst] = disk_bytes.get(s.dst, 0) + s.nbytes
        elif op == Op.P2P:
            p2p_in[s.dst] = p2p_in.get(s.dst, 0) + s.nbytes
        elif op == Op.HOST:
            host_bytes[s.dst] = host_bytes.get(s.dst, 0) + s.nbytes
        elif op == Op.INIT:
            init_bytes[s.dst] = init_bytes.get(s.dst, 0) + s.nbytes
        live[s.dst] = live.get(s.dst, 0) + s.nbytes
        peak[s.dst] = max(peak.get(s.dst, 0), live[s.dst])

    if not ipc_safe_alloc:
        # bounce-buffer registration: one extra copy of the largest shard
        biggest = max((s.nbytes for s in steps if s.op != Op.FREE), default=0)
        for d in list(peak):
            peak[d] = peak.get(d, 0) + biggest

    devs = set(plan.new.devices) | (set(plan.old.devices) if plan.old else set())
    for d in devs:
        peak.setdefault(d, 0)

    assert staging in ("serial", "overlap")
    p2p_bw = hw.p2p_bw if hccl else hw.p2p_bw_slow
    t_disk = max((b / hw.disk_bw for b in disk_bytes.values()), default=0.0)
    t_p2p = max((b / p2p_bw for b in p2p_in.values()), default=0.0)
    # host-tier H2D streams (demoted experts) ride the host link, NOT the
    # interconnect: they never contend with P2P and cost no source device
    t_host = max((b / hw.h2d_bw for b in host_bytes.values()), default=0.0)
    t_init = max((b / hw.hbm_init_bw for b in init_bytes.values()), default=0.0)
    t_mig = kv_migration_bytes / p2p_bw
    t_zc = n_zero_copy * hw.zero_copy_per_tensor
    if not ipc_safe_alloc:
        t_zc += n_zero_copy * hw.zero_copy_per_tensor * 20  # re-registration

    t_transfer = t_disk + t_p2p + t_host + t_init + t_mig
    if staging == "overlap":
        # background transfers contend with serving -> each op slower; in
        # exchange the warmup/compile window hides under the transfer
        # window (max, not sum) and decode only loses the contention share
        t_ops = t_transfer * hw.overlap_contention
        t = max(t_ops, hw.warmup_s) + t_zc
        decode_stall = t_ops * hw.overlap_stall_frac
        breakdown = {"disk": t_disk, "p2p": t_p2p, "host": t_host,
                     "init": t_init, "kv_migration": t_mig,
                     "zero_copy": t_zc, "warmup": hw.warmup_s,
                     "op_s": t_ops,
                     "overlap_hidden": t_ops + hw.warmup_s
                     - max(t_ops, hw.warmup_s)}
    else:
        t = t_transfer + t_zc + hw.warmup_s
        # serial staging blocks the serve loop one increment per tick: the
        # whole WEIGHT transfer time is decode stall — but KV migration
        # copies ride the background TransferEngine in every staging mode
        # (elastic_engine._advance_migration), so they only cost the HBM-
        # contention share, never a serve-loop block
        decode_stall = (t_disk + t_p2p + t_host + t_init
                        + t_mig * hw.overlap_stall_frac)
        breakdown = {"disk": t_disk, "p2p": t_p2p, "host": t_host,
                     "init": t_init, "kv_migration": t_mig,
                     "zero_copy": t_zc, "warmup": hw.warmup_s,
                     "op_s": t_transfer}
    if not preinit:
        t += hw.preinit_boot_s + hw.comm_setup_s
        breakdown["cold_boot"] = hw.preinit_boot_s + hw.comm_setup_s
    if strategy in ("cold_restart",) or not zero_copy:
        # old instance gone before the new one is ready -> downtime
        downtime = t
        breakdown["kv_alloc"] = hw.kv_alloc_s
        t += hw.kv_alloc_s
        downtime = t
        decode_stall = 0.0          # the outage already accounts for it
    else:
        downtime = 0.0
    return ScalingCost(scale_time_s=t, downtime_s=downtime,
                       peak_mem_bytes_per_device=peak, breakdown=breakdown,
                       decode_stall_s=decode_stall, staging=staging,
                       migration_bytes=kv_migration_bytes)


def resident_bytes(plan_place: Dict[int, Dict], kv_included: bool = True
                   ) -> Dict[int, int]:
    """Per-device live bytes of a placement (from scaling_plan.placement)."""
    return {d: sum(shards.values()) for d, shards in plan_place.items()}


def unpark_cost(plan: ScalingPlan, *,
                hw: HardwareModel = DEFAULT_HW,
                preinit: bool = True,
                staging: str = "overlap") -> ScalingCost:
    """Cold-start (scale-from-zero) transition pricing for an unpark plan
    (``scaling_plan.plan_unpark``): every weight shard rides the H2D lane
    at ``hw.h2d_bw`` — no disk, no P2P — and the KV pool is a fresh INIT.

    Overlap staging keeps the STAGING ∥ COMPILING discipline: the warmup/
    AOT-compile window hides under the H2D window (the ``max`` in
    ``plan_cost``), so a warm standby cache makes unpark wall-clock ≈ the
    weight bytes over the host link.  ``preinit=False`` adds the full
    cold-boot serial tail — the fleet driver prices an unparked model's
    first request with whatever the IMM actually holds.

    The model cannot serve while parked, so the whole transition is
    dead time for queued requests: ``downtime_s`` reports the scale time
    (unlike an elastic scale, where the old instance keeps serving)."""
    for s in plan.steps:
        assert s.op in (Op.HOST, Op.INIT, Op.FREE), \
            f"unpark plans stream host+init only, got {s.op}"
    cost = plan_cost(plan, hw=hw, preinit=preinit, staging=staging)
    cost.downtime_s = cost.scale_time_s
    cost.breakdown["cold_start"] = cost.scale_time_s
    return cost
