"""Inference Management Module (paper §4.5).

Keeps an LRU cache of *pre-initialized* inference instances.  In the paper a
standby instance is a CPU-resident vLLM process that has done every one-time
setup except binding weights; the JAX analogue of that expensive boot step is
AOT compilation of the instance's step functions for its (mesh, shapes) —
so a standby instance here is a set of compiled executables with **no
weights attached** (built purely from ShapeDtypeStructs).

``activate`` binds a standby instance to the HMM's zero-copy array handles —
a metadata-only operation (the ZeroCopyLoader replacing vLLM's DiskLoader).

With overlapped staging (``staging="overlap"``, DESIGN.md §3) the IMM's
AOT compile runs on the serving thread *while* the HMM's background
``TransferEngine`` moves bytes — STAGING ∥ COMPILING, so a cold compile
hides under the transfer window instead of following it.  Compilation is
pure tracing over ShapeDtypeStructs (no weight reads), so it races with
nothing the transfer ops touch.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.core.hmm import HMM, make_instance_mesh
from repro.core.topology import ElasticConfig
from repro.serving.engine import as_sds, compile_step_functions


@dataclasses.dataclass
class StandbyInstance:
    cfg: ElasticConfig
    mesh: Any
    compiled: Dict[str, Any]
    compile_s: float
    activations: int = 0


class IMM:
    def __init__(self, mcfg: ModelConfig, hmm: HMM, *,
                 batch_per_replica: int, max_len: int,
                 prefill_buckets=(64,), prefill_chunk: int = 0,
                 lru_capacity: int = 4, collect_routing: bool = False,
                 shared_cache: Optional[
                     "OrderedDict[Tuple, StandbyInstance]"] = None):
        self.mcfg = mcfg
        self.hmm = hmm
        self.batch_per_replica = batch_per_replica
        self.max_len = max_len
        self.prefill_buckets = tuple(prefill_buckets)
        # continuous batching: >0 also pre-compiles the chunk-prefill
        # executable per instance (engine.prefill_chunk)
        self.prefill_chunk = prefill_chunk
        # routing telemetry: also pre-compile the count-returning decode
        # twin ("decode_routed"; DESIGN.md §9)
        self.collect_routing = collect_routing
        self.lru_capacity = lru_capacity
        # A fleet shares one standby LRU across models (pass the same
        # OrderedDict to every IMM) so total cached executables stay
        # bounded by one capacity, not N of them; keys carry the full
        # model identity so same-mesh models can never collide.
        self._cache: "OrderedDict[Tuple, StandbyInstance]" = (
            shared_cache if shared_cache is not None else OrderedDict())
        self.stats = {"preinit_hits": 0, "preinit_misses": 0,
                      "compile_s_total": 0.0}

    def _key(self, cfg: ElasticConfig) -> Tuple:
        # Standby executables are specialized on everything that shapes the
        # traced program, not just the mesh: two fleet models with the same
        # (dp, tp, devices) must not collide on a cached executable, so the
        # key carries the model config and every compile-affecting knob.
        return (repr(self.mcfg),
                self.batch_per_replica, self.max_len,
                self.prefill_buckets, self.prefill_chunk,
                self.collect_routing,
                self.hmm.kv_mode, self.hmm.kv_block_size,
                self.hmm.kv_blocks_per_replica,
                self.hmm.expert_mode, self.hmm.expert_pool_pages,
                self.hmm.expert_slot_slack,
                self.hmm.kv_dtype, self.hmm.expert_dtype,
                cfg.dp, cfg.tp, cfg.devices)

    def has(self, cfg: ElasticConfig) -> bool:
        """True if a standby instance for ``cfg`` is already compiled (an
        imminent ``preinitialize``/``activate`` will be a metadata-only hit).
        Does not touch LRU order or hit/miss counters."""
        return self._key(cfg) in self._cache

    # ------------------------------------------------------------ pre-init
    def preinitialize(self, cfg: ElasticConfig) -> StandbyInstance:
        """Build (or fetch) a standby instance for ``cfg`` — compile only,
        no weights.  Corresponds to IMM pre-initialization (§4.5)."""
        key = self._key(cfg)
        if key in self._cache:
            self._cache.move_to_end(key)
            return self._cache[key]
        mesh = make_instance_mesh(cfg, self.hmm.all_devices)
        params_sds, cache_sds = self._shape_templates(cfg, mesh)
        compiled, dt = compile_step_functions(
            self.mcfg, cfg, mesh, params_sds, cache_sds,
            batch_per_replica=self.batch_per_replica, max_len=self.max_len,
            prefill_buckets=self.prefill_buckets,
            prefill_chunk=self.prefill_chunk,
            kv_mode=self.hmm.kv_mode,
            kv_block_size=self.hmm.kv_block_size,
            collect_routing=self.collect_routing)
        inst = StandbyInstance(cfg, mesh, compiled, dt)
        self._cache[key] = inst
        self.stats["compile_s_total"] += dt
        while len(self._cache) > self.lru_capacity:
            self._cache.popitem(last=False)
        return inst

    def _shape_templates(self, cfg: ElasticConfig, mesh):
        """Sharded ShapeDtypeStructs for params+cache — no allocation.
        The param layout comes from the HMM (dense, or the pooled expert
        store whose pool/table shapes depend on ``cfg``)."""
        params_shape = self.hmm.params_template(cfg)
        pshard = self.hmm.param_shardings(params_shape, mesh)
        params_sds = jax.tree.map(
            lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s),
            params_shape, pshard)
        cache_shape = self.hmm.cache_template(cfg)
        cshard = self.hmm.cache_shardings(cache_shape, mesh)
        cache_sds = jax.tree.map(
            lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s),
            cache_shape, cshard)
        return params_sds, cache_sds

    # ------------------------------------------------------------ activate
    def activate(self, cfg: ElasticConfig, staged: bool = False):
        """Attach a standby instance to HMM memory (zero-copy).  Returns
        (instance, params, cache, was_preinitialized)."""
        key = self._key(cfg)
        hit = key in self._cache
        if hit:
            self.stats["preinit_hits"] += 1
        else:
            self.stats["preinit_misses"] += 1
        inst = self.preinitialize(cfg)
        inst.activations += 1
        if staged:
            scfg, _, params, cache = self.hmm.attach_staged()
            assert self._key(scfg) == key
        else:
            acfg, _, params, cache = self.hmm.attach_active()
            assert self._key(acfg) == key
        return inst, params, cache, hit
