"""Virtual expert management — the TPU-native ``vpage-remap`` (paper §4.6/D.5).

The paper maps non-contiguous physical pages of expert weights into a
contiguous *virtual* range so EP reconfiguration is an O(1) remap instead of
a buffer reallocation + bulk copy.  XLA has no user-visible virtual memory,
so the TPU-idiomatic analogue is **indirection**: each device owns a fixed
page *pool* (one page = one (layer, expert) weight block) plus a page
*table* mapping logical expert slots to pool indices.  The MoE kernel
(`kernels/moe_gmm.py`) consumes the table and dynamic-slices pages out of the
pool in VMEM — kernels see a "contiguous" logical expert bank without any
data movement at remap time.

Double-buffered tables ("old mappings remain active on source devices until
the new inference instance takes over", §5.2): ``stage_remap`` builds the
target table + migration list; ``commit`` atomically swaps it in and returns
the pages to free.

Skew-aware rebalancing (DESIGN.md §10) extends the table two ways:

* **replica sets** — a (layer, expert) may map to *additional* device
  ``PageRef``s beyond its primary.  Replicas are byte-identical copies, so
  which one serves an expert's tokens is a pure host-side layout decision
  (``pooled_layout`` picks the least-loaded candidate when emitting
  edest/eslot) — dispatch math is unchanged and tokens stay bit-identical.
* **a pinned-host page tier** (logical device ``HOST``) — cold experts are
  *demoted*: their bytes stream D2H into a host page while the device
  primary keeps serving (correctness never depends on the demotion).  The
  payoff is at scale events: a host-backed expert that must move is
  streamed back H2D from the host tier instead of P2P from a device —
  zero expert P2P for the cold set (costmodel ``Op.HOST``).

Both are staged under the same two-phase discipline as scale remaps
(``stage_rebalance`` / ``commit_rebalance`` / ``abort_rebalance``), so an
abort-in-flight conserves the pool — device and host tiers alike.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.topology import ElasticConfig, expert_owner


#: logical device id of the pinned-host page tier (never a real device slot)
HOST = -1


@dataclasses.dataclass(frozen=True)
class PageRef:
    device: int
    page: int          # index into that device's pool

    @property
    def is_host(self) -> bool:
        return self.device == HOST


@dataclasses.dataclass(frozen=True)
class Migration:
    layer: int
    expert: int
    src: PageRef       # src.device == HOST: streamed from the pinned tier
    dst: PageRef


@dataclasses.dataclass(frozen=True)
class RebalanceOp:
    """One staged rebalance action with its allocated destination.

    kinds (DESIGN.md §10):
    * ``replicate``    — copy the expert onto ``dst`` (a fresh device page);
      ``src`` is the primary the bytes stream from.
    * ``demote``       — stream the expert's bytes D2H into ``dst`` (a fresh
      pinned-host page); the device primary keeps serving.
    * ``drop_replica`` — retire the replica ``src`` (no bytes move; the page
      frees at commit).
    * ``promote``      — retire the host copy ``src`` (no bytes move; the
      host page frees at commit — the expert is hot again, so it should P2P
      at scale events like any other instead of pinning tier capacity).
    """
    kind: str
    layer: int
    expert: int
    src: PageRef
    dst: Optional[PageRef] = None

    @property
    def key(self) -> Tuple[int, int]:
        return (self.layer, self.expert)


class ExpertPageTable:
    """Tracks (layer, expert) -> PageRef for the active and staged configs,
    plus replica sets and the pinned-host cold tier (DESIGN.md §10).

    Invariants: ``active`` always holds exactly one *device* primary per
    (layer, expert); ``replicas`` hold additional device copies; ``host``
    holds at most one pinned-host copy per expert.  At most one staging
    session — a scale remap OR a rebalance — may be open at a time."""

    def __init__(self, num_layers: int, num_experts: int,
                 pool_pages_per_device: int = 0,
                 host_pool_pages: Optional[int] = None):
        self.num_layers = num_layers
        self.num_experts = num_experts
        # default: room for every page twice (staging headroom) on one device
        self.pool_pages = pool_pages_per_device or 2 * num_layers * num_experts
        # pinned-host tier capacity: default fits every (layer, expert) once
        # — the scale-to-zero limit case (ROADMAP) parks the full expert set
        self.host_pool_pages = (num_layers * num_experts
                                if host_pool_pages is None else host_pool_pages)
        self.active: Dict[Tuple[int, int], PageRef] = {}
        # extra byte-identical device copies per (layer, expert); which copy
        # serves is decided host-side by pooled_layout (least-loaded pick)
        self.replicas: Dict[Tuple[int, int], Tuple[PageRef, ...]] = {}
        # pinned-host copies (device == HOST); bytes live with the HMM
        self.host: Dict[Tuple[int, int], PageRef] = {}
        self.staged: Optional[Dict[Tuple[int, int], PageRef]] = None
        self.staged_rebalance: Optional[List[RebalanceOp]] = None
        self._free: Dict[int, List[int]] = {}

    # ------------------------------------------------------------- helpers
    def _pool_size(self, device: int) -> int:
        return self.host_pool_pages if device == HOST else self.pool_pages

    def _ensure_pool(self, device: int):
        if device not in self._free:
            self._free[device] = list(range(self._pool_size(device)))

    def _alloc(self, device: int) -> int:
        self._ensure_pool(device)
        if not self._free[device]:
            tier = "host page tier" if device == HOST else \
                f"page pool on device {device}"
            raise MemoryError(f"{tier} exhausted")
        return self._free[device].pop()

    def pages_in_use(self, device: int) -> int:
        self._ensure_pool(device)
        return self._pool_size(device) - len(self._free[device])

    def replica_count(self, layer: int, expert: int) -> int:
        return len(self.replicas.get((layer, expert), ()))

    def demoted(self) -> List[Tuple[int, int]]:
        """(layer, expert) keys currently parked in the pinned-host tier."""
        return sorted(self.host)

    def clone(self) -> "ExpertPageTable":
        """Cheap independent copy for what-if staging (cost projections):
        ``PageRef``s are immutable, so only the containers are copied —
        no deep recursion over L*E dataclasses."""
        t = ExpertPageTable(self.num_layers, self.num_experts,
                            pool_pages_per_device=self.pool_pages,
                            host_pool_pages=self.host_pool_pages)
        t.active = dict(self.active)
        t.replicas = dict(self.replicas)
        t.host = dict(self.host)
        t.staged = dict(self.staged) if self.staged is not None else None
        t.staged_rebalance = (list(self.staged_rebalance)
                              if self.staged_rebalance is not None else None)
        t._free = {d: list(v) for d, v in self._free.items()}
        return t

    # ---------------------------------------------------------------- boot
    def initial_place(self, cfg: ElasticConfig) -> None:
        """First boot: allocate a page per (layer, expert) on its owner."""
        assert not self.active
        for l in range(self.num_layers):
            for e in range(self.num_experts):
                d = expert_owner(e, self.num_experts, cfg)
                self.active[(l, e)] = PageRef(d, self._alloc(d))

    # --------------------------------------------------------------- remap
    def stage_remap(self, new_cfg: ElasticConfig,
                    min_move: bool = True) -> List[Migration]:
        """Build the target table (paper Fig. 6: "global remapping of experts
        to balance placement across NPUs while minimizing data transfer").

        ``min_move=True`` (paper-faithful): per layer, compute balanced
        per-device capacities, keep every expert on its current device while
        capacity allows — thanks to the page indirection, placement need not
        be contiguous in logical expert order — and migrate only the
        overflow/orphaned experts to the devices with the most free capacity.

        ``min_move=False``: contiguous ``expert_owner`` placement (what the
        XLA dense-buffer execution path requires; moves more bytes).

        O(1) per expert either way: unchanged experts keep their *existing*
        page (no copy, no reallocation); moved experts get a fresh page on
        the target device and a P2P migration entry.  The active table keeps
        serving until commit().

        Rebalance interplay (DESIGN.md §10): with ``min_move=True`` an
        expert may be "kept in place" via *any* of its copies — primary or
        replica — so a replica landed on a surviving device counts as a
        zero-move; and an expert that must move sources its migration from
        the pinned-host tier when a host copy exists (``src.device ==
        HOST``), which costs H2D bandwidth instead of cross-device P2P.
        All unchosen replicas retire at commit (the new placement is
        rebuilt from fresh routing stats by the next rebalance pass)."""
        if self.staged is not None:
            raise RuntimeError(
                "a staged remap is already open; commit() or abort() it "
                "before staging another one (double-staging would leak the "
                "previously allocated pages)")
        if self.staged_rebalance is not None:
            raise RuntimeError(
                "a rebalance session is open; commit_rebalance() or "
                "abort_rebalance() before staging a scale remap (the two "
                "sessions race for the same page pools)")
        E = self.num_experts
        devs = list(new_cfg.devices)
        staged: Dict[Tuple[int, int], PageRef] = {}
        migrations: List[Migration] = []

        try:
            if not min_move:
                for (l, e), ref in self.active.items():
                    new_owner = expert_owner(e, E, new_cfg)
                    if new_owner == ref.device:
                        staged[(l, e)] = ref              # zero-copy remap
                    else:
                        dst = PageRef(new_owner, self._alloc(new_owner))
                        staged[(l, e)] = dst
                        migrations.append(Migration(l, e, ref, dst))
                self.staged = staged
                return migrations

            base, extra = divmod(E, len(devs))
            for l in range(self.num_layers):
                caps = {d: base + (1 if i < extra else 0)
                        for i, d in enumerate(devs)}
                pending: List[Tuple[int, PageRef]] = []
                for e in range(E):
                    # any surviving copy keeps the expert in place: primary
                    # first (stable), then replicas in creation order
                    copies = (self.active[(l, e)],) + \
                        self.replicas.get((l, e), ())
                    kept = next((c for c in copies
                                 if c.device in caps and caps[c.device] > 0),
                                None)
                    if kept is not None:
                        staged[(l, e)] = kept             # stays in place
                        caps[kept.device] -= 1
                    else:
                        pending.append((e, self.active[(l, e)]))
                for e, ref in pending:                    # most-free first
                    dst_dev = max(caps, key=lambda d: caps[d])
                    caps[dst_dev] -= 1
                    dst = PageRef(dst_dev, self._alloc(dst_dev))
                    staged[(l, e)] = dst
                    # cold experts stream back from the pinned-host tier:
                    # zero expert P2P for the demoted set (costmodel Op.HOST)
                    src = self.host.get((l, e), ref)
                    migrations.append(Migration(l, e, src, dst))
            self.staged = staged
            return migrations
        except BaseException:
            # MemoryError (pool exhausted) is documented as recoverable: a
            # failed staging must not strand the pages it already popped —
            # return them so the pool is exactly as before the call
            for m in migrations:
                self._free[m.dst.device].append(m.dst.page)
            raise

    def commit(self) -> List[PageRef]:
        """Switch to the staged table; returns pages to free (old homes of
        migrated experts, plus every replica the new placement didn't adopt
        — a replica picked as the kept copy is promoted to primary; pinned-
        host copies survive, weights are immutable so they never go stale).
        """
        if self.staged is None:
            raise RuntimeError("no staged remap open; call stage_remap() "
                               "before commit()")
        to_free: List[PageRef] = []
        for key, old_ref in self.active.items():
            if self.staged[key] != old_ref:
                self._free[old_ref.device].append(old_ref.page)
                to_free.append(old_ref)
        for key, refs in self.replicas.items():
            for ref in refs:
                if ref != self.staged[key]:
                    self._free[ref.device].append(ref.page)
                    to_free.append(ref)
        self.replicas = {}
        self.active = self.staged
        self.staged = None
        return to_free

    def abort(self) -> None:
        """Drop the staged table, freeing its freshly allocated pages.

        Idempotent: a second call is a no-op, and pages *shared* between the
        active table (primaries AND replicas) and the staged table — copies
        that would have stayed in place — are never freed; only staged-only
        pages return to the pool, each exactly once even if a table ever
        aliased the same page twice."""
        if self.staged is None:
            return
        live = set(self.active.values())
        for refs in self.replicas.values():
            live.update(refs)
        freed = set()
        for ref in self.staged.values():
            if ref not in live and ref not in freed:
                freed.add(ref)
                self._ensure_pool(ref.device)
                self._free[ref.device].append(ref.page)
        self.staged = None

    # ----------------------------------------------------------- rebalance
    def stage_rebalance(self, actions: List[Tuple]) -> List[RebalanceOp]:
        """Open a rebalance session: resolve + allocate each action.

        ``actions`` entries (see RebalanceOp for semantics):

        * ``("replicate", layer, expert, dst_device)``
        * ``("demote", layer, expert)``
        * ``("drop_replica", layer, expert, device)``
        * ``("promote", layer, expert)``

        Returns the resolved ops (fresh dst pages allocated for replicate /
        demote; nothing moves yet).  Exactly two-phase: commit_rebalance()
        applies the ops, abort_rebalance() returns every fresh page to its
        pool — an abort-in-flight conserves both tiers.  Allocation failure
        mid-way rolls back the pages already popped and re-raises, leaving
        the table untouched (same contract as stage_remap)."""
        if self.staged is not None:
            raise RuntimeError(
                "a staged scale remap is open; rebalance sessions are "
                "mutually exclusive with scale events")
        if self.staged_rebalance is not None:
            raise RuntimeError(
                "a rebalance session is already open; commit_rebalance() or "
                "abort_rebalance() it first")
        ops: List[RebalanceOp] = []
        try:
            for act in actions:
                kind, l, e = act[0], act[1], act[2]
                key = (l, e)
                primary = self.active.get(key)
                if primary is None:
                    raise KeyError(f"unknown expert {key}")
                if kind == "replicate":
                    dst_dev = act[3]
                    holders = {primary.device}
                    holders.update(r.device
                                   for r in self.replicas.get(key, ()))
                    if dst_dev in holders:
                        raise ValueError(
                            f"{key} already has a copy on device {dst_dev}")
                    dst = PageRef(dst_dev, self._alloc(dst_dev))
                    ops.append(RebalanceOp("replicate", l, e, primary, dst))
                elif kind == "demote":
                    if key in self.host:
                        raise ValueError(f"{key} is already demoted")
                    dst = PageRef(HOST, self._alloc(HOST))
                    ops.append(RebalanceOp("demote", l, e, primary, dst))
                elif kind == "drop_replica":
                    dev = act[3]
                    src = next((r for r in self.replicas.get(key, ())
                                if r.device == dev), None)
                    if src is None:
                        raise ValueError(
                            f"{key} has no replica on device {dev}")
                    ops.append(RebalanceOp("drop_replica", l, e, src))
                elif kind == "promote":
                    if key not in self.host:
                        raise ValueError(f"{key} is not demoted")
                    ops.append(RebalanceOp("promote", l, e, self.host[key]))
                else:
                    raise ValueError(f"unknown rebalance action {kind!r}")
        except BaseException:
            for op in ops:          # return the pages this call popped
                if op.dst is not None:
                    self._free[op.dst.device].append(op.dst.page)
            raise
        self.staged_rebalance = ops
        return ops

    def commit_rebalance(self) -> List[PageRef]:
        """Apply the staged rebalance; returns the pages freed by
        drop_replica / promote (replicate / demote pages become live)."""
        if self.staged_rebalance is None:
            raise RuntimeError("no rebalance session open; call "
                               "stage_rebalance() before commit_rebalance()")
        freed: List[PageRef] = []
        for op in self.staged_rebalance:
            key = op.key
            if op.kind == "replicate":
                self.replicas[key] = self.replicas.get(key, ()) + (op.dst,)
            elif op.kind == "demote":
                self.host[key] = op.dst
            elif op.kind == "drop_replica":
                kept = tuple(r for r in self.replicas[key] if r != op.src)
                if kept:
                    self.replicas[key] = kept
                else:
                    del self.replicas[key]
                self._free[op.src.device].append(op.src.page)
                freed.append(op.src)
            elif op.kind == "promote":
                del self.host[key]
                self._free[HOST].append(op.src.page)
                freed.append(op.src)
        self.staged_rebalance = None
        return freed

    def abort_rebalance(self) -> None:
        """Drop the rebalance session, returning every freshly allocated
        page (replicate dst / demote host dst) to its pool.  Idempotent;
        drop_replica / promote ops touched nothing, so there is nothing to
        undo for them — device and host tiers end exactly as before
        stage_rebalance()."""
        if self.staged_rebalance is None:
            return
        for op in self.staged_rebalance:
            if op.dst is not None:
                self._ensure_pool(op.dst.device)
                self._free[op.dst.device].append(op.dst.page)
        self.staged_rebalance = None

    # ------------------------------------------------------------- queries
    def device_table(self, cfg: ElasticConfig, layer: int,
                     device: int, staged: bool = False) -> List[int]:
        """Pool indices of the experts ``device`` owns for ``layer``, in
        logical expert order — the indirection vector the MoE kernel reads."""
        if staged and self.staged is None:
            raise RuntimeError(
                "no staged remap open: device_table(staged=True) is only "
                "valid between stage_remap() and commit()/abort()")
        table = self.staged if staged else self.active
        rows = [(e, ref.page) for (l, e), ref in table.items()
                if l == layer and ref.device == device]
        rows.sort()
        return [p for _, p in rows]

    def owners(self, layer: int) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = defaultdict(list)
        for (l, e), ref in self.active.items():
            if l == layer:
                out[ref.device].append(e)
        for v in out.values():
            v.sort()
        return out


# ------------------------------------------------- pooled execution layout

def pooled_layout(table: Dict[Tuple[int, int], PageRef], cfg: ElasticConfig,
                  num_layers: int, num_experts: int,
                  pages_per_device: int,
                  replicas: Optional[Dict[Tuple[int, int],
                                          Tuple[PageRef, ...]]] = None,
                  load: Optional[np.ndarray] = None,
                  slots_per_rank: Optional[int] = None
                  ) -> Dict[str, np.ndarray]:
    """Flatten a page-table mapping into the index arrays the pooled MoE
    execution path consumes (host-side numpy; the HMM device_puts them).

    Returns, with ``Elm = slots_per_rank or ceil(E / ndev)`` (min-move keeps
    per-device counts balanced to floor/ceil, so the default always bounds a
    device's experts; a larger ``slots_per_rank`` bakes replication slack
    into the compiled table width — DESIGN.md §10):

    * ``tables`` [L, ndev, Elm] int32 — per (layer, device-rank) the LOCAL
      pool-page index of each owned expert, logical-expert order, padded
      with page 0 (pad slots receive no tokens);
    * ``edest``  [L, E] int32 — serving device *rank* (mesh linear slot) per
      expert: the all-to-all destination;
    * ``eslot``  [L, E] int32 — the expert's slot within its rank's table;
    * ``gtable`` [L, E] int32 — GLOBAL pool row (rank * pages_per_device +
      local page) per expert, for the single-shard pooled path.

    Replica-aware serving assignment: when ``replicas`` maps experts to
    extra byte-identical copies, each expert's tokens are routed to the
    *least-loaded* candidate rank — experts in descending expected-``load``
    order (routing-histogram counts, [L, E] or [E]; uniform when None), each
    assigned to the candidate rank (primary's or any replica's) with the
    smallest accumulated load, primary rank breaking ties, subject to the
    per-rank ``Elm`` slot cap.  The assignment is deterministic and static
    per layout build, and every copy is byte-identical, so dispatch math —
    and therefore every token — is unchanged vs. the unreplicated layout.
    """
    ndev = cfg.ndev
    elm = slots_per_rank or math.ceil(num_experts / ndev)
    if load is None:
        load_le = np.ones((num_layers, num_experts), np.float64)
    else:
        load_le = np.broadcast_to(
            np.asarray(load, np.float64),
            (num_layers, num_experts))
    tables = np.zeros((num_layers, ndev, elm), np.int32)
    edest = np.zeros((num_layers, num_experts), np.int32)
    eslot = np.zeros((num_layers, num_experts), np.int32)
    gtable = np.zeros((num_layers, num_experts), np.int32)
    replicas = replicas or {}
    for l in range(num_layers):
        # phase 1 — pick each expert's serving copy (least-loaded rank)
        chosen: Dict[int, PageRef] = {}
        rank_load = [0.0] * ndev
        rank_slots = [0] * ndev
        order = sorted(range(num_experts),
                       key=lambda e: (-load_le[l, e], e))
        for e in order:
            cands = [table[(l, e)]] + list(replicas.get((l, e), ()))
            best = None
            for i, ref in enumerate(cands):
                r = cfg.slot(ref.device)
                if rank_slots[r] >= elm:
                    continue                      # rank's table is full
                k = (rank_load[r], i)             # primary wins load ties
                if best is None or k < best[0]:
                    best = (k, ref, r)
            if best is None:
                raise ValueError(
                    f"layer {l}: no candidate rank for expert {e} has a "
                    f"free slot (Elm={elm}) — placement not balanced; "
                    f"raise slots_per_rank (replication slack) or rebalance")
            _, ref, r = best
            chosen[e] = ref
            rank_load[r] += float(load_le[l, e])
            rank_slots[r] += 1
        # phase 2 — emit slots in ascending-e order (deterministic layout
        # independent of the load-sorted assignment order above)
        counts = [0] * ndev
        for e in range(num_experts):          # ascending e == logical order
            ref = chosen[e]
            r = cfg.slot(ref.device)
            s = counts[r]
            counts[r] += 1
            tables[l, r, s] = ref.page
            edest[l, e] = r
            eslot[l, e] = s
            gtable[l, e] = r * pages_per_device + ref.page
    return {"tables": tables, "edest": edest, "eslot": eslot,
            "gtable": gtable}
