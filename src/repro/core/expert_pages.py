"""Virtual expert management — the TPU-native ``vpage-remap`` (paper §4.6/D.5).

The paper maps non-contiguous physical pages of expert weights into a
contiguous *virtual* range so EP reconfiguration is an O(1) remap instead of
a buffer reallocation + bulk copy.  XLA has no user-visible virtual memory,
so the TPU-idiomatic analogue is **indirection**: each device owns a fixed
page *pool* (one page = one (layer, expert) weight block) plus a page
*table* mapping logical expert slots to pool indices.  The MoE kernel
(`kernels/moe_gmm.py`) consumes the table and dynamic-slices pages out of the
pool in VMEM — kernels see a "contiguous" logical expert bank without any
data movement at remap time.

Double-buffered tables ("old mappings remain active on source devices until
the new inference instance takes over", §5.2): ``stage_remap`` builds the
target table + migration list; ``commit`` atomically swaps it in and returns
the pages to free.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.topology import ElasticConfig, expert_owner


@dataclasses.dataclass(frozen=True)
class PageRef:
    device: int
    page: int          # index into that device's pool


@dataclasses.dataclass(frozen=True)
class Migration:
    layer: int
    expert: int
    src: PageRef
    dst: PageRef


class ExpertPageTable:
    """Tracks (layer, expert) -> PageRef for the active and staged configs."""

    def __init__(self, num_layers: int, num_experts: int,
                 pool_pages_per_device: int = 0):
        self.num_layers = num_layers
        self.num_experts = num_experts
        # default: room for every page twice (staging headroom) on one device
        self.pool_pages = pool_pages_per_device or 2 * num_layers * num_experts
        self.active: Dict[Tuple[int, int], PageRef] = {}
        self.staged: Optional[Dict[Tuple[int, int], PageRef]] = None
        self._free: Dict[int, List[int]] = {}

    # ------------------------------------------------------------- helpers
    def _ensure_pool(self, device: int):
        if device not in self._free:
            self._free[device] = list(range(self.pool_pages))

    def _alloc(self, device: int) -> int:
        self._ensure_pool(device)
        if not self._free[device]:
            raise MemoryError(f"page pool exhausted on device {device}")
        return self._free[device].pop()

    def pages_in_use(self, device: int) -> int:
        self._ensure_pool(device)
        return self.pool_pages - len(self._free[device])

    def clone(self) -> "ExpertPageTable":
        """Cheap independent copy for what-if staging (cost projections):
        ``PageRef``s are immutable, so only the containers are copied —
        no deep recursion over L*E dataclasses."""
        t = ExpertPageTable(self.num_layers, self.num_experts,
                            pool_pages_per_device=self.pool_pages)
        t.active = dict(self.active)
        t.staged = dict(self.staged) if self.staged is not None else None
        t._free = {d: list(v) for d, v in self._free.items()}
        return t

    # ---------------------------------------------------------------- boot
    def initial_place(self, cfg: ElasticConfig) -> None:
        """First boot: allocate a page per (layer, expert) on its owner."""
        assert not self.active
        for l in range(self.num_layers):
            for e in range(self.num_experts):
                d = expert_owner(e, self.num_experts, cfg)
                self.active[(l, e)] = PageRef(d, self._alloc(d))

    # --------------------------------------------------------------- remap
    def stage_remap(self, new_cfg: ElasticConfig,
                    min_move: bool = True) -> List[Migration]:
        """Build the target table (paper Fig. 6: "global remapping of experts
        to balance placement across NPUs while minimizing data transfer").

        ``min_move=True`` (paper-faithful): per layer, compute balanced
        per-device capacities, keep every expert on its current device while
        capacity allows — thanks to the page indirection, placement need not
        be contiguous in logical expert order — and migrate only the
        overflow/orphaned experts to the devices with the most free capacity.

        ``min_move=False``: contiguous ``expert_owner`` placement (what the
        XLA dense-buffer execution path requires; moves more bytes).

        O(1) per expert either way: unchanged experts keep their *existing*
        page (no copy, no reallocation); moved experts get a fresh page on
        the target device and a P2P migration entry.  The active table keeps
        serving until commit()."""
        if self.staged is not None:
            raise RuntimeError(
                "a staged remap is already open; commit() or abort() it "
                "before staging another one (double-staging would leak the "
                "previously allocated pages)")
        E = self.num_experts
        devs = list(new_cfg.devices)
        staged: Dict[Tuple[int, int], PageRef] = {}
        migrations: List[Migration] = []

        try:
            if not min_move:
                for (l, e), ref in self.active.items():
                    new_owner = expert_owner(e, E, new_cfg)
                    if new_owner == ref.device:
                        staged[(l, e)] = ref              # zero-copy remap
                    else:
                        dst = PageRef(new_owner, self._alloc(new_owner))
                        staged[(l, e)] = dst
                        migrations.append(Migration(l, e, ref, dst))
                self.staged = staged
                return migrations

            base, extra = divmod(E, len(devs))
            for l in range(self.num_layers):
                caps = {d: base + (1 if i < extra else 0)
                        for i, d in enumerate(devs)}
                pending: List[Tuple[int, PageRef]] = []
                for e in range(E):
                    ref = self.active[(l, e)]
                    if ref.device in caps and caps[ref.device] > 0:
                        staged[(l, e)] = ref              # stays in place
                        caps[ref.device] -= 1
                    else:
                        pending.append((e, ref))
                for e, ref in pending:                    # most-free first
                    dst_dev = max(caps, key=lambda d: caps[d])
                    caps[dst_dev] -= 1
                    dst = PageRef(dst_dev, self._alloc(dst_dev))
                    staged[(l, e)] = dst
                    migrations.append(Migration(l, e, ref, dst))
            self.staged = staged
            return migrations
        except BaseException:
            # MemoryError (pool exhausted) is documented as recoverable: a
            # failed staging must not strand the pages it already popped —
            # return them so the pool is exactly as before the call
            for m in migrations:
                self._free[m.dst.device].append(m.dst.page)
            raise

    def commit(self) -> List[PageRef]:
        """Switch to the staged table; returns pages to free (old homes of
        migrated experts)."""
        if self.staged is None:
            raise RuntimeError("no staged remap open; call stage_remap() "
                               "before commit()")
        to_free: List[PageRef] = []
        for key, old_ref in self.active.items():
            if self.staged[key] != old_ref:
                self._free[old_ref.device].append(old_ref.page)
                to_free.append(old_ref)
        self.active = self.staged
        self.staged = None
        return to_free

    def abort(self) -> None:
        """Drop the staged table, freeing its freshly allocated pages.

        Idempotent: a second call is a no-op, and pages *shared* between the
        active and staged tables (experts that would have stayed in place)
        are never freed — only staged-only pages return to the pool, each
        exactly once even if a table ever aliased the same page twice."""
        if self.staged is None:
            return
        live = set(self.active.values())
        freed = set()
        for ref in self.staged.values():
            if ref not in live and ref not in freed:
                freed.add(ref)
                self._ensure_pool(ref.device)
                self._free[ref.device].append(ref.page)
        self.staged = None

    # ------------------------------------------------------------- queries
    def device_table(self, cfg: ElasticConfig, layer: int,
                     device: int, staged: bool = False) -> List[int]:
        """Pool indices of the experts ``device`` owns for ``layer``, in
        logical expert order — the indirection vector the MoE kernel reads."""
        if staged and self.staged is None:
            raise RuntimeError(
                "no staged remap open: device_table(staged=True) is only "
                "valid between stage_remap() and commit()/abort()")
        table = self.staged if staged else self.active
        rows = [(e, ref.page) for (l, e), ref in table.items()
                if l == layer and ref.device == device]
        rows.sort()
        return [p for _, p in rows]

    def owners(self, layer: int) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = defaultdict(list)
        for (l, e), ref in self.active.items():
            if l == layer:
                out[ref.device].append(e)
        for v in out.values():
            v.sort()
        return out


# ------------------------------------------------- pooled execution layout

def pooled_layout(table: Dict[Tuple[int, int], PageRef], cfg: ElasticConfig,
                  num_layers: int, num_experts: int,
                  pages_per_device: int) -> Dict[str, np.ndarray]:
    """Flatten a page-table mapping into the index arrays the pooled MoE
    execution path consumes (host-side numpy; the HMM device_puts them).

    Returns, with ``Elm = ceil(E / ndev)`` (min-move keeps per-device counts
    balanced to floor/ceil, so Elm always bounds a device's experts):

    * ``tables`` [L, ndev, Elm] int32 — per (layer, device-rank) the LOCAL
      pool-page index of each owned expert, logical-expert order, padded
      with page 0 (pad slots receive no tokens);
    * ``edest``  [L, E] int32 — owning device *rank* (mesh linear slot) per
      expert: the all-to-all destination;
    * ``eslot``  [L, E] int32 — the expert's slot within its rank's table;
    * ``gtable`` [L, E] int32 — GLOBAL pool row (rank * pages_per_device +
      local page) per expert, for the single-shard pooled path.
    """
    ndev = cfg.ndev
    elm = math.ceil(num_experts / ndev)
    tables = np.zeros((num_layers, ndev, elm), np.int32)
    edest = np.zeros((num_layers, num_experts), np.int32)
    eslot = np.zeros((num_layers, num_experts), np.int32)
    gtable = np.zeros((num_layers, num_experts), np.int32)
    for l in range(num_layers):
        counts = [0] * ndev
        for e in range(num_experts):          # ascending e == logical order
            ref = table[(l, e)]
            r = cfg.slot(ref.device)
            s = counts[r]
            if s >= elm:
                raise ValueError(
                    f"layer {l}: device rank {r} owns more than "
                    f"ceil(E/ndev)={elm} experts — placement not balanced")
            counts[r] += 1
            tables[l, r, s] = ref.page
            edest[l, e] = r
            eslot[l, e] = s
            gtable[l, e] = r * pages_per_device + ref.page
    return {"tables": tables, "edest": edest, "eslot": eslot,
            "gtable": gtable}
