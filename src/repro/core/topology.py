"""Elastic instance topology: DP-TP-EP configurations and the logical-tensor
model description the HMM plans over.

Follows the paper's conventions (§2.1, §4.1):
* an inference instance runs on ``dp * tp`` accelerators,
* attention/dense weights are TP-sharded (``tp_rank = slot % tp``) and
  replicated across DP replicas,
* experts are EP-distributed with ``ep = dp * tp`` (one expert shard per
  device) — scaling changes DP and EP while **TP stays fixed** (§4.1),
* the KV cache is per-DP-replica state, TP-sharded within a replica.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """One serving configuration: which devices, and how they're organized."""
    dp: int
    tp: int
    devices: Tuple[int, ...]           # global device ids, slot order

    def __post_init__(self):
        assert len(self.devices) == self.dp * self.tp, \
            f"{self.dp}x{self.tp} != {len(self.devices)} devices"

    @property
    def ep(self) -> int:
        return self.dp * self.tp       # paper's EP = TP x DP convention

    @property
    def ndev(self) -> int:
        return len(self.devices)

    def slot(self, device: int) -> int:
        return self.devices.index(device)

    def tp_rank(self, device: int) -> int:
        return self.slot(device) % self.tp

    def dp_rank(self, device: int) -> int:
        return self.slot(device) // self.tp

    def ep_rank(self, device: int) -> int:
        return self.slot(device)       # one EP rank per device

    def describe(self) -> str:
        return f"DP{self.dp}-TP{self.tp}-EP{self.ep}@{list(self.devices)}"


# ----------------------------------------------------------- logical tensors

@dataclasses.dataclass(frozen=True)
class TensorDesc:
    """One logical tensor the HMM manages.

    kind:
      'replicated' — identical on every device (norms, routers, embeddings
                     when small; here: anything not TP-sharded),
      'tp'         — sharded over TP ranks; DP replicas hold identical shards,
      'expert'     — one expert's weight page; owned by exactly one EP rank,
      'kv'         — KV-cache block of one DP replica (TP-sharded);
                     *state*, not weights: preserved on shared devices,
                     freshly initialized on new ones.
    """
    name: str
    kind: str
    nbytes: int                        # per-shard bytes (after TP split)
    layer: int = -1
    expert: int = -1


def expert_owner(expert: int, num_experts: int, cfg: ElasticConfig) -> int:
    """Device owning ``expert`` under round-robin-contiguous EP placement."""
    per = math.ceil(num_experts / cfg.ep)
    rank = min(expert // per, cfg.ep - 1)
    return cfg.devices[rank]


def model_tensors(mcfg: ModelConfig, tp: int,
                  kv_bytes_per_replica: int = 0,
                  expert_dtype: Optional[str] = None) -> List[TensorDesc]:
    """Flatten a ModelConfig into the logical tensors the HMM plans over.

    Sizes are *per TP shard* for 'tp' tensors.  Expert pages are per
    (layer, expert) — the granularity of vpage-remap migration.

    ``expert_dtype``: storage dtype of the expert pages only (the pooled
    store's ``expert_dtype="int8"`` knob); dense/attention tensors keep the
    model dtype.  Quantized pages carry one f32 scale per bank, so the page
    size is ``ff_mult * (D * moe_d_ff * 1 + 4)`` — the planner and every
    projection built on it see the halved expert P2P/H2D bytes.
    """
    from repro.core.costmodel import dtype_bytes
    bpe = dtype_bytes(mcfg.dtype)
    ebpe = dtype_bytes(expert_dtype or mcfg.dtype)
    escale = 4 if (expert_dtype or mcfg.dtype) != mcfg.dtype else 0
    D = mcfg.d_model
    out: List[TensorDesc] = []
    out.append(TensorDesc("embed", "tp",
                          mcfg.vocab_size * D * bpe // tp))
    out.append(TensorDesc("lm_head", "tp",
                          mcfg.vocab_size * D * bpe // tp))

    H, KVH, hd = mcfg.num_heads, mcfg.num_kv_heads, mcfg.resolved_head_dim
    for l in range(mcfg.num_layers):
        if mcfg.arch_type not in ("ssm",):
            if mcfg.use_mla:
                r = mcfg.kv_lora_rank
                qk = mcfg.qk_nope_dim + mcfg.qk_rope_dim
                attn = (D * H * qk + D * (r + mcfg.qk_rope_dim)
                        + r * H * (mcfg.qk_nope_dim + mcfg.v_head_dim)
                        + H * mcfg.v_head_dim * D)
            else:
                attn = D * H * hd + 2 * D * KVH * hd + H * hd * D
            out.append(TensorDesc(f"layer{l}/attn", "tp", attn * bpe // tp,
                                  layer=l))
        ff_mult = 3 if mcfg.mlp_gated else 2
        if mcfg.is_moe and l >= mcfg.first_k_dense:
            page = ff_mult * (D * mcfg.moe_d_ff * ebpe + escale) // tp
            for e in range(mcfg.num_experts):
                out.append(TensorDesc(f"layer{l}/expert{e}", "expert", page,
                                      layer=l, expert=e))
            if mcfg.num_shared_experts:
                out.append(TensorDesc(
                    f"layer{l}/shared_experts", "tp",
                    mcfg.num_shared_experts * ff_mult * D * mcfg.moe_d_ff
                    * bpe // tp, layer=l))
            if mcfg.dense_residual and mcfg.d_ff:
                out.append(TensorDesc(f"layer{l}/dense_mlp", "tp",
                                      ff_mult * D * mcfg.d_ff * bpe // tp,
                                      layer=l))
            out.append(TensorDesc(f"layer{l}/router", "replicated",
                                  D * mcfg.num_experts * 4, layer=l))
        elif mcfg.d_ff:
            out.append(TensorDesc(f"layer{l}/mlp", "tp",
                                  ff_mult * D * mcfg.d_ff * bpe // tp,
                                  layer=l))
        if mcfg.arch_type in ("ssm", "hybrid"):
            di, N = mcfg.d_inner, mcfg.ssm_state
            ssm = D * (2 * di + 2 * N + mcfg.ssm_heads) + di * mcfg.ssm_conv \
                + di * D
            out.append(TensorDesc(f"layer{l}/ssm", "tp", ssm * bpe // tp,
                                  layer=l))
        out.append(TensorDesc(f"layer{l}/norms", "replicated", 2 * D * bpe,
                              layer=l))
    if kv_bytes_per_replica:
        for l in range(mcfg.num_layers):
            out.append(TensorDesc(f"layer{l}/kv", "kv",
                                  kv_bytes_per_replica
                                  // mcfg.num_layers // tp, layer=l))
    return out


def kv_cache_bytes(mcfg: ModelConfig, batch: int, max_len: int,
                   kv_dtype: Optional[str] = None) -> int:
    """Total KV/state bytes of ONE DP replica (all layers, before TP split).

    ``kv_dtype``: storage dtype of the KV entries (the paged pool's
    ``kv_dtype="int8"`` knob); int8 adds one f32 scale per (k, v) token row
    per layer — 8 bytes/token — so projections count exactly what the
    quantized block pool allocates."""
    from repro.core.costmodel import dtype_bytes
    bpe = dtype_bytes(mcfg.dtype)
    kv_bpe = dtype_bytes(kv_dtype or mcfg.dtype)
    kv_scale = 2 * 4 if (kv_dtype or mcfg.dtype) != mcfg.dtype else 0
    L = mcfg.num_layers
    if mcfg.arch_type in ("ssm", "hybrid"):
        di, N = mcfg.d_inner, mcfg.ssm_state
        n = L * batch * ((mcfg.ssm_conv - 1) * (di + 2 * N) * bpe
                         + mcfg.ssm_heads * N * mcfg.ssm_head_dim * 4)
        if mcfg.arch_type == "hybrid":
            ng = L // mcfg.attn_every
            n += ng * batch * max_len * 2 * mcfg.num_kv_heads \
                * mcfg.resolved_head_dim * bpe
        return n
    if mcfg.use_mla:
        return L * batch * max_len * (mcfg.kv_lora_rank
                                      + mcfg.qk_rope_dim) * kv_bpe
    return L * batch * max_len * (2 * mcfg.num_kv_heads
                                  * mcfg.resolved_head_dim * kv_bpe
                                  + kv_scale)
