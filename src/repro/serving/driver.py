"""Closed-loop elastic autoscaling driver (DESIGN.md §3, §6).

This module closes the loop the paper's Coordinator (§4.3) describes: one
``ClusterDriver`` owns a device pool, watches the SLO-aware ``LoadEstimator``,
selects the next ``ElasticConfig`` with the cost model, and executes the
transition as a resumable ``ScalingTask`` — polled once per serving tick so
the engine keeps producing tokens throughout the reconfiguration (the
paper's concurrent, zero-downtime scaling).  With ``staging="overlap"`` the
transfers themselves ride a background ``TransferEngine`` and the poll is
non-blocking; the serial legacy mode performs one synchronous increment per
poll (DESIGN.md §3).

The same driver loop runs unchanged over two backends implementing the
``ServingBackend`` protocol:

* ``repro.core.elastic_engine.ElasticServer`` — real JAX on host devices;
  staging is real per-tensor HMM reshards (zero-copy + P2P), off-thread
  when overlapped,
* ``repro.serving.simulator.ServingSimulator`` — the calibrated
  discrete-event model at paper scale; staging duration comes from
  ``plan_cost`` and commit happens when modelled time reaches ``t_ready``.

Admission gating during a transition is shared policy code
(``admission_during_scale``) rather than per-backend logic, so the simulator
cannot silently diverge from engine semantics.

Lifecycle of a ``ScalingTask`` (state diagram in DESIGN.md §3)::

    IDLE -> STAGING -> COMPILING -> [MIGRATING | DRAINING]
                                          -> COMMITTING -> DONE
                \\________________________________________/-> ABORTED

MIGRATING/DRAINING only occur on scale-down: with paged KV and
``scaledown="migrate"`` (the default) live sequences' KV blocks are
device-copied onto survivor partitions in the background and the devices
release in seconds; ``scaledown="drain"`` (and the dense layout) keeps
the legacy run-to-completion drain, whose latency is bounded by the
longest in-flight sequence.  Every arrow is traversed by ``advance(now)``
calls between serving ticks.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import List, Optional, Protocol, Sequence, Tuple, runtime_checkable

from typing import Dict, Union

from repro.configs.base import ModelConfig
from repro.core.coordinator import LoadEstimator, ScalingPolicy
from repro.core.costmodel import (DEFAULT_HW, HardwareModel, plan_cost,
                                  unpark_cost)
from repro.core.scaling_plan import STRATEGIES, placement, plan_unpark
from repro.core.topology import ElasticConfig, kv_cache_bytes, model_tensors
from repro.serving.metrics import latency_percentiles
from repro.serving.workload import Request, merge_arrivals


class ScalePhase(enum.Enum):
    STAGING = "staging"        # weights moving; serving continues
    COMPILING = "compiling"    # IMM pre-init (AOT compile) for the target
    MIGRATING = "migrating"    # scale-down: live KV blocks copy to survivors
    DRAINING = "draining"      # scale-down: evicted slots run to completion
    COMMITTING = "committing"  # switchover: retarget traffic, shared KV
    DONE = "done"
    ABORTED = "aborted"

    @property
    def terminal(self) -> bool:
        return self in (ScalePhase.DONE, ScalePhase.ABORTED)


class ScalingTask(Protocol):
    """A resumable scaling transition.  ``advance`` is a **non-blocking
    completion poll**: it observes progress, moves the phase machine
    forward when a phase has completed, and returns the current phase; the
    driver calls it once per serving tick.

    How much work runs *inside* an ``advance`` call is a backend property:
    with overlapped staging (``staging="overlap"``) the transfers ride a
    background ``TransferEngine`` and ``advance`` only polls, while the
    serial legacy path (``staging="serial"``) performs at most one
    synchronous staging increment per call — either way the serve loop
    ticks between calls and never blocks on a bulk transfer."""
    target: ElasticConfig
    phase: ScalePhase

    def advance(self, now: float) -> ScalePhase: ...


def admission_during_scale(strategy: str) -> Tuple[str, bool]:
    """Shared admission/capacity gating while a transition is in flight.

    Returns ``(capacity, admit_new)`` where capacity is one of
    ``'old'`` (old instance keeps serving) or ``'none'`` (downtime).
    Used identically by the real engine path and the simulator — the paper's
    strategy comparison (§3, §7):

    * elastic / colocated — old instance serves, **new admissions pause**
      until switchover (§C),
    * extravagant / horizontal — old instance untouched, admissions continue
      (the new devices are extra),
    * cold_restart — the old instance is torn down first: downtime.
    """
    if strategy == "cold_restart":
        return "none", False
    if strategy in ("extravagant", "horizontal"):
        return "old", True
    return "old", False


def projected_migration_blocks(used_blocks: float, old_dp: int,
                               new_dp: int) -> int:
    """THE shared scale-down migration policy for projections: the doomed
    partitions' share of current block occupancy must move to survivors.
    Slots fill partition-major and admission is paused during the
    transition, so occupancy is ~uniform across partitions — the simulator
    costs its scale events with this and the ClusterDriver projects
    candidate costs with it, while the real engine migrates the exact
    per-sequence block sets (DriverEvent records both)."""
    if new_dp >= old_dp or old_dp <= 0:
        return 0
    return int(math.ceil(used_blocks * (old_dp - new_dp) / old_dp))


def transition_cost(mcfg: ModelConfig, tp: int, old: ElasticConfig,
                    new: ElasticConfig, *, strategy: str = "elastic",
                    hw: Optional[HardwareModel] = None, preinit: bool = True,
                    kv_seq_len: int = 4096, kv_batch: int = 8,
                    expert_mode: str = "dense", page_table=None,
                    staging: str = "serial", kv_migration_bytes: int = 0,
                    kv_dtype: Optional[str] = None,
                    expert_dtype: Optional[str] = None):
    """Plan + cost of one transition — THE shared costing path: the
    simulator executes its scale events with this and the ClusterDriver
    selects targets with it, so projection and execution cannot drift.
    Returns a ``costmodel.ScalingCost``.

    ``expert_mode='pooled'`` costs the elastic transition with the min-move
    expert placement (``plan_elastic_paged``): only overflow experts count
    as P2P bytes, so the closed loop sees the cheaper vpage-remap scaling
    cost the pooled engine actually executes.  Pass the live
    ``page_table`` (the ClusterDriver does, from ``backend.hmm``) to cost
    from the server's ACTUAL — possibly non-contiguous, post-remap —
    placement; it is deep-copied, never mutated.  Without one, a fresh
    contiguous placement at ``old`` is assumed (a server booted there;
    also the simulator's model of itself).

    ``staging`` projects the serial vs overlapped transfer pipeline
    (``costmodel.plan_cost``): overlap hides warmup under the transfer
    window and converts decode stall into an HBM-contention share.

    ``kv_migration_bytes`` models a zero-drain scale-down: live KV blocks
    device-copied onto survivor partitions (use
    ``projected_migration_blocks`` × block bytes for the shared policy).

    ``kv_dtype``/``expert_dtype`` ('int8') cost the quantized pools: KV and
    expert-page bytes are sized at the storage element width (plus scale
    sidecars), so projections see the halved transfer/migration volumes the
    quantized backend actually moves."""
    kvb = kv_cache_bytes(mcfg, kv_batch, kv_seq_len, kv_dtype=kv_dtype)
    tensors = model_tensors(mcfg, tp, kv_bytes_per_replica=kvb,
                            expert_dtype=expert_dtype)
    if (expert_mode == "pooled" and mcfg.is_moe and old is not None
            and strategy == "elastic"):
        from repro.core.scaling_plan import (plan_elastic_min_move,
                                             plan_elastic_paged)
        if page_table is not None and page_table.staged is None:
            plan = plan_elastic_paged(tensors, old, new, page_table.clone(),
                                      first_k_dense=mcfg.first_k_dense)
        else:
            plan = plan_elastic_min_move(tensors, old, new, mcfg)
    else:
        plan = STRATEGIES[strategy](tensors, old, new)
    resident = {d: sum(s.values())
                for d, s in placement(tensors, old).items()}
    return plan_cost(plan, hw=hw or DEFAULT_HW, preinit=preinit,
                     strategy=strategy, resident_bytes_per_device=resident,
                     staging=staging, kv_migration_bytes=kv_migration_bytes)


def unpark_transition_cost(mcfg: ModelConfig, tp: int, new: ElasticConfig, *,
                           hw: Optional[HardwareModel] = None,
                           preinit: bool = True, staging: str = "overlap",
                           kv_seq_len: int = 4096, kv_batch: int = 8,
                           kv_dtype: Optional[str] = None,
                           expert_dtype: Optional[str] = None):
    """Cold-start pricing for scale-from-zero (DESIGN.md §12): the parked
    model's whole snapshot streams H2D at ``hw.h2d_bw`` while the IMM
    compile window hides underneath (overlap staging) — the shared costing
    path for the FleetDriver's unpark projections and the simulator's
    unpark execution, mirroring how ``transition_cost`` is shared for
    scale events.  Returns a ``costmodel.ScalingCost`` whose ``downtime_s``
    equals the scale time (a parked model serves nothing until commit)."""
    kvb = kv_cache_bytes(mcfg, kv_batch, kv_seq_len, kv_dtype=kv_dtype)
    tensors = model_tensors(mcfg, tp, kv_bytes_per_replica=kvb,
                            expert_dtype=expert_dtype)
    plan = plan_unpark(tensors, new)
    return unpark_cost(plan, hw=hw or DEFAULT_HW, preinit=preinit,
                       staging=staging)


# ------------------------------------------------------------- device pool

class DevicePool:
    """Single source of truth for accelerator ownership across models.

    Every device id in the fleet belongs to exactly one owner (a model
    name) or is free — the allocator raises on any claim that would
    double-book a device (two backends binding overlapping ids, or a
    driver handing a device to a model while another still holds it),
    instead of silently aliasing accelerator memory.  ``check_invariants``
    asserts pool conservation: owned ∪ free is exactly the pool, with no
    device in both and none leaked."""

    def __init__(self, devices: Sequence[int]):
        devs = tuple(int(d) for d in devices)
        if len(set(devs)) != len(devs):
            raise ValueError(f"duplicate device ids in pool: {devs}")
        self.devices: Tuple[int, ...] = devs
        self._known = frozenset(devs)
        self._owner: Dict[int, str] = {}

    def claim(self, owner: str, devs: Sequence[int]) -> Tuple[int, ...]:
        """Atomically claim ``devs`` for ``owner``.  Raises ValueError if
        any device is outside the pool or already owned (by anyone,
        including ``owner`` itself — a double-claim is a bookkeeping bug,
        not a no-op)."""
        devs = tuple(int(d) for d in devs)
        for d in devs:
            if d not in self._known:
                raise ValueError(f"device {d} is not in the pool "
                                 f"{self.devices}")
            holder = self._owner.get(d)
            if holder is not None:
                raise ValueError(
                    f"device {d} already owned by {holder!r} — refusing to "
                    f"double-book it for {owner!r}")
        if len(set(devs)) != len(devs):
            raise ValueError(f"duplicate device ids in claim: {devs}")
        for d in devs:
            self._owner[d] = owner
        return devs

    def release(self, owner: str, devs: Sequence[int]) -> None:
        """Return ``devs`` to the free set.  Raises ValueError unless every
        device is currently owned by ``owner``."""
        devs = tuple(int(d) for d in devs)
        for d in devs:
            holder = self._owner.get(d)
            if holder != owner:
                raise ValueError(
                    f"device {d} is owned by {holder!r}, not {owner!r} — "
                    f"refusing the release")
        for d in devs:
            del self._owner[d]

    def owned(self, owner: str) -> Tuple[int, ...]:
        return tuple(d for d in self.devices if self._owner.get(d) == owner)

    def free(self) -> Tuple[int, ...]:
        return tuple(d for d in self.devices if d not in self._owner)

    def owners(self) -> Dict[int, str]:
        return dict(self._owner)

    def check_invariants(
            self, leases: Optional[Dict[str, Sequence[int]]] = None) -> None:
        """Pool conservation: every device is free xor owned by exactly one
        model; nothing outside the pool is tracked.  ``leases``: optional
        {owner -> devices} view the caller believes (e.g. the FleetDriver's
        per-model lease lists) — asserted to agree with the allocator
        exactly, so a device can neither be double-booked nor leaked."""
        for d in self._owner:
            assert d in self._known, f"unknown device {d} tracked"
        free = set(self.free())
        owned = set(self._owner)
        assert not (free & owned), f"devices both free and owned: {free & owned}"
        assert free | owned == self._known, \
            f"devices leaked: {self._known - free - owned}"
        if leases is not None:
            seen: Dict[int, str] = {}
            for owner, devs in leases.items():
                for d in devs:
                    assert d not in seen, \
                        f"device {d} leased to both {seen[d]!r} and {owner!r}"
                    seen[d] = owner
                    assert self._owner.get(d) == owner, \
                        f"lease says {owner!r} holds {d}, allocator says " \
                        f"{self._owner.get(d)!r}"
            assert set(seen) == owned, \
                f"allocator/lease mismatch: {set(seen) ^ owned}"


@runtime_checkable
class ServingBackend(Protocol):
    """What the ClusterDriver needs from a serving system.  Implemented by
    ``ElasticServer`` (real JAX) and ``ServingSimulator`` (discrete-event)."""

    def submit(self, req: Request) -> None: ...

    def step(self, now: float) -> List[Request]:
        """Serve one tick/quantum ending at ``now``; returns requests that
        finished during it."""
        ...

    def queue_depth(self) -> int: ...

    def utilization(self) -> float:
        """Fraction of serving capacity currently occupied, in [0, 1]."""
        ...

    def current_config(self) -> ElasticConfig: ...

    def start_scale(self, target: ElasticConfig) -> ScalingTask: ...

    def prewarm(self, target: ElasticConfig) -> None:
        """Optional: pre-initialize a standby instance for ``target``."""
        ...

    def capacity(self, cfg: ElasticConfig) -> int:
        """Concurrent-request capacity of ``cfg`` on this backend."""
        ...

    def kv_stats(self) -> Optional[dict]:
        """Paged-KV block-pool stats (num_blocks / used_blocks /
        utilization / preemptions), or None when the backend serves with
        the dense layout.  Both backends implement it; with paged KV the
        driver's ``utilization()`` signal *is* block occupancy, so memory
        pressure — not just slot occupancy — drives scaling decisions."""
        ...

    def routing_stats(self) -> Optional[dict]:
        """Accumulated per-expert routing histogram (samples / counts
        [L_moe, E] / top_expert_share / expert_cv), or None when the
        backend collects no routing telemetry (non-MoE model, sampling
        disabled, or the modelled backend).  DESIGN.md §9."""
        ...


# ------------------------------------------------------------------ driver

@dataclasses.dataclass
class DriverConfig:
    """Target-selection and pacing knobs for the ClusterDriver."""
    dt: float = 0.05               # driver tick quantum, seconds
    step_dp: int = 1               # ladder granularity, DP replicas per rung
    max_step_dp: int = 2           # furthest rung considered per decision
    min_dp: int = 1
    settle_s: float = 0.0          # extra hysteresis after a completed scale
    scale_budget_s: float = math.inf   # veto candidates costlier than this
    prewarm_next: bool = True      # keep a standby instance one rung up
    # strategy/hw/staging: None (default) = adopt the backend's own settings
    # so projections match what it will execute; set explicitly to override.
    strategy: Optional[str] = None
    hw: Optional[HardwareModel] = None
    staging: Optional[str] = None  # "serial" | "overlap" projection override


@dataclasses.dataclass
class DriverEvent:
    t: float
    direction: str                 # 'up' | 'down'
    src: str
    dst: str
    projected_scale_s: float       # cost-model projection used for selection
    kv_util: Optional[float] = None    # block-pool occupancy at decision
    preemptions: int = 0               # cumulative, at decision time
    staging: Optional[str] = None      # staging mode used for the projection
    # filled in when the ScalingTask completes (None until then / if the
    # backend does not report them): serve-loop time lost to staging work,
    # Σ transfer-op time / staging wall-clock (>1 = real overlap), and the
    # zero-drain scale-down's live KV-block migration volume
    stall_s: Optional[float] = None
    overlap_eff: Optional[float] = None
    migrated_blocks: Optional[int] = None
    migration_bytes: Optional[int] = None
    # serving-latency snapshot at decision time (finished requests so far;
    # NaN until the first finish): metrics.latency_percentiles
    ttft_p50: Optional[float] = None
    ttft_p99: Optional[float] = None
    itl_p50: Optional[float] = None
    itl_p99: Optional[float] = None
    # routing-telemetry snapshot at decision time (None when the backend
    # collects none): sampled ticks, layer-averaged top-expert share and
    # coefficient of variation — the skew signal a future skew-aware
    # expert-replication policy would act on (backend.routing_stats())
    routing_samples: Optional[int] = None
    routing_top_share: Optional[float] = None
    routing_cv: Optional[float] = None


class ClusterDriver:
    """SLO-aware closed loop: estimator decision -> cost-model target
    selection -> ScalingTask execution, one non-blocking poll per tick
    (serial-staging backends do one increment inside the poll).

    The driver owns the device pool and the LoadEstimator; the backend owns
    serving.  ``run()`` is the paper's §5 lifecycle as a loop you can call
    repeatedly with more arrivals (state persists across calls).
    """

    def __init__(self, backend: ServingBackend, policy: ScalingPolicy, *,
                 mcfg: ModelConfig, tp: int,
                 device_pool: Union[DevicePool, Sequence[int]],
                 config: Optional[DriverConfig] = None):
        self.backend = backend
        self.estimator = LoadEstimator(policy)
        self.mcfg = mcfg
        self.tp = tp
        # Pool ownership lives in the DevicePool allocator, not the driver:
        # a raw id sequence gets its own private pool; passing a shared
        # DevicePool makes double-booking (two drivers claiming overlapping
        # ids) raise at construction instead of silently aliasing devices.
        if not isinstance(device_pool, DevicePool):
            device_pool = DevicePool(device_pool)
        self.allocator = device_pool
        self.pool: Tuple[int, ...] = self.allocator.claim(
            mcfg.name, self.allocator.devices)
        self.config = config or DriverConfig()
        self.task: Optional[ScalingTask] = None
        self.events: List[DriverEvent] = []
        self.finished: List[Request] = []
        self.t = 0.0
        self._last_done_t = -math.inf
        self._pending: List[Request] = []
        self._pi = 0
        # Cost-model settings: adopt the backend's own (the simulator costs
        # its transitions with its kv_seq_len / hw / preinit / strategy)
        # unless the DriverConfig overrides them explicitly — projections
        # must match the t_ready the backend will actually execute.
        self._kv_len = getattr(getattr(backend, "perf", None),
                               "kv_seq_len", 4096)
        self._hw = self.config.hw or getattr(backend, "hw", None)
        self._preinit = bool(getattr(backend, "preinit", True))
        self._strategy = (self.config.strategy
                          or getattr(backend, "strategy", "elastic"))
        # pooled expert store => min-move expert migration in projections
        self._expert_mode = getattr(backend, "expert_mode", "dense")
        # overlapped staging => overlap transfer pipeline in projections
        self._staging = (self.config.staging
                         or getattr(backend, "staging_mode", "serial"))
        # migrate-mode scale-down => projections cost migration bytes via
        # the shared projected_migration_blocks policy, not drain time
        self._scaledown = getattr(backend, "scaledown_mode", "drain")
        # quantized pools => projections size KV / expert-page bytes at the
        # storage element width (halved transfer volumes for int8)
        self._kv_dtype = getattr(backend, "kv_dtype", None)
        self._expert_dtype = getattr(backend, "expert_dtype", None)

    # ------------------------------------------------------ target selection
    @property
    def _disjoint(self) -> bool:
        """extravagant/horizontal provision NEW devices next to the old."""
        return self._strategy in ("extravagant", "horizontal")

    def _target_for_dp(self, dp: int,
                       cur: Optional[ElasticConfig] = None) -> ElasticConfig:
        if self._disjoint and cur is not None:
            base = max(cur.devices) + 1
            return ElasticConfig(dp=dp, tp=self.tp,
                                 devices=tuple(range(base,
                                                     base + dp * self.tp)))
        return ElasticConfig(dp=dp, tp=self.tp,
                             devices=tuple(self.pool[:dp * self.tp]))

    def _fits_pool(self, dp: int, cur: ElasticConfig) -> bool:
        need = dp * self.tp + (cur.ndev if self._disjoint else 0)
        return need <= len(self.pool)

    def ladder(self) -> List[ElasticConfig]:
        max_dp = len(self.pool) // self.tp
        return [self._target_for_dp(d)
                for d in range(self.config.min_dp, max_dp + 1,
                               self.config.step_dp)]

    def projected_cost_s(self, old: ElasticConfig,
                         new: ElasticConfig) -> float:
        """Cost-model projection of the transition's scale time (DESIGN.md
        §6) via the shared ``transition_cost`` path."""
        page_table = None
        if self._expert_mode == "pooled":
            # cost from the backend's LIVE placement (post previous remaps
            # AND rebalances — replicas price zero-copy keeps, host-tier
            # experts price H2D instead of P2P), not a hypothetical
            # contiguous boot at `old`.  ElasticServer exposes it through
            # hmm.page_table, the simulator as expert_pages.
            page_table = getattr(getattr(self.backend, "hmm", None),
                                 "page_table", None)
            if page_table is None:
                page_table = getattr(self.backend, "expert_pages", None)
        kv_mig = 0
        if new.dp < old.dp and self._scaledown == "migrate":
            # project the live occupancy that must evacuate doomed
            # partitions — same policy the simulator executes with
            kv = getattr(self.backend, "kv_stats", lambda: None)() or {}
            kv_mig = (projected_migration_blocks(
                kv.get("used_blocks", 0), old.dp, new.dp)
                * int(kv.get("block_bytes", 0)))
        try:
            return transition_cost(self.mcfg, self.tp, old, new,
                                   strategy=self._strategy, hw=self._hw,
                                   preinit=self._preinit,
                                   kv_seq_len=self._kv_len,
                                   expert_mode=self._expert_mode,
                                   page_table=page_table,
                                   staging=self._staging,
                                   kv_migration_bytes=kv_mig,
                                   kv_dtype=self._kv_dtype,
                                   expert_dtype=self._expert_dtype
                                   ).scale_time_s
        except MemoryError:
            # the live page pool cannot host this target's staged pages —
            # executing the transition would fail the same way, so veto the
            # candidate instead of crashing the control loop
            return math.inf

    def select_target(self, direction: str
                      ) -> Optional[Tuple[ElasticConfig, float]]:
        """Pick the next config at step granularity; returns
        ``(target, projected_scale_s)`` or None.

        Up: the smallest rung (within ``max_step_dp``) whose backend capacity
        covers current demand (active + queued), falling back to the largest
        affordable rung; candidates whose projected scale time exceeds
        ``scale_budget_s`` are vetoed.  Down: one rung, only if the remaining
        capacity still covers the active load with headroom (not supported
        for the disjoint-provisioning strategies).
        """
        cur = self.backend.current_config()
        cfg = self.config
        if direction == "up":
            rungs = [d for d in range(cur.dp + cfg.step_dp,
                                      cur.dp + cfg.max_step_dp * cfg.step_dp
                                      + 1, cfg.step_dp)
                     if self._fits_pool(d, cur)]
            if not rungs:
                return None
            demand = (self.backend.utilization()
                      * self.backend.capacity(cur)
                      + self.backend.queue_depth())
            affordable = []
            for d in rungs:
                cand = self._target_for_dp(d, cur)
                proj = self.projected_cost_s(cur, cand)
                if proj <= cfg.scale_budget_s and math.isfinite(proj):
                    affordable.append((cand, proj))
            if not affordable:
                return None
            for cand, proj in affordable:
                if self.backend.capacity(cand) >= demand:
                    return cand, proj
            return affordable[-1]
        # down: one rung, with capacity headroom for what's still running
        if self._disjoint:
            return None
        d = cur.dp - cfg.step_dp
        if d < cfg.min_dp:
            return None
        cand = self._target_for_dp(d, None)
        active = self.backend.utilization() * self.backend.capacity(cur)
        if self.backend.capacity(cand) < active * 1.25 \
                or self.backend.queue_depth():
            return None
        proj = self.projected_cost_s(cur, cand)
        if not math.isfinite(proj):
            return None                # live page pool cannot host the target
        return cand, proj

    # -------------------------------------------------------------- the loop
    def run(self, requests: Sequence[Request], until: float) -> List[Request]:
        """Advance the closed loop to ``until``.  ``requests`` are *added* to
        the pending arrival set; call again with more to continue."""
        if requests:
            self._pending = merge_arrivals(self._pending, self._pi, requests)
            self._pi = 0
        cfgd = self.config
        while self.t < until:
            t = self.t
            while self._pi < len(self._pending) \
                    and self._pending[self._pi].arrival_s <= t:
                self.backend.submit(self._pending[self._pi])
                self._pi += 1
            # serve one tick, then one non-blocking task poll (serial
            # backends do at most one staging increment inside it) — the
            # serve loop never waits on a bulk transfer
            finished = self.backend.step(t)
            for r in finished:
                self.estimator.record(r)
            self.finished.extend(finished)
            if self.task is not None:
                phase = self.task.advance(t)
                if phase.terminal:
                    if self.events:
                        # completion metrics into the event log: stall +
                        # overlap efficiency (metrics.summarize surfaces
                        # the backend-level aggregate)
                        ev = self.events[-1]
                        ev.stall_s = getattr(self.task, "stall_s", None)
                        ev.overlap_eff = getattr(
                            self.task, "overlap_efficiency", None)
                        ev.migrated_blocks = getattr(
                            self.task, "migrated_blocks", None)
                        ev.migration_bytes = getattr(
                            self.task, "migration_bytes", None)
                    self.task = None
                    self._last_done_t = t
            elif t - self._last_done_t >= cfgd.settle_s:
                decision = self.estimator.decide(
                    t, self.backend.queue_depth(),
                    self.backend.utilization())
                if decision:
                    picked = self.select_target(decision)
                    if picked is not None:
                        target, proj = picked
                        cur = self.backend.current_config()
                        kv = getattr(self.backend, "kv_stats",
                                     lambda: None)()
                        rt = getattr(self.backend, "routing_stats",
                                     lambda: None)() or {}
                        self.events.append(DriverEvent(
                            t=t, direction=decision, src=cur.describe(),
                            dst=target.describe(), projected_scale_s=proj,
                            kv_util=(kv or {}).get("utilization"),
                            preemptions=int((kv or {}).get(
                                "preemptions", 0)),
                            staging=self._staging,
                            routing_samples=rt.get("samples"),
                            routing_top_share=rt.get("top_expert_share"),
                            routing_cv=rt.get("expert_cv"),
                            **latency_percentiles(self.finished)))
                        self.task = self.backend.start_scale(target)
                        if cfgd.prewarm_next and decision == "up" \
                                and not self._disjoint:
                            nxt = target.dp + cfgd.step_dp
                            if self._fits_pool(nxt, target):
                                self.backend.prewarm(
                                    self._target_for_dp(nxt))
            self.t += cfgd.dt
        return self.finished
