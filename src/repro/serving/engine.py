"""Inference engine: continuous batching over an elastic instance.

The engine executes *real* JAX on the instance's mesh.  Decode slots are
rows of the HMM-owned global KV cache; scaling grows the slot count and the
surviving slots' state is reused zero-copy (the paper's "seamless handoff,
same KV cache", §5.2) — the determinism test asserts that tokens generated
across a scale-up event are identical to an unscaled run.

Step functions are AOT-compiled per (ElasticConfig, shape bucket); the IMM
caches them — compilation is the JAX analogue of instance pre-initialization.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.topology import ElasticConfig
from repro.distributed.sharding import ParallelCtx
from repro.models import model as M


def engine_parallel_ctx(mesh) -> ParallelCtx:
    return ParallelCtx(mesh=mesh, ep_axes=("dp", "tp"), tp_axis="tp",
                       dp_axes=("dp",), moe_tp=False)


def _decode_fn(mcfg: ModelConfig, parallel, temperature, params, cache,
               tokens, lengths, active, rng):
    logits, cache = M.decode_step(mcfg, params, tokens[:, None], cache,
                                  lengths, parallel=parallel)
    if temperature and temperature > 0:
        nxt = jax.random.categorical(
            rng, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)
    else:
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    nxt = jnp.where(active, nxt, tokens)
    return nxt, cache


def _prefill_fn(mcfg: ModelConfig, parallel, max_len, params, cache, tokens,
                length, slot):
    """Prefill one request (padded to a bucket) into cache row ``slot``."""
    logits, small = M.prefill(mcfg, params,
                              {"tokens": tokens, "lengths": length[None]},
                              max_len=max_len, parallel=parallel)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]

    def put(big, new):
        # big: [L, B, ...]; new: [L, 1, ...] -> overwrite row `slot`
        idx = (0, slot) + (0,) * (big.ndim - 2)
        return jax.lax.dynamic_update_slice(big, new.astype(big.dtype), idx)

    cache = jax.tree.map(put, cache, small)
    return first, cache


@dataclasses.dataclass
class SlotState:
    rid: int = -1
    remaining: int = 0
    active: bool = False


class InferenceEngine:
    """Continuous-batching engine bound to one (cfg, mesh, compiled steps).

    The engine object survives scaling: ``rebind`` swaps in the new
    instance's mesh/cache/compiled functions while preserving slot states.
    """

    def __init__(self, mcfg: ModelConfig, *, batch_per_replica: int,
                 max_len: int, prefill_bucket: int = 64):
        self.mcfg = mcfg
        self.batch_per_replica = batch_per_replica
        self.max_len = max_len
        self.prefill_bucket = prefill_bucket
        self.cfg: Optional[ElasticConfig] = None
        self.params = None
        self.cache = None
        self.compiled: Dict[str, Any] = {}
        self.slots: List[SlotState] = []
        self.lengths: Optional[np.ndarray] = None
        self.tokens: Optional[np.ndarray] = None
        self.generated: Dict[int, List[int]] = {}
        self.admit_limit: Optional[int] = None  # scale-down drain barrier

    # ------------------------------------------------------------- binding
    @property
    def num_slots(self) -> int:
        return 0 if self.cfg is None else self.cfg.dp * self.batch_per_replica

    def bind(self, cfg: ElasticConfig, mesh, params, cache, compiled):
        old_slots = self.slots
        old_lengths = self.lengths
        old_tokens = self.tokens
        self.cfg, self.mesh = cfg, mesh
        self.params, self.cache = params, cache
        self.compiled = compiled
        n = self.num_slots
        self.slots = [SlotState() for _ in range(n)]
        self.lengths = np.zeros((n,), np.int32)
        self.tokens = np.zeros((n,), np.int32)
        # surviving slots keep their requests (zero-copy KV reuse)
        for i in range(min(len(old_slots), n)):
            self.slots[i] = old_slots[i]
            self.lengths[i] = old_lengths[i]
            self.tokens[i] = old_tokens[i]

    def free_slots(self) -> List[int]:
        lim = self.admit_limit if self.admit_limit is not None else len(self.slots)
        return [i for i, s in enumerate(self.slots) if not s.active and i < lim]

    def drained(self, keep: int) -> bool:
        """True when all slots >= keep are inactive (scale-down ready)."""
        return all(not s.active for s in self.slots[keep:])

    def active_count(self) -> int:
        return sum(1 for s in self.slots if s.active)

    def utilization(self) -> float:
        """Occupied fraction of decode slots (drives the load estimator)."""
        return self.active_count() / max(self.num_slots, 1)

    # ------------------------------------------------------------- serving
    def start_request(self, req, prompt: np.ndarray, slot: int):
        S = len(prompt)
        bucket = self.prefill_bucket
        S_pad = max(bucket, -(-S // bucket) * bucket)
        toks = np.zeros((1, S_pad), np.int32)
        toks[0, :S] = prompt
        key = f"prefill_{S_pad}"
        first, self.cache = self.compiled[key](
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(S, jnp.int32), jnp.asarray(slot, jnp.int32))
        self.slots[slot] = SlotState(rid=req.rid, remaining=req.output_len - 1,
                                     active=req.output_len > 1)
        self.lengths[slot] = S
        first = int(first)
        self.tokens[slot] = first
        self.generated[req.rid] = [first]
        if req.output_len <= 1:
            self.slots[slot].active = False
        return first

    def decode_tick(self) -> List[Tuple[int, int, bool]]:
        """One decode step for all active slots.
        Returns [(rid, token, finished)] for slots that produced a token."""
        if self.active_count() == 0:
            return []
        active = np.array([s.active for s in self.slots])
        self._step_count = getattr(self, "_step_count", 0) + 1
        rng = jax.random.key_data(jax.random.PRNGKey(self._step_count))
        nxt, self.cache = self.compiled["decode"](
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.lengths), jnp.asarray(active), rng)
        nxt = np.asarray(nxt)
        out = []
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            self.lengths[i] += 1
            self.tokens[i] = nxt[i]
            self.generated[s.rid].append(int(nxt[i]))
            s.remaining -= 1
            fin = s.remaining <= 0 or self.lengths[i] >= self.max_len - 1
            if fin:
                s.active = False
            out.append((s.rid, int(nxt[i]), fin))
        return out


# ------------------------------------------------------------- compilation

def as_sds(tree):
    """pytree of arrays (or SDS) -> pytree of sharded ShapeDtypeStructs."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=x.sharding),
        tree)


def compile_step_functions(mcfg: ModelConfig, cfg: ElasticConfig, mesh,
                           params_sds, cache_sds, *,
                           batch_per_replica: int, max_len: int,
                           prefill_buckets=(64,),
                           temperature: float = 0.0
                           ) -> Tuple[Dict[str, Any], float]:
    """AOT-compile decode + prefill executables for an instance.

    ``params_sds``/``cache_sds``: pytrees of sharded ShapeDtypeStructs (no
    weights needed — pre-initialization works without the HMM, exactly the
    paper's CPU-standby instances, §4.5).  Returns (executables, seconds).
    """
    t0 = time.perf_counter()
    parallel = engine_parallel_ctx(mesh)
    B = cfg.dp * batch_per_replica
    repl = NamedSharding(mesh, P())

    out: Dict[str, Any] = {}
    cache_out = jax.tree.map(lambda s: s.sharding, cache_sds)
    dec = jax.jit(
        partial(_decode_fn, mcfg, parallel, temperature),
        donate_argnums=(1,),
        out_shardings=(repl, cache_out),
    )
    tok_sd = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=repl)
    rng_sd = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=repl)
    out["decode"] = dec.lower(params_sds, cache_sds, tok_sd, tok_sd,
                              jax.ShapeDtypeStruct((B,), jnp.bool_,
                                                   sharding=repl),
                              rng_sd).compile()
    for S_pad in prefill_buckets:
        pf = jax.jit(partial(_prefill_fn, mcfg, parallel, max_len),
                     donate_argnums=(1,),
                     out_shardings=(repl, cache_out))
        toks = jax.ShapeDtypeStruct((1, S_pad), jnp.int32, sharding=repl)
        out[f"prefill_{S_pad}"] = pf.lower(
            params_sds, cache_sds, toks,
            jax.ShapeDtypeStruct((), jnp.int32, sharding=repl),
            jax.ShapeDtypeStruct((), jnp.int32, sharding=repl)).compile()
    return out, time.perf_counter() - t0
