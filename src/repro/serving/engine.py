"""Inference engine: continuous batching over an elastic instance.

The engine executes *real* JAX on the instance's mesh.  Two KV layouts:

* **dense** (``kv_mode='dense'``): decode slots are rows of the HMM-owned
  global ``[L, B, max_len, ...]`` cache; every admitted request reserves a
  full-length row.
* **paged** (``kv_mode='paged'``): the cache is a block *pool*
  ``[L, NB, bs, ...]`` and each slot holds a block table
  (``serving/kv_blocks.py``).  Admission is gated by free blocks, shared
  prompt prefixes are copy-on-write, and when a partition's pool runs dry
  the lowest-priority sequence is preempted (freed + re-queued; recomputed
  on resume).  Decode attention gathers K/V through the block table
  (``kernels.ops.block_paged_decode_attention``).

Scaling grows the slot count (dense) or appends pool partitions (paged) and
the surviving slots' state is reused zero-copy (the paper's "seamless
handoff, same KV cache", §5.2) — with paged KV the survivors' block tables
stay valid *verbatim*, and the determinism test asserts that tokens
generated across a scale-up event are identical to an unscaled run.

Step functions are AOT-compiled per (ElasticConfig, shape bucket); the IMM
caches them — compilation is the JAX analogue of instance pre-initialization.

The engine is parameter-layout agnostic: with the HMM's pooled expert store
(``expert_mode='pooled'``, DESIGN.md §2) the params pytree it binds carries
page pools + table index arrays instead of dense expert banks, the decode/
prefill functions route the MoE through the paged-GMM path, and a scale
event rebind only swaps tables — the engine code is unchanged either way.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.topology import ElasticConfig
from repro.distributed.sharding import ParallelCtx
from repro.models import model as M
from repro.serving.kv_blocks import KVBlockManager, MigrationTicket
from repro.serving.scheduler import (PrefillJob, TokenBudgetScheduler,
                                     prefix_skip)


def engine_parallel_ctx(mesh) -> ParallelCtx:
    return ParallelCtx(mesh=mesh, ep_axes=("dp", "tp"), tp_axis="tp",
                       dp_axes=("dp",), moe_tp=False)


def _sample(logits, tokens, active, rng, temperature):
    if temperature and temperature > 0:
        nxt = jax.random.categorical(
            rng, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)
    else:
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.where(active, nxt, tokens)


def _decode_fn(mcfg: ModelConfig, parallel, temperature, params, cache,
               tokens, lengths, active, rng):
    logits, cache = M.decode_step(mcfg, params, tokens[:, None], cache,
                                  lengths, parallel=parallel)
    return _sample(logits, tokens, active, rng, temperature), cache


def _paged_decode_fn(mcfg: ModelConfig, parallel, temperature, params, cache,
                     tokens, lengths, active, block_tables, rng):
    """Paged decode: block_tables [B, MB]; the write block is derived from
    each sequence's length; inactive slots write to the NB sentinel row
    (dropped)."""
    NB, bs = cache["k"].shape[1], cache["k"].shape[2]
    wb = jnp.take_along_axis(block_tables, (lengths // bs)[:, None], 1)[:, 0]
    wb = jnp.where(active, wb, NB)
    logits, cache = M.paged_decode_step(mcfg, params, tokens[:, None], cache,
                                        lengths, block_tables, wb,
                                        parallel=parallel)
    return _sample(logits, tokens, active, rng, temperature), cache


def _decode_routed_fn(mcfg: ModelConfig, parallel, temperature, params,
                      cache, tokens, lengths, active, rng):
    """Routing-telemetry decode: identical math plus per-(layer, expert)
    token counts [L_moe, E] from the MoE routers (models/moe.py)."""
    logits, cache, counts = M.decode_step(
        mcfg, params, tokens[:, None], cache, lengths, parallel=parallel,
        collect_routing=True)
    return _sample(logits, tokens, active, rng, temperature), cache, counts


def _paged_decode_routed_fn(mcfg: ModelConfig, parallel, temperature,
                            params, cache, tokens, lengths, active,
                            block_tables, rng):
    NB, bs = cache["k"].shape[1], cache["k"].shape[2]
    wb = jnp.take_along_axis(block_tables, (lengths // bs)[:, None], 1)[:, 0]
    wb = jnp.where(active, wb, NB)
    logits, cache, counts = M.paged_decode_step(
        mcfg, params, tokens[:, None], cache, lengths, block_tables, wb,
        parallel=parallel, collect_routing=True)
    return _sample(logits, tokens, active, rng, temperature), cache, counts


def _prefill_fn(mcfg: ModelConfig, parallel, max_len, params, cache, tokens,
                length, slot):
    """Prefill one request (padded to a bucket) into cache row ``slot``."""
    logits, small = M.prefill(mcfg, params,
                              {"tokens": tokens, "lengths": length[None]},
                              max_len=max_len, parallel=parallel)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]

    def put(big, new):
        # big: [L, B, ...]; new: [L, 1, ...] -> overwrite row `slot`
        idx = (0, slot) + (0,) * (big.ndim - 2)
        return jax.lax.dynamic_update_slice(big, new.astype(big.dtype), idx)

    cache = jax.tree.map(put, cache, small)
    return first, cache


def _paged_prefill_fn(mcfg: ModelConfig, parallel, params, cache, tokens,
                      length, block_ids):
    """Prefill one request and scatter its KV into pool blocks.

    ``block_ids`` [S_pad/bs]: pool row per prompt chunk; the NB sentinel
    marks both padding chunks and CoW-shared prefix blocks (already resident
    with identical contents — rewriting them would clobber a co-owner's
    tokens beyond this prompt's length)."""
    S_pad = tokens.shape[1]
    logits, small = M.prefill(mcfg, params,
                              {"tokens": tokens, "lengths": length[None]},
                              max_len=S_pad, parallel=parallel)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]
    cache = M.write_prefill_to_blocks(cache, small, block_ids)
    return first, cache


def _chunk_prefill_fn(mcfg: ModelConfig, parallel, params, cache, tokens,
                      start, length, slot):
    """One dense-KV prefill chunk: tokens [1, C] are prompt positions
    [start, start+C) of cache row ``slot``; ``length`` is the prompt length
    covered so far (start + valid tokens in this chunk).  The returned token
    is the argmax at the last valid position — only meaningful on the final
    chunk (continuous batching, serving/scheduler.py)."""
    logits, cache = M.chunk_prefill_step(mcfg, params, tokens, cache, start,
                                         length, slot, parallel=parallel)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]
    return first, cache


def _paged_chunk_prefill_fn(mcfg: ModelConfig, parallel, params, cache,
                            tokens, start, length, block_tables, chunk_ids):
    """One paged prefill chunk: the chunk's KV scatters into pool rows
    ``chunk_ids`` (NB sentinel = padding or CoW-shared block; dropped) and
    attention reads the whole context through ``block_tables`` [1, MB] via
    the mixed prefill/decode kernel."""
    logits, cache = M.paged_chunk_prefill_step(mcfg, params, tokens, cache,
                                               start, length, block_tables,
                                               chunk_ids, parallel=parallel)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[0]
    return first, cache


@partial(jax.jit, donate_argnums=(0,))
def _cow_copy(cache, src, dst):
    """Copy pool block row ``src`` -> ``dst`` in every layer of every pool
    tensor; donation lets XLA alias the buffers (in-place on the pool)."""
    return jax.tree.map(
        lambda p: p.at[:, dst].set(
            jax.lax.dynamic_index_in_dim(p, src, axis=1, keepdims=False)),
        cache)


@dataclasses.dataclass
class SlotState:
    rid: int = -1
    remaining: int = 0
    active: bool = False
    priority: int = 0
    # live KV-block migration (scale-down): a migrating slot's sequence is
    # paused (its blocks are frozen while copies are in flight); a reserved
    # slot is the migration's destination and must not admit anything else
    migrating: bool = False
    reserved: bool = False
    # chunked prefill: admitted but not fully prefilled — occupies the slot
    # (and its KV blocks) but is excluded from decode until the final chunk
    prefilling: bool = False


@dataclasses.dataclass
class MigrationJob:
    """One in-flight slot migration: a sharing component of doomed slots
    moving to reserved survivor slots.  ``ticket.pairs`` is the device copy
    list; ``moves`` maps each sequence to its (src_slot, dst_slot)."""
    ticket: MigrationTicket
    moves: List[Tuple[int, int, int]]      # (rid, src_slot, dst_slot)


class InferenceEngine:
    """Continuous-batching engine bound to one (cfg, mesh, compiled steps).

    The engine object survives scaling: ``bind`` swaps in the new
    instance's mesh/cache/compiled functions while preserving slot states
    (and, in paged mode, block tables — the pool only grows/shrinks whole
    partitions, so surviving tables need no translation).
    """

    #: max lazily-compiled prefill buckets retained (satellite fix: the
    #: bucket cache used to grow without bound as preemption resumes pushed
    #: effective prompt lengths through ever-new buckets — each entry is a
    #: full XLA executable, and via the IMM's aliased ``compiled`` dict the
    #: leak outlived rebinds; AOT-precompiled buckets are never evicted)
    MAX_LAZY_PREFILL = 8

    def __init__(self, mcfg: ModelConfig, *, batch_per_replica: int,
                 max_len: int, prefill_bucket: int = 64,
                 prefill_chunk: int = 0,
                 prefill_budget: Optional[int] = None,
                 routing_sample_every: int = 0):
        self.mcfg = mcfg
        self.batch_per_replica = batch_per_replica
        self.max_len = max_len
        self.prefill_bucket = prefill_bucket
        # routing telemetry: every Nth decode tick runs the counts-emitting
        # "decode_routed" executable (when the bound instance compiled one)
        # and accumulates host-side per-(layer, expert) histograms
        self.routing_sample_every = routing_sample_every
        self._routing_counts: Optional[np.ndarray] = None
        self._routing_samples = 0
        # continuous batching: >0 splits prefill into fixed `prefill_chunk`-
        # token buckets interleaved with decode ticks under a per-tick token
        # budget (serving/scheduler.py); 0 = monolithic prefill at admission
        self.prefill_chunk = prefill_chunk
        self.scheduler = (TokenBudgetScheduler(prefill_chunk, prefill_budget)
                          if prefill_chunk > 0 else None)
        self._prefilling: List[PrefillJob] = []       # FIFO, admission order
        # slot -> (full prompt, resumed): host-side context for chunk jobs
        self._chunk_ctx: Dict[int, Tuple[np.ndarray, bool]] = {}
        self._lazy_prefill: "OrderedDict[str, None]" = OrderedDict()
        self.cfg: Optional[ElasticConfig] = None
        self.params = None
        self.cache = None
        self.compiled: Dict[str, Any] = {}
        self.slots: List[SlotState] = []
        self.lengths: Optional[np.ndarray] = None
        self.tokens: Optional[np.ndarray] = None
        self.generated: Dict[int, List[int]] = {}
        self.admit_limit: Optional[int] = None  # scale-down drain barrier
        # paged-KV state (kv_mode='paged'); see serving/kv_blocks.py
        self.kv: Optional[KVBlockManager] = None
        self.block_tables: Optional[np.ndarray] = None
        self._preempted_pending: List[int] = []   # rids awaiting re-queue
        self._resume_rids: set = set()            # preempted at least once
        self._finished_at_admission: List[int] = []
        self.preemptions = 0
        # serializes every mutation of ``self.cache`` (the compiled steps
        # donate it, so the handle is replaced each call): decode/prefill on
        # the serve thread vs per-block migration copies on the
        # TransferEngine workers (copy_block)
        self._cache_lock = threading.Lock()

    # ------------------------------------------------------------- binding
    @property
    def num_slots(self) -> int:
        return 0 if self.cfg is None else self.cfg.dp * self.batch_per_replica

    @property
    def paged(self) -> bool:
        return self.kv is not None

    def bind(self, cfg: ElasticConfig, mesh, params, cache, compiled,
             kv: Optional[KVBlockManager] = None):
        old_slots = self.slots
        old_lengths = self.lengths
        old_tokens = self.tokens
        old_tables = self.block_tables
        self.cfg, self.mesh = cfg, mesh
        self.params, self.cache = params, cache
        self.compiled = compiled
        self.kv = kv
        n = self.num_slots
        self.slots = [SlotState() for _ in range(n)]
        self.lengths = np.zeros((n,), np.int32)
        self.tokens = np.zeros((n,), np.int32)
        if self.prefill_chunk:
            assert M.chunk_prefill_supported(self.mcfg), \
                "chunked prefill unsupported for this model config"
        if self.paged:
            bs = self.kv.block_size
            assert self.max_len % bs == 0 and self.prefill_bucket % bs == 0, \
                "max_len and prefill buckets must be block-size multiples"
            assert self.prefill_chunk % bs == 0, \
                "prefill_chunk must be a block-size multiple (paged KV)"
            # padding rows hold the NB sentinel (never block id 0, which is
            # a valid pool row); NB tracks the *current* pool size, so
            # tables are rebuilt from the block manager on every rebind
            self.block_tables = np.full((n, self.max_len // bs),
                                        self.kv.num_blocks, np.int32)
        # surviving slots keep their requests (zero-copy KV reuse)
        for i in range(min(len(old_slots), n)):
            self.slots[i] = old_slots[i]
            self.lengths[i] = old_lengths[i]
            self.tokens[i] = old_tokens[i]
            if self.paged and old_tables is not None \
                    and self.slots[i].active:
                tbl = self.kv.block_table(self.slots[i].rid)
                self.block_tables[i, :len(tbl)] = tbl
        # chunk jobs survive rebinds slot-for-slot (scale-down migrates or
        # drains their slots first, so none can reference a dropped slot)
        self._prefilling = [j for j in self._prefilling if j.slot < n]
        self._chunk_ctx = {s: c for s, c in self._chunk_ctx.items() if s < n}
        # the new instance's compiled dict may not carry the old lazily-
        # compiled buckets; keep LRU bookkeeping consistent with it
        self._lazy_prefill = OrderedDict(
            (k, None) for k in self._lazy_prefill if k in compiled)

    def unbind(self):
        """Drop every device-array reference (park / scale-to-zero,
        DESIGN.md §12): the HMM has snapshotted the weights host-side, and
        the engine holding the old handles would keep the device buffers
        alive past the release.  Callers drain first — refusing to unbind
        under live sequences keeps park from silently killing requests."""
        assert self.active_count() == 0, "unbind with active sequences"
        self.cfg = None
        self.mesh = None
        self.params = None
        self.cache = None
        self.compiled = {}
        self.kv = None
        self.block_tables = None
        self.slots = []
        self.lengths = None
        self.tokens = None
        self._prefilling = []
        self._chunk_ctx = {}
        self._lazy_prefill = OrderedDict()
        self.admit_limit = None

    def free_slots(self) -> List[int]:
        lim = self.admit_limit if self.admit_limit is not None else len(self.slots)
        return [i for i, s in enumerate(self.slots)
                if not s.active and not s.reserved and i < lim]

    def drained(self, keep: int) -> bool:
        """True when all slots >= keep are inactive (scale-down ready)."""
        return all(not s.active for s in self.slots[keep:])

    def active_count(self) -> int:
        return sum(1 for s in self.slots if s.active)

    def utilization(self) -> float:
        """Occupied fraction of *admissible* serving capacity (drives the
        load estimator): slot occupancy dense, block-pool occupancy paged.

        During a scale-down, capacity is what survives the transition
        (``admit_limit`` slots / partitions) — counting doomed slots would
        deflate the load signal exactly while the estimator is judging
        whether the shrink was a good idea."""
        if self.paged:
            cap = self.kv.num_blocks
            if self.admit_limit is not None:
                parts = max(1, self.admit_limit // self.batch_per_replica)
                cap = min(cap, parts * self.kv.blocks_per_partition)
            return self.kv.used_blocks() / max(cap, 1)
        lim = (len(self.slots) if self.admit_limit is None
               else max(1, min(self.admit_limit, len(self.slots))))
        return self.active_count() / max(lim, 1)

    def kv_stats(self) -> Optional[Dict[str, float]]:
        if not self.paged:
            return None
        st = self.kv.stats()
        st["preemptions"] = self.preemptions
        st["block_bytes"] = self.block_nbytes()
        # single source of truth: the manager counts committed migrations
        # (kv.stats already reports migrated_blocks); bytes are derived
        st["migration_bytes"] = (self.kv.migrated_blocks
                                 * self.block_nbytes())
        return st

    # ------------------------------------------------------------- serving
    def _partition(self, slot: int) -> int:
        return slot // self.batch_per_replica

    def _full_prompt(self, req, prompt: np.ndarray) -> np.ndarray:
        """Preemption resume (recompute mode): the effective prompt is the
        original prompt plus everything generated before eviction."""
        if req.rid in self._resume_rids and self.generated.get(req.rid):
            return np.concatenate(
                [np.asarray(prompt, np.int32),
                 np.asarray(self.generated[req.rid], np.int32)])
        return np.asarray(prompt, np.int32)

    def can_admit(self, req, prompt: np.ndarray, slot: int) -> bool:
        if not self.paged:
            return True
        full = self._full_prompt(req, prompt)
        # +1: the first decode token must be appendable without preemption
        return self.kv.can_allocate(len(full) + 1, self._partition(slot),
                                    tokens=[int(t) for t in full])

    def preferred_slots(self, req, prompt: np.ndarray,
                        free: List[int]) -> List[int]:
        """Prefix-cache-aware admission order: free slots sorted so
        partitions already holding the longest registered prefix of this
        prompt come first — binding there turns the shared prefix into a
        refcount bump plus a prefill skip instead of recomputation (sharing
        is partition-local, kv_blocks.py).  Ties keep slot order, so the
        dense layout and prefix-free workloads are byte-identical to the
        old first-free-slot policy."""
        if not self.paged or len(free) <= 1:
            return list(free)
        full = self._full_prompt(req, prompt)
        toks = [int(t) for t in full]
        score = {p: len(self.kv.prefix_match_blocks(p, toks))
                 for p in {self._partition(s) for s in free}}
        return sorted(free, key=lambda s: (-score[self._partition(s)], s))

    def start_request(self, req, prompt: np.ndarray, slot: int):
        """Admit ``req`` into ``slot``.  Monolithic mode (prefill_chunk=0)
        runs the whole padded prompt here and returns the first generated
        token; chunked mode only allocates KV + enqueues a PrefillJob and
        returns None — the first token arrives from ``decode_tick`` when the
        final chunk lands."""
        if self.prefill_chunk:
            return self._start_request_chunked(req, prompt, slot)
        resume = req.rid in self._resume_rids
        full = self._full_prompt(req, prompt)
        S = len(full)
        bucket = self.prefill_bucket
        S_pad = max(bucket, -(-S // bucket) * bucket)
        toks = np.zeros((1, S_pad), np.int32)
        toks[0, :S] = full
        if self.paged:
            alloc = self.kv.allocate(req.rid, S,
                                     partition=self._partition(slot),
                                     priority=getattr(req, "priority", 0),
                                     tokens=[int(t) for t in full])
            bs = self.kv.block_size
            ids = np.full((S_pad // bs,), self.kv.num_blocks, np.int32)
            for j, b in enumerate(alloc.blocks):
                if j >= alloc.num_shared:      # shared prefix: don't rewrite
                    ids[j] = b
            with self._cache_lock:
                first, self.cache = self._prefill(S_pad)(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(S, jnp.int32), jnp.asarray(ids))
            # clear the previous occupant's rows with the NB sentinel, NOT
            # 0 — block 0 is a valid pool row, and a stale row beyond this
            # request's (possibly shorter) table must never alias a block
            # another sequence owns (module docstring: NB marks padding)
            self.block_tables[slot, :] = self.kv.num_blocks
            self.block_tables[slot, :len(alloc.blocks)] = alloc.blocks
        else:
            with self._cache_lock:
                first, self.cache = self._prefill(S_pad)(
                    self.params, self.cache, jnp.asarray(toks),
                    jnp.asarray(S, jnp.int32), jnp.asarray(slot, jnp.int32))
        produced = len(self.generated.get(req.rid, [])) if resume else 0
        remaining = req.output_len - produced - 1
        self.slots[slot] = SlotState(rid=req.rid, remaining=remaining,
                                     active=remaining > 0,
                                     priority=getattr(req, "priority", 0))
        self.lengths[slot] = S
        first = int(first)
        self.tokens[slot] = first
        if resume:
            self._resume_rids.discard(req.rid)
            self.generated[req.rid].append(first)
        else:
            self.generated[req.rid] = [first]
        if remaining <= 0:
            # the prefill token was the last one (output_len 1, or a
            # preemption resume that only had its final token left): the
            # request never reaches decode_tick, so completion must be
            # reported here or the caller waits on it forever
            self.slots[slot].active = False
            if self.paged:
                self.kv.free(req.rid)
            self._finished_at_admission.append(req.rid)
        return first

    def _start_request_chunked(self, req, prompt: np.ndarray, slot: int):
        """Chunked admission: no model compute runs here.  Paged KV is
        allocated up-front (occupancy-gated exactly like the monolithic
        path, so ``can_admit`` is unchanged) but prefix chains register only
        as chunks are written (``register_written``) — a matching arrival
        must never bind to blocks whose contents are still pending.  The
        job starts past the CoW-shared prefix (``prefix_skip``), charging
        only the non-shared tail against the token budget."""
        resume = req.rid in self._resume_rids
        full = self._full_prompt(req, prompt)
        S = len(full)
        start = 0
        if self.paged:
            alloc = self.kv.allocate(req.rid, S,
                                     partition=self._partition(slot),
                                     priority=getattr(req, "priority", 0),
                                     tokens=[int(t) for t in full],
                                     register=False)
            self.block_tables[slot, :] = self.kv.num_blocks
            self.block_tables[slot, :len(alloc.blocks)] = alloc.blocks
            start = prefix_skip(alloc.num_shared, self.kv.block_size, S)
        produced = len(self.generated.get(req.rid, [])) if resume else 0
        remaining = req.output_len - produced - 1
        self.slots[slot] = SlotState(rid=req.rid, remaining=remaining,
                                     active=True, prefilling=True,
                                     priority=getattr(req, "priority", 0))
        self.lengths[slot] = S
        if resume:
            self._resume_rids.discard(req.rid)
        self._chunk_ctx[slot] = (full,
                                 resume and bool(self.generated.get(req.rid)))
        self._prefilling.append(PrefillJob(slot=slot, rid=req.rid,
                                           pos=start, total=S))
        return None

    def drain_finished_at_admission(self) -> List[int]:
        """Requests whose prefill produced their final token this tick."""
        out, self._finished_at_admission = self._finished_at_admission, []
        return out

    def _prefill(self, S_pad: int):
        """Compiled prefill for a bucket; paged mode lazily compiles unseen
        buckets (preemption resume grows effective prompts past the
        pre-compiled set).  Lazy buckets are LRU-bounded at
        ``MAX_LAZY_PREFILL`` — AOT-precompiled buckets are never evicted
        (regression test: tests/test_paged_engine.py)."""
        key = f"prefill_{S_pad}"
        if key in self.compiled:
            if key in self._lazy_prefill:
                self._lazy_prefill.move_to_end(key)
            return self.compiled[key]
        assert self.paged, f"no compiled {key}"
        parallel = engine_parallel_ctx(self.mesh)
        repl = NamedSharding(self.mesh, P())
        cache_out = jax.tree.map(lambda x: x.sharding, self.cache)
        pf = jax.jit(partial(_paged_prefill_fn, self.mcfg, parallel),
                     donate_argnums=(1,),
                     out_shardings=(repl, cache_out))
        bs = self.kv.block_size
        self.compiled[key] = pf.lower(
            as_sds(self.params), as_sds(self.cache),
            jax.ShapeDtypeStruct((1, S_pad), jnp.int32, sharding=repl),
            jax.ShapeDtypeStruct((), jnp.int32, sharding=repl),
            jax.ShapeDtypeStruct((S_pad // bs,), jnp.int32,
                                 sharding=repl)).compile()
        self._lazy_prefill[key] = None
        while len(self._lazy_prefill) > self.MAX_LAZY_PREFILL:
            old, _ = self._lazy_prefill.popitem(last=False)
            self.compiled.pop(old, None)
        return self.compiled[key]

    def _chunk_prefill(self):
        """Compiled chunk-prefill executable (one bucket: ``prefill_chunk``
        tokens).  AOT-compiled by ``compile_step_functions`` when the
        instance was built with ``prefill_chunk``; compiled lazily here
        otherwise (never evicted — there is exactly one chunk shape)."""
        key = f"chunk_prefill_{self.prefill_chunk}"
        if key not in self.compiled:
            parallel = engine_parallel_ctx(self.mesh)
            repl = NamedSharding(self.mesh, P())
            cache_out = jax.tree.map(lambda x: x.sharding, self.cache)
            C = self.prefill_chunk

            def sd(shape):
                return jax.ShapeDtypeStruct(shape, jnp.int32, sharding=repl)

            if self.paged:
                pf = jax.jit(
                    partial(_paged_chunk_prefill_fn, self.mcfg, parallel),
                    donate_argnums=(1,), out_shardings=(repl, cache_out))
                bs = self.kv.block_size
                self.compiled[key] = pf.lower(
                    as_sds(self.params), as_sds(self.cache), sd((1, C)),
                    sd(()), sd(()), sd((1, self.max_len // bs)),
                    sd((C // bs,))).compile()
            else:
                pf = jax.jit(partial(_chunk_prefill_fn, self.mcfg, parallel),
                             donate_argnums=(1,),
                             out_shardings=(repl, cache_out))
                self.compiled[key] = pf.lower(
                    as_sds(self.params), as_sds(self.cache), sd((1, C)),
                    sd(()), sd(()), sd(())).compile()
        return self.compiled[key]

    # -------------------------------------------------- paged bookkeeping
    def _slot_of(self, rid: int) -> int:
        for i, s in enumerate(self.slots):
            if s.rid == rid and s.active:
                return i
        raise KeyError(rid)

    def _preempt_slot(self, slot: int) -> None:
        """Evict a sequence under pool pressure: free its blocks, park the
        rid for the server to re-queue; it restarts in recompute mode."""
        s = self.slots[slot]
        if s.prefilling:
            # mid-prefill eviction: drop the chunk job — recompute mode
            # restarts the prompt from scratch on re-admission
            self._prefilling = [j for j in self._prefilling
                                if j.slot != slot]
            self._chunk_ctx.pop(slot, None)
        self.kv.preempt(s.rid)
        self.preemptions += 1
        obs.get_tracer().instant("preempt", cat="serve",
                                 args={"rid": s.rid, "slot": slot})
        self._resume_rids.add(s.rid)
        self._preempted_pending.append(s.rid)
        self.slots[slot] = SlotState()

    def drain_preempted(self) -> List[int]:
        out, self._preempted_pending = self._preempted_pending, []
        return out

    def _copy_block(self, src: int, dst: int) -> None:
        """Physical copy-on-write: duplicate pool row ``src`` into ``dst``
        across all layers.  Jitted with the cache donated so XLA updates
        the pool buffers in place (one block row moved, not a pool copy)."""
        with self._cache_lock:
            self.cache = _cow_copy(self.cache, jnp.asarray(src, jnp.int32),
                                   jnp.asarray(dst, jnp.int32))

    def _ensure_append(self, slot: int) -> bool:
        """Reserve the write slot for this sequence's next token, preempting
        lower-priority sequences in the same partition when the pool is dry.
        Returns False if the sequence itself was preempted."""
        rid = self.slots[slot].rid
        while True:
            try:
                r = self.kv.append(rid)
                break
            except MemoryError:
                part = self._partition(slot)
                cands = [s.rid for i, s in enumerate(self.slots)
                         if s.active and self._partition(i) == part]
                victim = self.kv.victim(candidates=cands)
                if victim is None or victim == rid:
                    self._preempt_slot(slot)
                    return False
                self._preempt_slot(self._slot_of(victim))
        if r is not None:
            if r.cow_src is not None:
                self._copy_block(r.cow_src, r.block)
                obs.get_tracer().instant(
                    "kv.cow_copy", cat="serve",
                    args={"src": r.cow_src, "dst": r.block})
            j = int(self.lengths[slot]) // self.kv.block_size
            self.block_tables[slot, j] = r.block
        return True

    # ------------------------------------- live migration (scale-down)
    def block_nbytes(self) -> int:
        """Device bytes of ONE pool block across all layers/tensors — the
        unit of migration byte accounting."""
        assert self.paged and self.cache is not None
        return sum(leaf.nbytes // leaf.shape[1]
                   for leaf in jax.tree.leaves(self.cache))

    def doomed_active_slots(self) -> List[int]:
        """Active slots that will be evicted by the pending scale-down
        (at or above ``admit_limit``), including ones mid-migration."""
        assert self.admit_limit is not None
        return [i for i, s in enumerate(self.slots)
                if s.active and i >= self.admit_limit]

    def copy_block(self, src: int, dst: int) -> None:
        """One migration device copy (pool row ``src`` -> ``dst``), safe to
        run on a TransferEngine worker: the jit-donated CoW copy under the
        cache lock, serialized against decode/prefill cache swaps.  Call
        once from the serve thread first (``prewarm_block_copy``) so the
        compile never happens on a worker."""
        self._copy_block(src, dst)

    def prewarm_block_copy(self) -> None:
        """Compile the block-copy executable on the serve thread (a
        self-copy is a content no-op) before workers start issuing it."""
        self._copy_block(0, 0)

    def plan_migration(self) -> Optional[MigrationJob]:
        """Plan ONE component move off a doomed partition, or None.

        Picks the first doomed partition with unmigrated live sequences,
        groups them into block-sharing components (the unit that preserves
        CoW refcounts), and best-effort places each component onto a
        survivor partition with enough free *slots* and free *blocks*.  A
        component no survivor can hold block-wise falls back to
        recompute-preemption (freed + re-queued, restarted after
        switchover); one that is merely waiting on survivor slots is left
        for a later call (survivors only finish during a scale — admission
        is paused — so slots free up monotonically)."""
        assert self.paged and self.admit_limit is not None
        keep_parts = self.admit_limit // self.batch_per_replica
        bpr = self.batch_per_replica
        slot_of = {s.rid: i for i, s in enumerate(self.slots) if s.active}
        for part in range(keep_parts, self.kv.num_partitions):
            for comp in self.kv.share_components(part):
                if any(self.kv.migrating(s) for s in comp):
                    continue
                if any(r not in slot_of for r in comp):
                    continue            # finishing this tick; skip
                need = self.kv.migration_need(comp)
                placed = None
                for q in range(keep_parts):
                    free = [i for i in range(q * bpr, (q + 1) * bpr)
                            if not self.slots[i].active
                            and not self.slots[i].reserved
                            and i < self.admit_limit]
                    if len(free) >= len(comp) \
                            and self.kv.free_blocks(q) >= need:
                        placed = (q, free)
                        break
                if placed is None:
                    if len(comp) <= bpr and any(
                            self.kv.free_blocks(q) >= need
                            for q in range(keep_parts)):
                        continue        # blocks exist; waiting on slots
                    # no survivor can ever hold this component: recompute
                    for rid in sorted(comp):
                        self._preempt_slot(slot_of[rid])
                    continue
                q, free = placed
                ticket = self.kv.begin_migration(comp, q)
                moves = []
                for rid, dst in zip(sorted(comp), free):
                    src = slot_of[rid]
                    self.slots[src].migrating = True
                    self.slots[dst] = SlotState(reserved=True)
                    moves.append((rid, src, dst))
                return MigrationJob(ticket=ticket, moves=moves)
        return None

    def finish_migration(self, job: MigrationJob) -> None:
        """Cut-over after every pair in ``job.ticket`` was device-copied:
        commit the block-table rewrite, re-home each slot's state to its
        survivor slot, and resume decoding there."""
        obs.get_tracer().instant(
            "kv.migrate", cat="serve",
            args={"rids": sorted(r for r, _, _ in job.moves),
                  "blocks": len(job.ticket.pairs)})
        self.kv.commit_migration(job.ticket)
        NB = self.kv.num_blocks
        for rid, src, dst in job.moves:
            st = self.slots[src]
            assert st.rid == rid and st.migrating
            st.migrating = False
            self.slots[dst] = st
            self.slots[src] = SlotState()
            self.lengths[dst] = self.lengths[src]
            self.tokens[dst] = self.tokens[src]
            tbl = self.kv.block_table(rid)
            self.block_tables[dst, :] = NB
            self.block_tables[dst, :len(tbl)] = tbl
            self.block_tables[src, :] = NB
            # a mid-prefill sequence resumes chunking on its survivor slot
            # (chunk ids are re-derived from the committed block table at
            # execution time, so the move is transparent to the job)
            for j in self._prefilling:
                if j.slot == src:
                    j.slot = dst
            if src in self._chunk_ctx:
                self._chunk_ctx[dst] = self._chunk_ctx.pop(src)

    def cancel_migration(self, job: MigrationJob) -> None:
        """Abort an in-flight migration: the reservation unwinds, source
        tables were never touched (device truth unchanged), and the paused
        sequences resume decoding in place."""
        self.kv.abort_migration(job.ticket)
        for _, src, dst in job.moves:
            if self.slots[src].migrating:
                self.slots[src].migrating = False
            if self.slots[dst].reserved:
                self.slots[dst] = SlotState()

    @obs.traced("prefill.chunks", cat="serve")
    def _run_prefill_chunks(self) -> List[Tuple[int, int, bool]]:
        """The tick's prefill phase (continuous batching): consume at most
        ``prefill_budget`` prompt tokens as ``prefill_chunk``-token buckets
        in admission order.  Chunk block ids are re-derived from the block
        manager at execution time (not admission time) so live migration
        re-homing is transparent.  Returns first-token events for jobs whose
        final chunk landed this tick."""
        for job in self._prefilling:
            job.paused = self.slots[job.slot].migrating
        plans = self.scheduler.plan(self._prefilling)
        out: List[Tuple[int, int, bool]] = []
        C = self.prefill_chunk
        jobs = {j.slot: j for j in self._prefilling}
        for plan in plans:
            slot = plan.slot
            job = jobs[slot]
            full, resumed = self._chunk_ctx[slot]
            toks = np.zeros((1, C), np.int32)
            toks[0, :plan.take] = full[plan.start:plan.start + plan.take]
            upto = plan.start + plan.take
            with self._cache_lock:
                if self.paged:
                    bs = self.kv.block_size
                    NB = self.kv.num_blocks
                    sb = self.kv.seq(job.rid)
                    j0 = plan.start // bs
                    # pool rows this chunk writes: the NB sentinel drops
                    # writes to padding, CoW-shared prefix blocks, and (on
                    # the rounded-down prefix_skip start) recomputed rows
                    ids = np.full((C // bs,), NB, np.int32)
                    for k in range(C // bs):
                        j = j0 + k
                        if j < len(sb.blocks) and j >= sb.num_shared:
                            ids[k] = sb.blocks[j]
                    tbl = np.full((1, self.max_len // bs), NB, np.int32)
                    bt = self.kv.block_table(job.rid)
                    tbl[0, :len(bt)] = bt
                    first, self.cache = self._chunk_prefill()(
                        self.params, self.cache, jnp.asarray(toks),
                        jnp.asarray(plan.start, jnp.int32),
                        jnp.asarray(upto, jnp.int32),
                        jnp.asarray(tbl), jnp.asarray(ids))
                else:
                    first, self.cache = self._chunk_prefill()(
                        self.params, self.cache, jnp.asarray(toks),
                        jnp.asarray(plan.start, jnp.int32),
                        jnp.asarray(upto, jnp.int32),
                        jnp.asarray(slot, jnp.int32))
            job.pos = upto
            if self.paged:
                # written blocks become matchable for later arrivals
                self.kv.register_written(job.rid, [int(t) for t in full],
                                         upto)
            if plan.final:
                out.append(self._finish_prefill(slot, job, int(first),
                                                resumed))
        return out

    def _finish_prefill(self, slot: int, job: PrefillJob, first: int,
                        resumed: bool) -> Tuple[int, int, bool]:
        """Final chunk landed: record the first generated token and move the
        slot into the decode pool (it decodes this same tick — the same
        cadence as monolithic admission, whose first decode follows the
        admission-tick prefill immediately)."""
        s = self.slots[slot]
        s.prefilling = False
        self._prefilling.remove(job)
        self._chunk_ctx.pop(slot, None)
        self.tokens[slot] = first
        if resumed:
            self.generated[s.rid].append(first)
        else:
            self.generated[s.rid] = [first]
        fin = s.remaining <= 0
        if fin:
            # output_len 1 (or a resume with only its final token left):
            # never reaches decode — reported through this tick's events
            s.active = False
            if self.paged:
                self.kv.free(s.rid)
        return (s.rid, first, fin)

    @obs.traced("decode.tick", cat="serve")
    def decode_tick(self) -> List[Tuple[int, int, bool]]:
        """One engine tick.  With chunked prefill enabled the tick is a
        token-budget schedule: first the prefill phase (at most
        ``prefill_budget`` prompt tokens as fixed-size chunks, admission
        order), then one decode step for all runnable slots — decode runs
        every tick regardless of prefill backlog, which is the
        no-starvation guarantee (serving/scheduler.py).  Runnable = active,
        not mid-prefill, and not paused by an in-flight migration (a
        migrating sequence's blocks are frozen until the copies land, then
        it resumes on its survivor slot).  Returns [(rid, token, finished)]
        for slots that produced a token; prefill completions come first."""
        pre: List[Tuple[int, int, bool]] = []
        if self.scheduler is not None and self._prefilling:
            pre = self._run_prefill_chunks()
        runnable = [s.active and not s.migrating and not s.prefilling
                    for s in self.slots]
        if self.paged:
            # highest priority first, oldest first on ties: pressure evicts
            # from the low-priority/young end before it reaches them
            order = sorted((i for i in range(len(self.slots)) if runnable[i]),
                           key=lambda i: (-self.slots[i].priority,
                                          self.slots[i].rid))
            for slot in order:
                if self.slots[slot].active:
                    self._ensure_append(slot)
            runnable = [s.active and not s.migrating and not s.prefilling
                        for s in self.slots]
        if not any(runnable):
            return pre
        active = np.array(runnable)
        self._step_count = getattr(self, "_step_count", 0) + 1
        # routing telemetry: every Nth tick runs the counts-emitting twin
        # executable (same math — only an extra histogram output)
        routed = (self.routing_sample_every > 0
                  and "decode_routed" in self.compiled
                  and self._step_count % self.routing_sample_every == 0)
        key = "decode_routed" if routed else "decode"
        rng = jax.random.key_data(jax.random.PRNGKey(self._step_count))
        with self._cache_lock:
            if self.paged:
                res = self.compiled[key](
                    self.params, self.cache, jnp.asarray(self.tokens),
                    jnp.asarray(self.lengths), jnp.asarray(active),
                    jnp.asarray(self.block_tables), rng)
            else:
                res = self.compiled[key](
                    self.params, self.cache, jnp.asarray(self.tokens),
                    jnp.asarray(self.lengths), jnp.asarray(active), rng)
            if routed:
                nxt, self.cache, counts = res
            else:
                nxt, self.cache = res
        if routed:
            self._accumulate_routing(counts)
        nxt = np.asarray(nxt)
        out = []
        for i, s in enumerate(self.slots):
            if not active[i]:
                continue
            self.lengths[i] += 1
            self.tokens[i] = nxt[i]
            self.generated[s.rid].append(int(nxt[i]))
            s.remaining -= 1
            fin = s.remaining <= 0 or self.lengths[i] >= self.max_len - 1
            if fin:
                s.active = False
                if self.paged:
                    self.kv.free(s.rid)
            out.append((s.rid, int(nxt[i]), fin))
        return pre + out

    # --------------------------------------------------- routing telemetry
    def _accumulate_routing(self, counts) -> None:
        """Fold one sampled tick's [L_moe, E] expert counts into the
        host-side histogram and emit a skew counter sample."""
        c = np.asarray(counts, np.int64)
        if self._routing_counts is None or \
                self._routing_counts.shape != c.shape:
            # shape change = a different routed executable (rebind): the
            # accumulator AND the sample count restart together — zeroing
            # only the counts would leave routing_stats()["samples"]
            # overcounting and skew averages dividing by the wrong
            # denominator
            self._routing_counts = np.zeros_like(c)
            self._routing_samples = 0
        self._routing_counts += c
        self._routing_samples += 1
        tr = obs.get_tracer()
        if tr.enabled:
            tot = np.maximum(c.sum(axis=-1), 1)
            tr.counter("routing.top_expert_share",
                       float((c.max(axis=-1) / tot).mean()), cat="routing")

    def reset_routing_stats(self) -> None:
        """Restart the routing histogram (counts AND sample count together).

        Invoked at scale-event commit (``ElasticServer.switchover``) and at
        rebalance commit: counts accumulated under the *old* placement
        describe traffic the new placement no longer sees, so letting them
        survive would bias the rebalancer's first post-reconfiguration
        decisions toward stale skew."""
        self._routing_counts = None
        self._routing_samples = 0

    def routing_stats(self) -> Optional[dict]:
        """Accumulated per-expert routing histogram (None until a sampled
        tick has landed).  ``counts`` is [L_moe, E] token counts;
        ``top_expert_share`` / ``expert_cv`` are layer-averaged skew
        metrics (heavy-tailed routing shows up as share >> 1/E and
        cv >> 0) — the signal the skew-aware expert rebalancer
        (serving/rebalance.py, DESIGN.md §10) acts on."""
        if self._routing_counts is None or self._routing_samples == 0:
            return None
        c = self._routing_counts.astype(np.float64)
        tot = np.maximum(c.sum(axis=-1), 1.0)
        share = c.max(axis=-1) / tot
        mean = np.maximum(c.mean(axis=-1), 1e-9)
        cv = c.std(axis=-1) / mean
        return {"samples": self._routing_samples,
                "counts": self._routing_counts.copy(),
                "top_expert_share": float(share.mean()),
                "expert_cv": float(cv.mean())}


# ------------------------------------------------------------- compilation

def as_sds(tree):
    """pytree of arrays (or SDS) -> pytree of sharded ShapeDtypeStructs."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=x.sharding),
        tree)


def compile_step_functions(mcfg: ModelConfig, cfg: ElasticConfig, mesh,
                           params_sds, cache_sds, *,
                           batch_per_replica: int, max_len: int,
                           prefill_buckets=(64,),
                           temperature: float = 0.0,
                           kv_mode: str = "dense",
                           kv_block_size: int = 0,
                           prefill_chunk: int = 0,
                           collect_routing: bool = False
                           ) -> Tuple[Dict[str, Any], float]:
    """AOT-compile decode + prefill executables for an instance.

    ``params_sds``/``cache_sds``: pytrees of sharded ShapeDtypeStructs (no
    weights needed — pre-initialization works without the HMM, exactly the
    paper's CPU-standby instances, §4.5).  ``kv_mode='paged'`` compiles the
    block-table variants (cache_sds is then the pool layout).
    ``prefill_chunk > 0`` additionally compiles the continuous-batching
    chunk-prefill executable (one shape — the chunk bucket).
    ``collect_routing`` additionally compiles the "decode_routed" twin that
    also returns per-(layer, expert) routing counts (obs telemetry); the
    default decode path is byte-identical either way.
    Returns (executables, seconds).
    """
    t0 = time.perf_counter()
    parallel = engine_parallel_ctx(mesh)
    B = cfg.dp * batch_per_replica
    repl = NamedSharding(mesh, P())
    paged = kv_mode == "paged"

    out: Dict[str, Any] = {}
    cache_out = jax.tree.map(lambda s: s.sharding, cache_sds)
    tok_sd = jax.ShapeDtypeStruct((B,), jnp.int32, sharding=repl)
    rng_sd = jax.ShapeDtypeStruct((2,), jnp.uint32, sharding=repl)
    act_sd = jax.ShapeDtypeStruct((B,), jnp.bool_, sharding=repl)
    if paged:
        assert kv_block_size > 0 and max_len % kv_block_size == 0
        MB = max_len // kv_block_size
        dec = jax.jit(partial(_paged_decode_fn, mcfg, parallel, temperature),
                      donate_argnums=(1,), out_shardings=(repl, cache_out))
        bt_sd = jax.ShapeDtypeStruct((B, MB), jnp.int32, sharding=repl)
        out["decode"] = dec.lower(params_sds, cache_sds, tok_sd, tok_sd,
                                  act_sd, bt_sd, rng_sd).compile()
        if collect_routing:
            assert M.routing_stats_supported(mcfg), \
                f"{mcfg.name}: routing telemetry unsupported"
            decr = jax.jit(
                partial(_paged_decode_routed_fn, mcfg, parallel, temperature),
                donate_argnums=(1,),
                out_shardings=(repl, cache_out, repl))
            out["decode_routed"] = decr.lower(
                params_sds, cache_sds, tok_sd, tok_sd, act_sd, bt_sd,
                rng_sd).compile()
    else:
        dec = jax.jit(partial(_decode_fn, mcfg, parallel, temperature),
                      donate_argnums=(1,), out_shardings=(repl, cache_out))
        out["decode"] = dec.lower(params_sds, cache_sds, tok_sd, tok_sd,
                                  act_sd, rng_sd).compile()
        if collect_routing:
            assert M.routing_stats_supported(mcfg), \
                f"{mcfg.name}: routing telemetry unsupported"
            decr = jax.jit(
                partial(_decode_routed_fn, mcfg, parallel, temperature),
                donate_argnums=(1,),
                out_shardings=(repl, cache_out, repl))
            out["decode_routed"] = decr.lower(
                params_sds, cache_sds, tok_sd, tok_sd, act_sd,
                rng_sd).compile()
    for S_pad in prefill_buckets:
        toks = jax.ShapeDtypeStruct((1, S_pad), jnp.int32, sharding=repl)
        len_sd = jax.ShapeDtypeStruct((), jnp.int32, sharding=repl)
        if paged:
            pf = jax.jit(partial(_paged_prefill_fn, mcfg, parallel),
                         donate_argnums=(1,),
                         out_shardings=(repl, cache_out))
            ids_sd = jax.ShapeDtypeStruct((S_pad // kv_block_size,),
                                          jnp.int32, sharding=repl)
            out[f"prefill_{S_pad}"] = pf.lower(
                params_sds, cache_sds, toks, len_sd, ids_sd).compile()
        else:
            pf = jax.jit(partial(_prefill_fn, mcfg, parallel, max_len),
                         donate_argnums=(1,),
                         out_shardings=(repl, cache_out))
            out[f"prefill_{S_pad}"] = pf.lower(
                params_sds, cache_sds, toks, len_sd, len_sd).compile()
    if prefill_chunk:
        assert M.chunk_prefill_supported(mcfg), \
            "chunked prefill unsupported for this model config"
        C = prefill_chunk
        toks = jax.ShapeDtypeStruct((1, C), jnp.int32, sharding=repl)
        len_sd = jax.ShapeDtypeStruct((), jnp.int32, sharding=repl)
        if paged:
            assert C % kv_block_size == 0
            MB = max_len // kv_block_size
            pf = jax.jit(partial(_paged_chunk_prefill_fn, mcfg, parallel),
                         donate_argnums=(1,),
                         out_shardings=(repl, cache_out))
            out[f"chunk_prefill_{C}"] = pf.lower(
                params_sds, cache_sds, toks, len_sd, len_sd,
                jax.ShapeDtypeStruct((1, MB), jnp.int32, sharding=repl),
                jax.ShapeDtypeStruct((C // kv_block_size,), jnp.int32,
                                     sharding=repl)).compile()
        else:
            pf = jax.jit(partial(_chunk_prefill_fn, mcfg, parallel),
                         donate_argnums=(1,),
                         out_shardings=(repl, cache_out))
            out[f"chunk_prefill_{C}"] = pf.lower(
                params_sds, cache_sds, toks, len_sd, len_sd,
                len_sd).compile()
    return out, time.perf_counter() - t0
