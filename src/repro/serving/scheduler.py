"""Token-budget chunk scheduler for continuous batching (DESIGN.md §8).

Pure host-side policy, shared verbatim by the real engine
(``serving/engine.py``) and the analytic simulator
(``serving/simulator.py``) so ``ClusterDriver`` projections price admission
exactly like the serving path — the same contract as
``driver.admission_during_scale``.

Each engine tick runs one decode step for every active slot plus at most
``budget`` prefill tokens, consumed as fixed-size ``chunk``-token buckets
(one compiled shape) in admission (FIFO) order.  A chunk is only scheduled
when the remaining per-tick budget covers its valid tokens — chunks are
never split below the bucket, so in paged mode every non-final chunk
boundary stays block-aligned.  Prefix-cache-aware admission seeds a job's
``pos`` past the CoW-shared prefix, charging only the non-shared tail.

Properties pinned by tests/test_scheduler_properties.py: the per-tick
budget is never exceeded; each job's chunks arrive in order and exactly
cover ``[skip, total)``; decode never starves (every tick decodes all
active slots regardless of prefill backlog).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro import obs


@dataclass
class PrefillJob:
    """One admitted request's outstanding prefill work.

    ``pos`` is the next un-prefilled token (starts at the prefix-cache skip,
    always block-aligned in paged mode); ``total`` the full prompt length.
    ``paused`` freezes a job (its blocks are mid-migration).
    """
    slot: int
    rid: int
    pos: int
    total: int
    paused: bool = False

    @property
    def remaining(self) -> int:
        return self.total - self.pos


@dataclass(frozen=True)
class ChunkPlan:
    """One scheduled prefill chunk: ``take`` valid tokens at ``start``
    (the compiled bucket may be wider; the tail is padding)."""
    slot: int
    rid: int
    start: int
    take: int
    final: bool


@dataclass
class TokenBudgetScheduler:
    """Plans which prefill chunks run this tick.

    ``chunk``: compiled bucket width in tokens (engine: ``prefill_chunk``).
    ``budget``: max prefill tokens charged per tick; defaults to ``chunk``
    (one full bucket).  Decode tokens are not charged against it — decode
    runs every tick for every active slot by construction, which is the
    no-starvation guarantee.
    """
    chunk: int
    budget: Optional[int] = None

    def __post_init__(self):
        assert self.chunk > 0
        if self.budget is None:
            self.budget = self.chunk
        assert self.budget >= self.chunk, \
            "budget below one chunk would stall prefill forever"

    def plan(self, jobs: List[PrefillJob]) -> List[ChunkPlan]:
        """FIFO, no skipping: the head job drains before later jobs see any
        budget, and planning stops at the first job whose next chunk does
        not fit — order is admission order, so TTFT stays FIFO-fair."""
        out: List[ChunkPlan] = []
        left = self.budget
        for job in jobs:
            if job.paused:
                continue
            pos = job.pos
            while pos < job.total:
                take = min(self.chunk, job.total - pos)
                if take > left:
                    return self._record(out)
                out.append(ChunkPlan(slot=job.slot, rid=job.rid, start=pos,
                                     take=take, final=pos + take == job.total))
                pos += take
                left -= take
            if left <= 0:
                break
        return self._record(out)

    @staticmethod
    def _record(out: List[ChunkPlan]) -> List[ChunkPlan]:
        # shared policy code => one instrumentation point covers both
        # backends (DESIGN.md §9); no-op under the NULL_TRACER
        tr = obs.get_tracer()
        if tr.enabled and out:
            tr.instant("chunk.plan", cat="serve",
                       args={"chunks": len(out),
                             "tokens": sum(p.take for p in out),
                             "rids": sorted({p.rid for p in out})})
        return out


def prefix_skip(num_shared: int, block_size: int, prompt_len: int) -> int:
    """Block-aligned prefill start for a prompt whose first ``num_shared``
    blocks were matched in the CoW prefix registry.

    At least one token is always computed (the last position's logits
    sample the first output token), so when the shared prefix covers the
    whole prompt the start rounds down to the last block boundary before
    ``prompt_len - 1`` — those few recomputed tokens land on sentinel
    (shared) rows and are dropped, not rewritten.
    """
    if num_shared <= 0:
        return 0
    return min(num_shared * block_size,
               ((prompt_len - 1) // block_size) * block_size)
