"""Serving metrics (paper §7.3): TTFT, TPOT, SLO attainment, SLO/XPU —
plus the paged-KV pressure surface (preemption count, block-pool
utilization) and the staging-overlap surface (decode-stall seconds during
scaling, overlap efficiency = Σ transfer-op time / staging wall-clock)
reported by both serving backends (serving/kv_blocks.py, DESIGN.md §3)."""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence

import numpy as np

from repro.serving.workload import Request


@dataclasses.dataclass(frozen=True)
class SLO:
    ttft_s: float
    tpot_s: float


def meets_slo(r: Request, slo: SLO) -> Optional[bool]:
    if r.ttft is None or r.finish_s is None:
        return None
    ok = r.ttft <= slo.ttft_s
    if r.tpot is not None:
        ok = ok and r.tpot <= slo.tpot_s
    return ok


def slo_attainment(reqs: Sequence[Request], slo: SLO) -> float:
    done = [meets_slo(r, slo) for r in reqs]
    done = [d for d in done if d is not None]
    if not done:
        return float("nan")
    return sum(done) / len(done)


def slo_attainment_timeline(reqs: Sequence[Request], slo: SLO,
                            window_s: float = 10.0, dt: float = 1.0):
    """(times, attainment) over sliding windows keyed by finish time.

    Each request's verdict is judged once and windows resolve as two
    sorted-boundary lookups (O(N log N + T log N), not the naive O(T·N)
    per-window rescan); the window is inclusive at both ends
    (``t - window_s <= finish_s <= t``) and empty windows are NaN,
    identical to the original rescan."""
    finished = [r for r in reqs if r.finish_s is not None]
    if not finished:
        return np.array([]), np.array([])
    t_end = max(r.finish_s for r in finished)
    ts = np.arange(0.0, t_end + dt, dt)
    judged = [(r.finish_s, v) for r in finished
              for v in (meets_slo(r, slo),) if v is not None]
    judged.sort(key=lambda fv: fv[0])
    fs = np.array([f for f, _ in judged])
    ok_cum = np.concatenate([[0], np.cumsum([v for _, v in judged])])
    hi = np.searchsorted(fs, ts, side="right")       # finish_s <= t
    lo = np.searchsorted(fs, ts - window_s, side="left")  # >= t - window_s
    n = hi - lo
    att = np.where(n > 0, (ok_cum[hi] - ok_cum[lo]) / np.maximum(n, 1),
                   np.nan)
    return ts, att


def iter_itls(reqs: Sequence[Request]) -> Iterable[float]:
    """Inter-token latencies: consecutive ``token_times`` gaps across all
    requests.  The real engine records wall-clock token times; the simulator
    synthesizes them from its modelled decode rate plus prefill stalls —
    either way ITL p99 is the headline continuous-batching metric (a
    monolithic prefill stalls every running decode for the whole prompt,
    chunked prefill bounds the stall at one chunk; serving/scheduler.py)."""
    for r in reqs:
        if r.token_times and len(r.token_times) > 1:
            for a, b in zip(r.token_times, r.token_times[1:]):
                yield b - a


def latency_percentiles(reqs: Sequence[Request]) -> dict:
    """TTFT/ITL p50/p99 snapshot (NaN when no samples) — the scale-event
    annotation (DriverEvent / SimScaleEvent) and the summarize core."""
    ttfts = [r.ttft for r in reqs if r.ttft is not None]
    itls = list(iter_itls(reqs))

    def pct(xs, q):
        return float(np.percentile(xs, q)) if xs else float("nan")

    return {"ttft_p50": pct(ttfts, 50), "ttft_p99": pct(ttfts, 99),
            "itl_p50": pct(itls, 50), "itl_p99": pct(itls, 99)}


def throughput_rps(reqs: Sequence[Request], t0: float, t1: float) -> float:
    n = sum(1 for r in reqs if r.finish_s is not None and t0 <= r.finish_s < t1)
    return n / max(t1 - t0, 1e-9)


@dataclasses.dataclass(frozen=True)
class KVPoolStats:
    """Paged-KV pressure snapshot of a serving backend."""
    num_blocks: int
    used_blocks: int
    utilization: float
    preemptions: int


def kv_pool_stats(backend) -> Optional[KVPoolStats]:
    """Normalize a backend's ``kv_stats()`` dict (ElasticServer,
    ServingSimulator, or the engine itself); None for dense-KV backends."""
    getter = getattr(backend, "kv_stats", None)
    raw = getter() if getter is not None else None
    if not raw:
        return None
    return KVPoolStats(num_blocks=int(raw.get("num_blocks", 0)),
                       used_blocks=int(raw.get("used_blocks", 0)),
                       utilization=float(raw.get("utilization", 0.0)),
                       preemptions=int(raw.get("preemptions", 0)))


def summarize(reqs: Sequence[Request], slo: Optional[SLO] = None,
              backend=None) -> dict:
    tpots = [r.tpot for r in reqs if r.tpot is not None]
    lat = latency_percentiles(reqs)
    out = {
        "n": len(reqs),
        "finished": sum(1 for r in reqs if r.finish_s is not None),
        # TTFT percentiles come straight from the latency_percentiles core
        # (np.percentile(x, 50) == np.median; NaN when empty — identical)
        "ttft_p50": lat["ttft_p50"],
        "ttft_p99": lat["ttft_p99"],
        "tpot_p50": float(np.median(tpots)) if tpots else float("nan"),
        "itl_p50": lat["itl_p50"],
        "itl_p99": lat["itl_p99"],
    }
    if slo:
        out["slo_attainment"] = slo_attainment(reqs, slo)
    if backend is not None:
        kv = kv_pool_stats(backend)
        if kv is not None:
            out["preemptions"] = kv.preemptions
            out["kv_block_utilization"] = kv.utilization
        sc = scaling_overlap_stats(backend)
        if sc is not None:
            out.update(sc)
        rt = getattr(backend, "routing_stats", lambda: None)()
        if rt:
            # expert-routing skew counters (DESIGN.md §9): sampled decode
            # ticks, layer-averaged top-expert share and per-layer CV
            out["routing_samples"] = int(rt["samples"])
            out["routing_top_expert_share"] = float(rt["top_expert_share"])
            out["routing_expert_cv"] = float(rt["expert_cv"])
    return out


def fleet_summary(per_model_requests: "dict[str, Sequence[Request]]",
                  slo: SLO,
                  device_seconds: "dict[str, float]") -> dict:
    """Fleet-level rollup (DESIGN.md §12): per-model and aggregate SLO
    attainment plus device-hours actually provisioned.

    ``device_seconds`` is ∫(devices leased) dt per model — what the
    FleetDriver (or a static allocation) actually paid for, the
    denominator of the shared-pool win: the fleet arm must match or beat
    the static arm's aggregate attainment at strictly fewer device-hours.
    Aggregate attainment is request-weighted (all requests pooled), not a
    mean of per-model ratios — a model serving 10× the traffic counts 10×."""
    all_reqs: List[Request] = []
    per_model = {}
    for name, reqs in per_model_requests.items():
        all_reqs.extend(reqs)
        per_model[name] = {
            "n": len(reqs),
            "finished": sum(1 for r in reqs if r.finish_s is not None),
            "slo_attainment": slo_attainment(reqs, slo),
            "device_hours": device_seconds.get(name, 0.0) / 3600.0,
        }
    return {
        "aggregate_slo_attainment": slo_attainment(all_reqs, slo),
        "finished": sum(1 for r in all_reqs if r.finish_s is not None),
        "n": len(all_reqs),
        "device_hours": sum(device_seconds.values()) / 3600.0,
        "per_model": per_model,
    }


def scaling_overlap_stats(backend) -> Optional[dict]:
    """Normalize a backend's ``scaling_summary()`` (ElasticServer or
    ServingSimulator): staging mode, total decode-stall seconds during
    scaling, and overlap efficiency (Σ transfer-op time / staging
    wall-clock — >1 means transfers genuinely overlapped serving).  None
    when the backend has executed no scale events (or predates the async
    transfer pipeline, DESIGN.md §3)."""
    getter = getattr(backend, "scaling_summary", None)
    raw = getter() if getter is not None else None
    if not raw:
        return None
    out = {"staging_mode": raw.get("staging_mode", "serial"),
           "decode_stall_s": float(raw.get("decode_stall_s", 0.0))}
    if raw.get("overlap_efficiency") is not None:
        out["overlap_efficiency"] = float(raw["overlap_efficiency"])
    if raw.get("scaledown_mode") is not None:
        # zero-drain scale-down: live KV blocks moved to survivors
        out["scaledown_mode"] = raw["scaledown_mode"]
        out["migrated_blocks"] = int(raw.get("migrated_blocks", 0))
        out["migration_bytes"] = int(raw.get("migration_bytes", 0))
    return out
