"""FleetDriver (DESIGN.md §12): one shared accelerator pool, many models.

The single-model ``ClusterDriver`` owns its whole pool for one backend;
the fleet refactor moves pool ownership into the ``DevicePool`` allocator
and arbitrates it across N serving backends (``ElasticServer`` or
``ServingSimulator`` — anything implementing ``ServingBackend`` plus the
``park``/``start_unpark`` scale-to-zero surface):

* each model keeps its OWN ``LoadEstimator`` (per-model SLO windows,
  cooldowns and confirm timers — the per-model hysteresis), feeding a
  global allocator that scores candidate moves with the shared cost
  model (``transition_cost`` / ``unpark_transition_cost``) and hands
  devices between models through the existing per-model ``ScalingTask``
  lifecycle — a device is claimed at decision time, serves through the
  transition, and only returns to the free set when the releasing
  model's task commits;
* **scale-to-zero is first-class**: a model idle past
  ``park_after_idle_s`` (with ``min_devices == 0``) parks — its whole
  snapshot moves to the pinned-host tier, every device releases — and
  the next queued request cold-starts it through an unpark task whose
  H2D window hides the AOT compile (STAGING ∥ COMPILING);
* pool conservation is enforced, not assumed: every claim/release goes
  through the allocator (double-booking raises), and
  ``check_invariants`` cross-checks the allocator against the driver's
  per-model lease lists every tick.

Backends address their devices *logically* (slots ``0..ndev-1`` — the
simulator's internal device space, or indices into an ``ElasticServer``'s
``all_devices``); the allocator's fleet device ids are the ownership
ledger.  What conservation means is therefore exact: Σ leases + free ==
pool, always, with no id in two leases.

Anti-thrash hysteresis is layered: per-model estimator ``cooldown_s`` +
``confirm_s`` (a burst must persist to trigger), the driver's
``settle_s`` (no new decision while a transition just landed), and
``park_after_idle_s`` (a trough must persist before the model gives up
its last devices) — so anti-correlated bursts hand devices back and
forth at workload cadence, not tick cadence.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Union

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.coordinator import LoadEstimator, ScalingPolicy
from repro.core.topology import ElasticConfig
from repro.serving.driver import (DevicePool, ScalingTask, transition_cost,
                                  unpark_transition_cost)
from repro.serving.metrics import latency_percentiles
from repro.serving.workload import Request, merge_arrivals


@dataclasses.dataclass
class FleetModelSpec:
    """One fleet member: a serving backend plus its scaling envelope."""
    name: str
    backend: object                  # ServingBackend + park/start_unpark
    policy: ScalingPolicy
    mcfg: ModelConfig
    tp: int
    # device floor: the model never scales below ceil(min_devices/tp)
    # replicas' worth of devices; 0 additionally allows scale-to-zero
    min_devices: int = 0
    # trough persistence before a min_devices==0 model parks
    park_after_idle_s: float = 60.0


@dataclasses.dataclass
class FleetConfig:
    dt: float = 0.05
    settle_s: float = 10.0           # post-transition decision quiet time
    step_dp: int = 1
    max_step_dp: int = 2
    sample_every_s: float = 5.0      # devices-provisioned timeline cadence


@dataclasses.dataclass
class FleetEvent:
    """One allocator move: scale up/down, park, or unpark."""
    t: float
    model: str
    kind: str                        # 'up' | 'down' | 'park' | 'unpark'
    src: str
    dst: str
    projected_s: float = 0.0
    queue_depth: int = 0
    free_devices: int = 0


@dataclasses.dataclass
class _ModelState:
    spec: FleetModelSpec
    estimator: LoadEstimator
    lease: List[int]                 # fleet device ids currently owned
    task: Optional[ScalingTask] = None
    task_kind: Optional[str] = None  # 'up' | 'down' | 'unpark'
    task_prev_lease: int = 0         # lease size before the in-flight claim
    parked: bool = False
    idle_since: Optional[float] = None
    last_done_t: float = -math.inf
    device_seconds: float = 0.0      # ∫ len(lease) dt — what this model cost
    pending: List[Request] = dataclasses.field(default_factory=list)
    pi: int = 0
    finished: List[Request] = dataclasses.field(default_factory=list)


class FleetDriver:
    """Closed loop over N models sharing one ``DevicePool``."""

    def __init__(self, specs: Sequence[FleetModelSpec],
                 device_pool: Union[DevicePool, Sequence[int]],
                 config: Optional[FleetConfig] = None):
        if not isinstance(device_pool, DevicePool):
            device_pool = DevicePool(device_pool)
        self.pool = device_pool
        self.config = config or FleetConfig()
        names = [s.name for s in specs]
        assert len(set(names)) == len(names), f"duplicate model names {names}"
        self.states: Dict[str, _ModelState] = {}
        for spec in specs:
            cfg = spec.backend.current_config()
            ndev = cfg.ndev if cfg is not None else 0
            # adopt the backend's boot allocation: claim exactly as many
            # devices as it currently runs on (raises if the pool cannot
            # conserve them — e.g. two models booted past the pool size)
            lease = list(self.pool.claim(spec.name, self.pool.free()[:ndev])) \
                if ndev else []
            if len(lease) != ndev:
                raise ValueError(
                    f"pool cannot cover {spec.name}'s boot config "
                    f"({ndev} devices; {len(self.pool.devices)} in pool)")
            self.states[spec.name] = _ModelState(
                spec=spec, estimator=LoadEstimator(spec.policy), lease=lease,
                parked=(cfg is None) or getattr(spec.backend, "parked",
                                                False))
        self.t = 0.0
        self.events: List[FleetEvent] = []
        self.timeline: List[dict] = []     # devices-provisioned samples
        self._next_sample_t = 0.0
        self.check_invariants()

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Pool conservation against the per-model lease ledger: every
        device free xor leased to exactly one model, none leaked."""
        self.pool.check_invariants(
            {name: st.lease for name, st in self.states.items()})

    # ----------------------------------------------------------- utilities
    def _min_dp(self, spec: FleetModelSpec) -> int:
        return max(1, math.ceil(spec.min_devices / spec.tp))

    def _logical(self, dp: int, tp: int) -> ElasticConfig:
        return ElasticConfig(dp=dp, tp=tp, devices=tuple(range(dp * tp)))

    def _projected_scale_s(self, st: _ModelState, old: ElasticConfig,
                           new: ElasticConfig) -> float:
        """Shared-cost-model score of a candidate move (the same adoption
        of backend staging/layout knobs as ``ClusterDriver``)."""
        b = st.spec.backend
        page_table = getattr(getattr(b, "hmm", None), "page_table", None)
        if page_table is None:
            page_table = getattr(b, "expert_pages", None)
        try:
            return transition_cost(
                st.spec.mcfg, st.spec.tp, old, new,
                strategy=getattr(b, "strategy", "elastic"),
                hw=getattr(b, "hw", None),
                preinit=bool(getattr(b, "preinit", True)),
                kv_seq_len=getattr(getattr(b, "perf", None),
                                   "kv_seq_len", 4096),
                expert_mode=getattr(b, "expert_mode", "dense"),
                page_table=page_table,
                staging=getattr(b, "staging_mode", "serial"),
                kv_dtype=getattr(b, "kv_dtype", None),
                expert_dtype=getattr(b, "expert_dtype", None)).scale_time_s
        except MemoryError:
            return math.inf

    def _projected_unpark_s(self, st: _ModelState,
                            new: ElasticConfig) -> float:
        b = st.spec.backend
        return unpark_transition_cost(
            st.spec.mcfg, st.spec.tp, new,
            hw=getattr(b, "hw", None),
            preinit=bool(getattr(b, "preinit", True)),
            staging=getattr(b, "staging_mode", "serial"),
            kv_seq_len=getattr(getattr(b, "perf", None), "kv_seq_len", 4096),
            kv_dtype=getattr(b, "kv_dtype", None),
            expert_dtype=getattr(b, "expert_dtype", None)).scale_time_s

    def _record(self, st: _ModelState, kind: str, src: str, dst: str,
                proj: float = 0.0) -> None:
        ev = FleetEvent(t=self.t, model=st.spec.name, kind=kind, src=src,
                        dst=dst, projected_s=proj,
                        queue_depth=st.spec.backend.queue_depth(),
                        free_devices=len(self.pool.free()))
        self.events.append(ev)
        obs.get_tracer().instant(f"fleet.{kind}", cat="fleet", t=self.t,
                                 tid="fleet",
                                 args={"model": st.spec.name, "src": src,
                                       "dst": dst})

    # ------------------------------------------------------- task lifecycle
    def _advance_task(self, st: _ModelState, t: float) -> None:
        if st.task is None:
            return
        phase = st.task.advance(t)
        if not phase.terminal:
            return
        name = st.spec.name
        aborted = phase.name == "ABORTED"
        if st.task_kind == "down" and not aborted:
            # the shrink committed: the tail of the lease returns to the
            # free set — THIS is the handoff point to other models
            new_n = st.task.target.ndev
            self.pool.release(name, st.lease[new_n:])
            del st.lease[new_n:]
        elif st.task_kind in ("up", "unpark") and aborted:
            # the claim at decision time never materialized: hand the
            # delta straight back (an aborted unpark returns to parked)
            self.pool.release(name, st.lease[st.task_prev_lease:])
            del st.lease[st.task_prev_lease:]
        if st.task_kind == "unpark" and not aborted:
            st.parked = False
            st.idle_since = None
        st.task = None
        st.task_kind = None
        st.last_done_t = t

    # ------------------------------------------------------------ decisions
    def _decide(self, st: _ModelState, t: float) -> None:
        if st.task is not None or t - st.last_done_t < self.config.settle_s:
            return
        if st.parked:
            self._maybe_unpark(st, t)
            return
        spec, b, cfgd = st.spec, st.spec.backend, self.config
        decision = st.estimator.decide(t, b.queue_depth(), b.utilization())
        if decision == "up":
            self._scale_up(st, t)
        elif decision == "down":
            self._scale_down(st, t)
        else:
            self._maybe_park(st, t)

    def _maybe_unpark(self, st: _ModelState, t: float) -> None:
        """A parked model's next request always answers with an unpark —
        as soon as the pool can cover its smallest legal config."""
        spec, b = st.spec, st.spec.backend
        if b.queue_depth() == 0:
            return
        free = self.pool.free()
        min_dp = self._min_dp(spec)
        max_dp = len(free) // spec.tp
        if max_dp < min_dp:
            return                      # pool exhausted; retry next window
        # smallest rung whose capacity covers the queued demand
        demand = b.queue_depth()
        dp = next((d for d in range(min_dp, max_dp + 1)
                   if b.capacity(self._logical(d, spec.tp)) >= demand),
                  max_dp)
        target = self._logical(dp, spec.tp)
        proj = self._projected_unpark_s(st, target)
        st.task_prev_lease = len(st.lease)
        st.lease.extend(self.pool.claim(spec.name, free[:dp * spec.tp]))
        self._record(st, "unpark", "parked", target.describe(), proj)
        st.task = b.start_unpark(target)
        st.task_kind = "unpark"

    def _scale_up(self, st: _ModelState, t: float) -> None:
        spec, b, cfgd = st.spec, st.spec.backend, self.config
        cur = b.current_config()
        free = self.pool.free()
        max_extra_dp = len(free) // spec.tp
        rungs = [d for d in range(cur.dp + cfgd.step_dp,
                                  cur.dp + cfgd.max_step_dp * cfgd.step_dp
                                  + 1, cfgd.step_dp)
                 if d - cur.dp <= max_extra_dp]
        if not rungs:
            return                      # pool exhausted; retry next window
        demand = b.utilization() * b.capacity(cur) + b.queue_depth()
        scored = []
        for d in rungs:
            cand = self._logical(d, spec.tp)
            proj = self._projected_scale_s(st, cur, cand)
            if math.isfinite(proj):
                scored.append((cand, proj))
        if not scored:
            return
        target, proj = next(((c, p) for c, p in scored
                             if b.capacity(c) >= demand), scored[-1])
        delta = target.ndev - cur.ndev
        st.task_prev_lease = len(st.lease)
        st.lease.extend(self.pool.claim(spec.name, free[:delta]))
        self._record(st, "up", cur.describe(), target.describe(), proj)
        st.task = b.start_scale(target)
        st.task_kind = "up"

    def _scale_down(self, st: _ModelState, t: float) -> None:
        spec, b, cfgd = st.spec, st.spec.backend, self.config
        cur = b.current_config()
        d = cur.dp - cfgd.step_dp
        if d < self._min_dp(spec):
            return
        cand = self._logical(d, spec.tp)
        active = b.utilization() * b.capacity(cur)
        if b.capacity(cand) < active * 1.25 or b.queue_depth():
            return
        proj = self._projected_scale_s(st, cur, cand)
        if not math.isfinite(proj):
            return
        self._record(st, "down", cur.describe(), cand.describe(), proj)
        # devices release when the task COMMITS (_advance_task), never at
        # decision time — the model still serves on them while draining
        st.task = b.start_scale(cand)
        st.task_kind = "down"

    def _maybe_park(self, st: _ModelState, t: float) -> None:
        spec, b = st.spec, st.spec.backend
        if spec.min_devices > 0:
            return
        idle = b.queue_depth() == 0 and b.utilization() == 0.0
        if not idle:
            st.idle_since = None
            return
        if st.idle_since is None:
            st.idle_since = t
            return
        if t - st.idle_since < spec.park_after_idle_s:
            return
        cur = b.current_config()
        self._record(st, "park", cur.describe(), "parked")
        b.park()
        self.pool.release(spec.name, st.lease)
        st.lease.clear()
        st.parked = True
        st.idle_since = None
        st.last_done_t = t

    # -------------------------------------------------------------- the loop
    def run(self, arrivals: Dict[str, Sequence[Request]],
            until: float) -> Dict[str, List[Request]]:
        """Advance the fleet loop to ``until``.  ``arrivals`` maps model
        name -> new requests (added to that model's pending set; call again
        with more to continue).  Returns per-model finished requests."""
        for name, reqs in (arrivals or {}).items():
            st = self.states[name]
            if reqs:
                st.pending = merge_arrivals(st.pending, st.pi, reqs)
                st.pi = 0
        cfgd = self.config
        while self.t < until:
            t = self.t
            for st in self.states.values():
                # deliver arrivals — parked models still take submissions
                # (their queue is the unpark trigger)
                while st.pi < len(st.pending) \
                        and st.pending[st.pi].arrival_s <= t:
                    st.spec.backend.submit(st.pending[st.pi])
                    st.pi += 1
                finished = st.spec.backend.step(t)
                for r in finished:
                    st.estimator.record(r)
                st.finished.extend(finished)
                st.device_seconds += len(st.lease) * cfgd.dt
            for st in self.states.values():
                self._advance_task(st, t)
            for st in self.states.values():
                self._decide(st, t)
            if t >= self._next_sample_t:
                self.timeline.append(
                    {"t": round(t, 6),
                     **{n: len(s.lease) for n, s in self.states.items()},
                     "free": len(self.pool.free())})
                self._next_sample_t = t + cfgd.sample_every_s
            self.check_invariants()
            self.t += cfgd.dt
        return {name: st.finished for name, st in self.states.items()}

    # ------------------------------------------------------------- reporting
    def device_seconds(self) -> Dict[str, float]:
        return {n: st.device_seconds for n, st in self.states.items()}

    def finished_requests(self) -> Dict[str, List[Request]]:
        return {n: st.finished for n, st in self.states.items()}

    def summary(self) -> dict:
        """Event/latency rollup (the fleet benchmark's raw material)."""
        out = {}
        for name, st in self.states.items():
            kinds = [e.kind for e in self.events if e.model == name]
            out[name] = {"ups": kinds.count("up"),
                         "downs": kinds.count("down"),
                         "parks": kinds.count("park"),
                         "unparks": kinds.count("unpark"),
                         "device_hours": st.device_seconds / 3600.0,
                         **latency_percentiles(st.finished)}
        return out
