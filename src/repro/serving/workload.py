"""Synthetic workloads (paper §7.1): IO sequences under fixed, variable
(ramp), and patterned (burst) request-rate profiles.  Prompt lengths may be
fixed, sampled from a range, or drawn from a custom sampler; the
shared-prefix generator exercises the paged KV cache's copy-on-write path
(serving/kv_blocks.py)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

# fixed length | inclusive (lo, hi) range | rng -> length sampler
PromptLen = Union[int, tuple, Callable[[np.random.Generator], int]]


def _prompt_sampler(prompt_len: PromptLen) -> Callable[
        [np.random.Generator], int]:
    if callable(prompt_len):
        return prompt_len
    if isinstance(prompt_len, tuple):
        lo, hi = prompt_len
        return lambda rng: int(rng.integers(lo, hi + 1))
    return lambda rng: int(prompt_len)


@dataclasses.dataclass
class Request:
    rid: int
    arrival_s: float
    prompt_len: int
    output_len: int
    prompt: Optional[np.ndarray] = None      # token ids (engine runs)
    priority: int = 0                        # paged KV: preemption order

    # filled by the engine/simulator
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    token_times: Optional[List[float]] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def tpot(self) -> Optional[float]:
        if self.finish_s is None or self.first_token_s is None \
                or self.output_len <= 1:
            return None
        return (self.finish_s - self.first_token_s) / (self.output_len - 1)


def make_workload(*, duration_s: float, rps_fn: Callable[[float], float],
                  prompt_len: PromptLen = 2000, output_range=(500, 750),
                  seed: int = 0, vocab_size: int = 0,
                  dt: float = 0.05) -> List[Request]:
    """Poisson-ish arrivals with time-varying rate ``rps_fn(t)``.

    ``prompt_len`` is a fixed int, an inclusive ``(lo, hi)`` range, or a
    ``rng -> int`` sampler — variable-length prompts are what block-managed
    KV admission exploits (fixed-length reservation wastes the difference).
    """
    rng = np.random.default_rng(seed)
    sample_prompt = _prompt_sampler(prompt_len)
    reqs: List[Request] = []
    t, rid = 0.0, 0
    while t < duration_s:
        lam = max(rps_fn(t), 0.0) * dt
        n = rng.poisson(lam)
        for _ in range(n):
            out = int(rng.integers(output_range[0], output_range[1] + 1))
            S = sample_prompt(rng)
            prompt = (rng.integers(0, vocab_size, S)
                      if vocab_size else None)
            reqs.append(Request(rid, t + rng.uniform(0, dt), S, out,
                                prompt=prompt))
            rid += 1
        t += dt
    reqs.sort(key=lambda r: r.arrival_s)
    return reqs


def shared_prefix_workload(schedule, *, prefix_len: int,
                           suffix_range=(4, 16), num_prefixes: int = 1,
                           output_range=(10, 24), vocab_size: int = 256,
                           seed: int = 0, rid0: int = 0) -> List[Request]:
    """Engine-runnable workload where prompts share long common prefixes —
    the copy-on-write exerciser (kv_blocks.py): requests in the same prefix
    group reuse the prefix's KV blocks and only fork at their suffix.

    ``schedule`` is ``[(t_arrival, n_requests), ...]``; each request picks
    one of ``num_prefixes`` groups (round-robin).  A group is one fixed
    prefix plus one fixed continuation stream; each request's prompt is the
    prefix plus the first ``k`` continuation tokens (``k`` drawn from
    ``suffix_range``) — i.e. the group's prompts are mutual prefixes
    (branching continuations of one context), so a shorter request arriving
    after a longer one shares the partially-filled tail block and forks it
    copy-on-write at its first generated token.
    """
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, vocab_size, prefix_len)
                for _ in range(num_prefixes)]
    streams = [rng.integers(0, vocab_size, suffix_range[1])
               for _ in range(num_prefixes)]
    reqs: List[Request] = []
    rid = rid0
    for t_arr, n in schedule:
        for _ in range(n):
            g = rid % num_prefixes
            k = int(rng.integers(suffix_range[0], suffix_range[1] + 1))
            prompt = np.concatenate([prefixes[g],
                                     streams[g][:k]]).astype(np.int64)
            out = int(rng.integers(output_range[0], output_range[1] + 1))
            reqs.append(Request(rid, float(t_arr), len(prompt), out,
                                prompt=prompt))
            rid += 1
    reqs.sort(key=lambda r: r.arrival_s)
    return reqs


def merge_arrivals(pending: List[Request], consumed: int,
                   new: List[Request]) -> List[Request]:
    """Resume-with-more-arrivals protocol shared by ClusterDriver.run and
    ServingSimulator.run: merge ``new`` requests into the unconsumed tail of
    ``pending`` (``consumed`` = index of the first undelivered request),
    keeping arrival order.  The caller resets its cursor to 0."""
    return sorted(pending[consumed:] + list(new), key=lambda r: r.arrival_s)


def scripted_burst(schedule, *, prompt_len: int = 16,
                   output_range=(10, 24), vocab_size: int = 256,
                   seed: int = 0, rid0: int = 0) -> List[Request]:
    """Deterministic engine-run workload from an explicit arrival schedule.

    ``schedule`` is ``[(t_arrival, n_requests), ...]``; every request gets a
    random prompt (token ids) and output length from ``output_range`` —
    the calm->burst->calm shapes the closed-loop driver tests and examples
    replay on real host devices.
    """
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    rid = rid0
    for t_arr, n in schedule:
        for _ in range(n):
            out = int(rng.integers(output_range[0], output_range[1] + 1))
            reqs.append(Request(rid, float(t_arr), prompt_len, out,
                                prompt=rng.integers(0, vocab_size,
                                                    prompt_len)))
            rid += 1
    reqs.sort(key=lambda r: r.arrival_s)
    return reqs


# rate profiles used across the benchmarks
def fixed_rate(rps: float):
    return lambda t: rps


def ramp(rps0: float, rps1: float, duration: float):
    return lambda t: rps0 + (rps1 - rps0) * min(t / duration, 1.0)


def step_up(rps0: float, rps1: float, at: float):
    return lambda t: rps0 if t < at else rps1


def burst(base: float, peak: float, start: float, width: float):
    return lambda t: peak if start <= t < start + width else base


def diurnal(base: float, peak: float, period_s: float,
            phase_frac: float = 0.0):
    """Sinusoidal day/night demand: ``base`` rps at the trough, ``peak`` at
    the crest, one cycle per ``period_s``.  ``phase_frac`` shifts the cycle
    by a fraction of a period — a fleet of N models at ``phase_frac=i/N``
    gives staggered (anti-correlated) peaks, the regime where one shared
    pool beats N static pools (DESIGN.md §12).  With ``phase_frac=0`` the
    trough is at t=0 and the crest at ``period_s/2``."""
    amp = (peak - base) * 0.5
    return lambda t: base + amp * (1.0 - np.cos(
        2.0 * np.pi * (t / period_s + phase_frac)))


def diurnal_crest(period_s: float, phase_frac: float = 0.0) -> float:
    """Time of the first crest of ``diurnal(..., phase_frac)`` in [0, T)."""
    return ((0.5 - phase_frac) % 1.0) * period_s


def fleet_workload(model_names: Sequence[str], *, duration_s: float,
                   base_rps: float, peak_rps: float, period_s: float,
                   burst_rps: float = 0.0, burst_width_s: float = 0.0,
                   prompt_len: PromptLen = 2000, output_range=(500, 750),
                   seed: int = 0, dt: float = 0.05
                   ) -> Dict[str, List[Request]]:
    """Per-model arrival streams for a fleet benchmark: model ``i`` of N
    rides ``diurnal(base_rps, peak_rps, period_s, phase_frac=i/N)`` —
    staggered peaks, so aggregate demand is much flatter than any single
    model's — plus an optional rate burst of ``burst_rps`` for
    ``burst_width_s`` seconds at each model's own crest (bursty AND
    anti-correlated, the fleet allocator's target regime).  Returns
    ``{model_name: [Request, ...]}`` with independent seeds per model."""
    out: Dict[str, List[Request]] = {}
    n = max(len(model_names), 1)
    for i, name in enumerate(model_names):
        phase = i / n
        rate = diurnal(base_rps, peak_rps, period_s, phase_frac=phase)
        if burst_rps and burst_width_s:
            spike = burst(0.0, burst_rps,
                          diurnal_crest(period_s, phase), burst_width_s)
            rate = (lambda t, f=rate, b=spike: f(t) + b(t))
        out[name] = make_workload(duration_s=duration_s, rps_fn=rate,
                                  prompt_len=prompt_len,
                                  output_range=output_range,
                                  seed=seed + i, dt=dt)
    return out
