"""Paged KV-cache block manager — the KV-side analogue of ``expert_pages``.

The paper's HMM "reuses weights and KV caches via zero-copy remapping"
(§5.2).  ``core/expert_pages.py`` applies that to expert weights; this module
applies the same pool-plus-table indirection to the KV cache itself (the
PagedAttention design): the physical cache is a fixed pool of fixed-size
*blocks* (``[L, num_blocks, block_size, KVH, hd]`` on device, see
``models/model.py:init_paged_cache``) and every sequence owns a *block
table* — an ordered list of pool indices.  Three things fall out:

* **admission by occupancy** — a request needs blocks for its *current*
  tokens, not a ``max_len`` reservation, multiplying servable concurrency;
* **copy-on-write prefix sharing** — sequences with a common prompt prefix
  reference the same physical blocks (refcounted); a write into a shared
  block first copies it (the engine performs the physical copy, this module
  does the bookkeeping);
* **zero-copy scaling** — the pool is partitioned per DP replica
  (``block id = partition * blocks_per_partition + local``), so growing the
  instance appends whole partitions and every surviving sequence's block
  table remains *valid verbatim* — the HMM grows the device pool along the
  block axis reusing surviving shards (``hmm._grow_cache``), a page-table
  remap instead of a buffer copy (DESIGN.md §7).

When the pool runs dry the caller evicts the lowest-priority sequence
(``victim``/``preempt``) and recomputes it on resume — vLLM's recompute-mode
preemption.  This module is pure host-side bookkeeping (no JAX): the engine
and the discrete-event simulator both drive it, and property tests assert
conservation (no block leaked or double-owned) across arbitrary
alloc/append/free/preempt/CoW interleavings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


def blocks_for(num_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``num_tokens`` tokens."""
    return max(1, -(-num_tokens // block_size))


@dataclasses.dataclass
class SeqBlocks:
    """One sequence's view of the pool."""
    seq: int
    partition: int
    priority: int
    blocks: List[int]
    num_tokens: int                    # tokens currently stored
    num_shared: int = 0                # leading blocks adopted via prefix match


@dataclasses.dataclass
class AppendResult:
    """What the caller must do before writing the next token.

    ``block``    — pool index the token will be written into,
    ``cow_src``  — if set, the caller must first copy the physical contents
                   of ``cow_src`` into ``block`` (copy-on-write),
    ``grew``     — True when ``block`` was freshly allocated this call.
    """
    block: int
    cow_src: Optional[int] = None
    grew: bool = False


class KVBlockManager:
    """Fixed per-partition block pools + per-sequence block tables.

    Mirrors ``ExpertPageTable``: allocation is a free-list pop, remapping is
    table surgery, and the device arrays never move.  One partition per DP
    replica; prefix sharing is partition-local (a replica's pool lives on
    that replica's devices — cross-partition sharing would break locality).
    """

    def __init__(self, num_partitions: int, blocks_per_partition: int,
                 block_size: int):
        assert blocks_per_partition > 0 and block_size > 0
        self.blocks_per_partition = blocks_per_partition
        self.block_size = block_size
        self._free: List[List[int]] = []
        self._refcount: Dict[int, int] = {}
        self._seqs: Dict[int, SeqBlocks] = {}
        # prefix index: chain_hash -> [(block, content_key)] of *immutable*
        # blocks of live sequences; content_key is the token tuple so a
        # partial tail matches any request whose tail is a prefix of it.
        self._prefix: Dict[Tuple[int, int], List[Tuple[int, Tuple[int, ...]]]] = {}
        self._block_prefix_key: Dict[int, Tuple[int, int]] = {}
        self.preemptions = 0
        self.cow_copies = 0
        self.shared_block_hits = 0
        for _ in range(num_partitions):
            self._add_partition()

    # ---------------------------------------------------------- partitions
    @property
    def num_partitions(self) -> int:
        return len(self._free)

    @property
    def num_blocks(self) -> int:
        return self.num_partitions * self.blocks_per_partition

    def _add_partition(self):
        base = self.num_blocks
        self._free.append(list(range(base, base + self.blocks_per_partition)))

    def grow_partitions(self, num_partitions: int) -> None:
        """Scale-up: append fresh partitions.  Existing block ids — and
        therefore every live block table — stay valid verbatim."""
        assert num_partitions >= self.num_partitions
        while self.num_partitions < num_partitions:
            self._add_partition()

    def shrink_partitions(self, num_partitions: int) -> None:
        """Scale-down: drop trailing partitions.  They must be fully free
        (the engine drains evicted slots first; sharing is partition-local,
        so no survivor can hold a doomed block)."""
        assert 0 < num_partitions <= self.num_partitions
        for p in range(num_partitions, self.num_partitions):
            assert len(self._free[p]) == self.blocks_per_partition, \
                f"partition {p} still has allocated blocks"
        self._free = self._free[:num_partitions]

    # ------------------------------------------------------------- queries
    def free_blocks(self, partition: Optional[int] = None) -> int:
        if partition is None:
            return sum(len(f) for f in self._free)
        return len(self._free[partition])

    def used_blocks(self) -> int:
        return self.num_blocks - self.free_blocks()

    def utilization(self) -> float:
        return self.used_blocks() / max(self.num_blocks, 1)

    def seq(self, seq: int) -> SeqBlocks:
        return self._seqs[seq]

    def live_seqs(self) -> List[int]:
        return list(self._seqs)

    def block_table(self, seq: int) -> List[int]:
        return list(self._seqs[seq].blocks)

    def blocks_needed(self, num_tokens: int) -> int:
        return blocks_for(num_tokens, self.block_size)

    # ------------------------------------------------------- prefix hashing
    def _chunks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        bs = self.block_size
        return [tuple(tokens[i:i + bs]) for i in range(0, len(tokens), bs)]

    def _match_prefix(self, partition: int, tokens: Sequence[int]
                      ) -> List[int]:
        """Longest chain of live blocks whose contents cover the leading
        chunks of ``tokens`` (a partial last chunk matches a block whose
        contents *start with* it — the CoW-on-append case)."""
        matched: List[int] = []
        h = partition                     # chain seed: partition-local index
        for chunk in self._chunks(tokens):
            cands = self._prefix.get((partition, h), [])
            hit = None
            for block, content in cands:
                if content[:len(chunk)] == chunk:
                    hit = block
                    break
            if hit is None:
                break
            matched.append(hit)
            if len(chunk) < self.block_size:
                break                     # partial tail ends the chain
            h = hash((h, chunk))
        return matched

    def _register_prefix(self, partition: int, tokens: Sequence[int],
                         blocks: Sequence[int]) -> None:
        h = partition
        for chunk, block in zip(self._chunks(tokens), blocks):
            key = (partition, h)
            if block not in [b for b, _ in self._prefix.get(key, [])]:
                self._prefix.setdefault(key, []).append((block, chunk))
                self._block_prefix_key[block] = key
            if len(chunk) < self.block_size:
                break
            h = hash((h, chunk))

    def _unregister_block(self, block: int) -> None:
        key = self._block_prefix_key.pop(block, None)
        if key is None:
            return
        entries = [e for e in self._prefix.get(key, []) if e[0] != block]
        if entries:
            self._prefix[key] = entries
        else:
            self._prefix.pop(key, None)

    # ---------------------------------------------------------- allocation
    def can_allocate(self, num_tokens: int, partition: int,
                     tokens: Optional[Sequence[int]] = None) -> bool:
        """True if ``allocate`` would succeed (prefix credit included)."""
        need = self.blocks_needed(num_tokens)
        if tokens is not None:
            need -= len(self._match_prefix(partition, tokens))
        return len(self._free[partition]) >= max(need, 0)

    def allocate(self, seq: int, num_tokens: int, *, partition: int = 0,
                 priority: int = 0,
                 tokens: Optional[Sequence[int]] = None) -> SeqBlocks:
        """Blocks for a prompt of ``num_tokens`` tokens.  With ``tokens``
        (the prompt ids), leading blocks already resident for another live
        sequence in the same partition are *shared* (refcount bump, no
        allocation, no write) — copy-on-write happens lazily at ``append``.
        Raises MemoryError when the partition's pool is dry (caller
        preempts and retries)."""
        assert seq not in self._seqs, f"seq {seq} already allocated"
        need = self.blocks_needed(num_tokens)
        shared: List[int] = []
        if tokens is not None:
            assert len(tokens) == num_tokens
            shared = self._match_prefix(partition, tokens)[:need]
        fresh_n = need - len(shared)
        if len(self._free[partition]) < fresh_n:
            raise MemoryError(
                f"kv pool dry on partition {partition}: need {fresh_n}, "
                f"free {len(self._free[partition])}")
        for b in shared:
            self._refcount[b] += 1
        self.shared_block_hits += len(shared)
        fresh = [self._free[partition].pop() for _ in range(fresh_n)]
        for b in fresh:
            self._refcount[b] = 1
        sb = SeqBlocks(seq=seq, partition=partition, priority=priority,
                       blocks=shared + fresh, num_tokens=num_tokens,
                       num_shared=len(shared))
        self._seqs[seq] = sb
        if tokens is not None:
            self._register_prefix(partition, tokens, sb.blocks)
        return sb

    def append(self, seq: int) -> Optional[AppendResult]:
        """Reserve a slot for the sequence's next token (written at position
        ``num_tokens``).  Returns None when the current tail block has room
        and is uniquely owned; an AppendResult when the caller must use a
        (possibly CoW-copied) block.  Raises MemoryError when a new block is
        needed and the partition is dry."""
        sb = self._seqs[seq]
        pos = sb.num_tokens
        j = pos // self.block_size
        if j == len(sb.blocks):                       # crosses into new block
            if not self._free[sb.partition]:
                raise MemoryError(
                    f"kv pool dry on partition {sb.partition} (append)")
            b = self._free[sb.partition].pop()
            self._refcount[b] = 1
            sb.blocks.append(b)
            sb.num_tokens += 1
            return AppendResult(block=b, grew=True)
        old = sb.blocks[j]
        if self._refcount[old] > 1:                   # copy-on-write
            if not self._free[sb.partition]:
                raise MemoryError(
                    f"kv pool dry on partition {sb.partition} (CoW)")
            b = self._free[sb.partition].pop()
            self._refcount[b] = 1
            self._refcount[old] -= 1
            sb.blocks[j] = b
            sb.num_shared = min(sb.num_shared, j)
            sb.num_tokens += 1
            self.cow_copies += 1
            return AppendResult(block=b, cow_src=old, grew=True)
        # uniquely owned: writing in place mutates it -> stale prefix entry
        self._unregister_block(old)
        sb.num_tokens += 1
        return None

    def free(self, seq: int) -> List[int]:
        """Release a sequence.  Returns the blocks actually returned to the
        pool (shared blocks survive until their last holder frees them)."""
        sb = self._seqs.pop(seq)
        released = []
        for b in sb.blocks:
            self._refcount[b] -= 1
            if self._refcount[b] == 0:
                del self._refcount[b]
                self._unregister_block(b)
                self._free[sb.partition].append(b)
                released.append(b)
        return released

    # ---------------------------------------------------------- preemption
    def victim(self, candidates: Optional[Sequence[int]] = None,
               exclude: Sequence[int] = ()) -> Optional[int]:
        """Sequence to evict under pressure: lowest priority, youngest
        (highest seq id) on ties — vLLM's recompute-preemption order."""
        pool = [s for s in (candidates if candidates is not None
                            else self._seqs) if s not in exclude
                and s in self._seqs]
        if not pool:
            return None
        return min(pool, key=lambda s: (self._seqs[s].priority, -s))

    def preempt(self, seq: int) -> List[int]:
        """Evict ``seq`` (recompute-on-resume: all state dropped)."""
        self.preemptions += 1
        return self.free(seq)

    # ------------------------------------------------------------- checking
    def check_invariants(self) -> None:
        """No block leaked, double-owned, or double-free (property tests)."""
        bpp = self.blocks_per_partition
        holders: Dict[int, int] = {}
        for sb in self._seqs.values():
            assert len(set(sb.blocks)) == len(sb.blocks), \
                f"seq {sb.seq} holds a block twice"
            for b in sb.blocks:
                assert b // bpp == sb.partition, \
                    f"seq {sb.seq} holds foreign block {b}"
                holders[b] = holders.get(b, 0) + 1
        assert holders == self._refcount, (holders, self._refcount)
        seen = set(holders)
        for p, free in enumerate(self._free):
            assert len(set(free)) == len(free), f"double-free in partition {p}"
            for b in free:
                assert b // bpp == p and b not in holders, b
                seen.add(b)
        assert seen == set(range(self.num_blocks)), "blocks leaked"
        for block, key in self._block_prefix_key.items():
            assert block in self._refcount, \
                f"prefix index references freed block {block}"
            assert any(b == block for b, _ in self._prefix.get(key, []))

    def stats(self) -> Dict[str, float]:
        return {
            "num_blocks": self.num_blocks,
            "used_blocks": self.used_blocks(),
            "utilization": self.utilization(),
            "preemptions": self.preemptions,
            "cow_copies": self.cow_copies,
            "shared_block_hits": self.shared_block_hits,
            "live_seqs": len(self._seqs),
        }
