"""Paged KV-cache block manager — the KV-side analogue of ``expert_pages``.

The paper's HMM "reuses weights and KV caches via zero-copy remapping"
(§5.2).  ``core/expert_pages.py`` applies that to expert weights; this module
applies the same pool-plus-table indirection to the KV cache itself (the
PagedAttention design): the physical cache is a fixed pool of fixed-size
*blocks* (``[L, num_blocks, block_size, KVH, hd]`` on device, see
``models/model.py:init_paged_cache``) and every sequence owns a *block
table* — an ordered list of pool indices.  Three things fall out:

* **admission by occupancy** — a request needs blocks for its *current*
  tokens, not a ``max_len`` reservation, multiplying servable concurrency;
* **copy-on-write prefix sharing** — sequences with a common prompt prefix
  reference the same physical blocks (refcounted); a write into a shared
  block first copies it (the engine performs the physical copy, this module
  does the bookkeeping);
* **zero-copy scaling** — the pool is partitioned per DP replica
  (``block id = partition * blocks_per_partition + local``), so growing the
  instance appends whole partitions and every surviving sequence's block
  table remains *valid verbatim* — the HMM grows the device pool along the
  block axis reusing surviving shards (``hmm._grow_cache``), a page-table
  remap instead of a buffer copy (DESIGN.md §7).

When the pool runs dry the caller evicts the lowest-priority sequence
(``victim``/``preempt``) and recomputes it on resume — vLLM's recompute-mode
preemption.  This module is pure host-side bookkeeping (no JAX): the engine
and the discrete-event simulator both drive it, and property tests assert
conservation (no block leaked or double-owned) across arbitrary
alloc/append/free/preempt/CoW interleavings.

**Live migration (zero-drain scale-down, DESIGN.md §7).**  Shrinking used
to require draining every doomed partition — scale-down latency bounded by
the longest in-flight sequence.  ``begin_migration`` instead *reserves*
blocks on a survivor partition for a whole sharing component of live
sequences (two-phase: sequences keep reading their source blocks — device
truth — while the engine copies rows in the background), and
``commit_migration`` atomically rewrites the block tables, moves CoW
refcounts block-for-block, re-keys the prefix-registry chains to the
destination partition's hash seed, and frees the source blocks.
``abort_migration`` returns the reservation untouched.  Migration is
component-granular precisely so refcounted sharing survives the move.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


def blocks_for(num_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``num_tokens`` tokens."""
    return max(1, -(-num_tokens // block_size))


def block_bytes(mcfg, block_size: int, kv_dtype: Optional[str] = None) -> int:
    """Device bytes of ONE physical KV block across all layers — the unit of
    admission, migration, and CoW accounting.  ``kv_dtype`` is the pool's
    storage dtype (``kv_dtype="int8"`` halves the entries and adds the
    per-token f32 (k, v) scale rows that travel with the block — DESIGN.md
    §11); None uses the model dtype.  Matches ``engine.block_nbytes()``
    (which measures the live pool) and ``topology.kv_cache_bytes`` exactly —
    all three resolve element sizes via ``costmodel.dtype_bytes``."""
    from repro.core.costmodel import dtype_bytes
    kv_bpe = dtype_bytes(kv_dtype or mcfg.dtype)
    scale = 2 * 4 if (kv_dtype or mcfg.dtype) != mcfg.dtype else 0
    return mcfg.num_layers * block_size * (
        2 * mcfg.num_kv_heads * mcfg.resolved_head_dim * kv_bpe + scale)


@dataclasses.dataclass
class SeqBlocks:
    """One sequence's view of the pool."""
    seq: int
    partition: int
    priority: int
    blocks: List[int]
    num_tokens: int                    # tokens currently stored
    num_shared: int = 0                # leading blocks adopted via prefix match


@dataclasses.dataclass
class MigrationTicket:
    """An in-flight cross-partition move of one sharing component.

    ``pairs`` is the device copy list — the caller must copy the physical
    contents of every ``src`` block into its ``dst`` block (in any order;
    the blocks are frozen: migrating sequences may not append) before
    calling ``commit_migration``.  Until commit, every sequence still
    *reads* its source blocks — the ticket only holds a reservation on the
    destination partition, so ``abort_migration`` is a pure unwind."""
    tid: int
    seqs: List[int]
    src_partition: int
    dst_partition: int
    pairs: List[Tuple[int, int]]           # (src_block, dst_block)
    mapping: Dict[int, int]                # src_block -> dst_block

    @property
    def num_blocks(self) -> int:
        return len(self.pairs)


@dataclasses.dataclass
class AppendResult:
    """What the caller must do before writing the next token.

    ``block``    — pool index the token will be written into,
    ``cow_src``  — if set, the caller must first copy the physical contents
                   of ``cow_src`` into ``block`` (copy-on-write),
    ``grew``     — True when ``block`` was freshly allocated this call.
    """
    block: int
    cow_src: Optional[int] = None
    grew: bool = False


class KVBlockManager:
    """Fixed per-partition block pools + per-sequence block tables.

    Mirrors ``ExpertPageTable``: allocation is a free-list pop, remapping is
    table surgery, and the device arrays never move.  One partition per DP
    replica; prefix sharing is partition-local (a replica's pool lives on
    that replica's devices — cross-partition sharing would break locality).
    """

    def __init__(self, num_partitions: int, blocks_per_partition: int,
                 block_size: int):
        assert blocks_per_partition > 0 and block_size > 0
        self.blocks_per_partition = blocks_per_partition
        self.block_size = block_size
        self._free: List[List[int]] = []
        self._refcount: Dict[int, int] = {}
        self._seqs: Dict[int, SeqBlocks] = {}
        # prefix index: chain_hash -> [(block, content_key)] of *immutable*
        # blocks of live sequences; content_key is the token tuple so a
        # partial tail matches any request whose tail is a prefix of it.
        self._prefix: Dict[Tuple[int, int], List[Tuple[int, Tuple[int, ...]]]] = {}
        self._block_prefix_key: Dict[int, Tuple[int, int]] = {}
        self.preemptions = 0
        self.cow_copies = 0
        self.shared_block_hits = 0
        # live migrations (zero-drain scale-down): tid -> MigrationTicket
        self._migrations: Dict[int, MigrationTicket] = {}
        self._next_tid = 0
        self.migrated_blocks = 0
        for _ in range(num_partitions):
            self._add_partition()

    # ---------------------------------------------------------- partitions
    @property
    def num_partitions(self) -> int:
        return len(self._free)

    @property
    def num_blocks(self) -> int:
        return self.num_partitions * self.blocks_per_partition

    def _add_partition(self):
        base = self.num_blocks
        self._free.append(list(range(base, base + self.blocks_per_partition)))

    def grow_partitions(self, num_partitions: int) -> None:
        """Scale-up: append fresh partitions.  Existing block ids — and
        therefore every live block table — stay valid verbatim."""
        assert num_partitions >= self.num_partitions
        while self.num_partitions < num_partitions:
            self._add_partition()

    def shrink_partitions(self, num_partitions: int) -> None:
        """Scale-down: drop trailing partitions.  They must be fully free —
        the engine first *migrates* live sequences onto survivors (or, in
        drain mode, lets evicted slots finish); sharing is partition-local,
        so no survivor can hold a doomed block."""
        assert 0 < num_partitions <= self.num_partitions
        assert not self._migrations, \
            "cannot shrink with migrations in flight (commit/abort first)"
        for p in range(num_partitions, self.num_partitions):
            assert len(self._free[p]) == self.blocks_per_partition, \
                f"partition {p} still has allocated blocks"
        self._free = self._free[:num_partitions]

    # ------------------------------------------------------------- queries
    def free_blocks(self, partition: Optional[int] = None) -> int:
        if partition is None:
            return sum(len(f) for f in self._free)
        return len(self._free[partition])

    def used_blocks(self) -> int:
        return self.num_blocks - self.free_blocks()

    def utilization(self) -> float:
        return self.used_blocks() / max(self.num_blocks, 1)

    def seq(self, seq: int) -> SeqBlocks:
        return self._seqs[seq]

    def live_seqs(self) -> List[int]:
        return list(self._seqs)

    def block_table(self, seq: int) -> List[int]:
        return list(self._seqs[seq].blocks)

    def blocks_needed(self, num_tokens: int) -> int:
        return blocks_for(num_tokens, self.block_size)

    # ------------------------------------------------------- prefix hashing
    def _chunks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        bs = self.block_size
        return [tuple(tokens[i:i + bs]) for i in range(0, len(tokens), bs)]

    def _match_prefix(self, partition: int, tokens: Sequence[int]
                      ) -> List[int]:
        """Longest chain of live blocks whose contents cover the leading
        chunks of ``tokens`` (a partial last chunk matches a block whose
        contents *start with* it — the CoW-on-append case)."""
        matched: List[int] = []
        h = partition                     # chain seed: partition-local index
        for chunk in self._chunks(tokens):
            cands = self._prefix.get((partition, h), [])
            hit = None
            for block, content in cands:
                if content[:len(chunk)] == chunk:
                    hit = block
                    break
            if hit is None:
                break
            matched.append(hit)
            if len(chunk) < self.block_size:
                break                     # partial tail ends the chain
            h = hash((h, chunk))
        return matched

    def _register_prefix(self, partition: int, tokens: Sequence[int],
                         blocks: Sequence[int]) -> None:
        h = partition
        for chunk, block in zip(self._chunks(tokens), blocks):
            key = (partition, h)
            if block not in [b for b, _ in self._prefix.get(key, [])]:
                self._prefix.setdefault(key, []).append((block, chunk))
                self._block_prefix_key[block] = key
            if len(chunk) < self.block_size:
                break
            h = hash((h, chunk))

    def prefix_match_blocks(self, partition: int,
                            tokens: Sequence[int]) -> List[int]:
        """Public read-only prefix probe (no state change): the chain of
        live registered blocks covering the leading chunks of ``tokens``.
        Prefix-cache-aware admission ranks candidate partitions by this
        length before binding a request to a slot (engine.preferred_slots)."""
        return self._match_prefix(partition, tokens)

    def register_written(self, seq: int, tokens: Sequence[int],
                         upto: int) -> None:
        """Register prefix chains for the first ``upto`` tokens of ``seq``'s
        prompt — the chunked-prefill path, where a block only becomes
        matchable once its KV is actually resident (registering at allocate
        time, as the monolithic path does, would let a matching arrival bind
        to blocks whose contents are still pending).  Only fully-written
        blocks register until ``upto`` reaches the whole prompt, then the
        partial tail registers too (the CoW-on-append case).  Idempotent."""
        sb = self._seqs[seq]
        upto = min(upto, len(tokens))
        if upto >= len(tokens):
            self._register_prefix(sb.partition, tokens, sb.blocks)
        else:
            nb = upto // self.block_size
            self._register_prefix(sb.partition, tokens[:nb * self.block_size],
                                  sb.blocks[:nb])

    def _unregister_block(self, block: int) -> None:
        key = self._block_prefix_key.pop(block, None)
        if key is None:
            return
        entries = [e for e in self._prefix.get(key, []) if e[0] != block]
        if entries:
            self._prefix[key] = entries
        else:
            self._prefix.pop(key, None)

    # ---------------------------------------------------------- allocation
    def can_allocate(self, num_tokens: int, partition: int,
                     tokens: Optional[Sequence[int]] = None) -> bool:
        """True if ``allocate`` would succeed (prefix credit included)."""
        need = self.blocks_needed(num_tokens)
        if tokens is not None:
            need -= len(self._match_prefix(partition, tokens))
        return len(self._free[partition]) >= max(need, 0)

    def allocate(self, seq: int, num_tokens: int, *, partition: int = 0,
                 priority: int = 0,
                 tokens: Optional[Sequence[int]] = None,
                 register: bool = True) -> SeqBlocks:
        """Blocks for a prompt of ``num_tokens`` tokens.  With ``tokens``
        (the prompt ids), leading blocks already resident for another live
        sequence in the same partition are *shared* (refcount bump, no
        allocation, no write) — copy-on-write happens lazily at ``append``.
        ``register=False`` defers prefix registration (chunked prefill
        registers progressively via ``register_written`` as chunks land —
        an unwritten block must never be matchable).  Raises MemoryError
        when the partition's pool is dry (caller preempts and retries)."""
        assert seq not in self._seqs, f"seq {seq} already allocated"
        need = self.blocks_needed(num_tokens)
        shared: List[int] = []
        if tokens is not None:
            assert len(tokens) == num_tokens
            shared = self._match_prefix(partition, tokens)[:need]
        fresh_n = need - len(shared)
        if len(self._free[partition]) < fresh_n:
            raise MemoryError(
                f"kv pool dry on partition {partition}: need {fresh_n}, "
                f"free {len(self._free[partition])}")
        for b in shared:
            self._refcount[b] += 1
        self.shared_block_hits += len(shared)
        fresh = [self._free[partition].pop() for _ in range(fresh_n)]
        for b in fresh:
            self._refcount[b] = 1
        sb = SeqBlocks(seq=seq, partition=partition, priority=priority,
                       blocks=shared + fresh, num_tokens=num_tokens,
                       num_shared=len(shared))
        self._seqs[seq] = sb
        if tokens is not None and register:
            self._register_prefix(partition, tokens, sb.blocks)
        return sb

    def append(self, seq: int) -> Optional[AppendResult]:
        """Reserve a slot for the sequence's next token (written at position
        ``num_tokens``).  Returns None when the current tail block has room
        and is uniquely owned; an AppendResult when the caller must use a
        (possibly CoW-copied) block.  Raises MemoryError when a new block is
        needed and the partition is dry."""
        sb = self._seqs[seq]
        assert not self.migrating(seq), \
            f"seq {seq} is mid-migration (blocks frozen)"
        pos = sb.num_tokens
        j = pos // self.block_size
        if j == len(sb.blocks):                       # crosses into new block
            if not self._free[sb.partition]:
                raise MemoryError(
                    f"kv pool dry on partition {sb.partition} (append)")
            b = self._free[sb.partition].pop()
            self._refcount[b] = 1
            sb.blocks.append(b)
            sb.num_tokens += 1
            return AppendResult(block=b, grew=True)
        old = sb.blocks[j]
        if self._refcount[old] > 1:                   # copy-on-write
            if not self._free[sb.partition]:
                raise MemoryError(
                    f"kv pool dry on partition {sb.partition} (CoW)")
            b = self._free[sb.partition].pop()
            self._refcount[b] = 1
            self._refcount[old] -= 1
            sb.blocks[j] = b
            sb.num_shared = min(sb.num_shared, j)
            sb.num_tokens += 1
            self.cow_copies += 1
            return AppendResult(block=b, cow_src=old, grew=True)
        # uniquely owned: writing in place mutates it -> stale prefix entry
        self._unregister_block(old)
        sb.num_tokens += 1
        return None

    def free(self, seq: int) -> List[int]:
        """Release a sequence.  Returns the blocks actually returned to the
        pool (shared blocks survive until their last holder frees them)."""
        assert not self.migrating(seq), \
            f"seq {seq} is mid-migration (abort_migration first)"
        sb = self._seqs.pop(seq)
        released = []
        for b in sb.blocks:
            self._refcount[b] -= 1
            if self._refcount[b] == 0:
                del self._refcount[b]
                self._unregister_block(b)
                self._free[sb.partition].append(b)
                released.append(b)
        return released

    # ---------------------------------------------------------- preemption
    def victim(self, candidates: Optional[Sequence[int]] = None,
               exclude: Sequence[int] = ()) -> Optional[int]:
        """Sequence to evict under pressure: lowest priority, youngest
        (highest seq id) on ties — vLLM's recompute-preemption order."""
        pool = [s for s in (candidates if candidates is not None
                            else self._seqs) if s not in exclude
                and s in self._seqs and not self.migrating(s)]
        if not pool:
            return None
        return min(pool, key=lambda s: (self._seqs[s].priority, -s))

    def preempt(self, seq: int) -> List[int]:
        """Evict ``seq`` (recompute-on-resume: all state dropped)."""
        self.preemptions += 1
        return self.free(seq)

    # ----------------------------------------------------------- migration
    def migrating(self, seq: int) -> bool:
        return any(seq in t.seqs for t in self._migrations.values())

    @property
    def migrations_pending(self) -> int:
        return len(self._migrations)

    def share_components(self, partition: int) -> List[List[int]]:
        """Live sequences of ``partition`` grouped into connected components
        of the block-sharing graph (CoW'd prefixes).  A component is the
        migration unit: moving it whole keeps every refcount intact.
        Deterministic: components and members sorted by sequence id."""
        holders: Dict[int, List[int]] = {}
        for s, sb in self._seqs.items():
            if sb.partition != partition:
                continue
            for b in sb.blocks:
                holders.setdefault(b, []).append(s)
        parent: Dict[int, int] = {}

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for seqs in holders.values():
            for s in seqs:
                parent.setdefault(s, s)
            for s in seqs[1:]:
                parent[find(seqs[0])] = find(s)
        comps: Dict[int, List[int]] = {}
        for s in parent:
            comps.setdefault(find(s), []).append(s)
        return sorted((sorted(c) for c in comps.values()), key=lambda c: c[0])

    def migration_need(self, seqs: Sequence[int]) -> int:
        """Blocks a ``begin_migration`` of ``seqs`` would reserve (unique
        blocks across the component — shared blocks counted once)."""
        return len({b for s in seqs for b in self._seqs[s].blocks})

    def begin_migration(self, seqs: Sequence[int],
                        dst_partition: int) -> MigrationTicket:
        """Reserve destination blocks for a whole sharing component.

        Validates the component is closed (every co-owner of every block is
        in ``seqs`` — otherwise the move would strand a survivor's table)
        and reserves one destination block per unique source block.  No
        sequence state changes: the caller device-copies ``ticket.pairs``
        and then commits.  Raises MemoryError when the destination
        partition lacks free blocks (the caller falls back to
        recompute-preemption)."""
        assert seqs, "empty migration"
        parts = {self._seqs[s].partition for s in seqs}
        assert len(parts) == 1, f"component spans partitions {parts}"
        src_partition = parts.pop()
        assert dst_partition != src_partition
        assert 0 <= dst_partition < self.num_partitions
        for s in seqs:
            assert not self.migrating(s), f"seq {s} already migrating"
        order: List[int] = []
        seen = set()
        for s in seqs:
            for b in self._seqs[s].blocks:
                if b not in seen:
                    seen.add(b)
                    order.append(b)
        # closure: a shared block whose co-owner stays behind cannot move
        for s, sb in self._seqs.items():
            if s not in seqs:
                assert not seen & set(sb.blocks), \
                    f"seq {s} shares blocks with the migrating component"
        if len(self._free[dst_partition]) < len(order):
            raise MemoryError(
                f"survivor partition {dst_partition} lacks free blocks for "
                f"migration: need {len(order)}, "
                f"free {len(self._free[dst_partition])}")
        dst = [self._free[dst_partition].pop() for _ in order]
        ticket = MigrationTicket(
            tid=self._next_tid, seqs=sorted(seqs),
            src_partition=src_partition, dst_partition=dst_partition,
            pairs=list(zip(order, dst)), mapping=dict(zip(order, dst)))
        self._next_tid += 1
        self._migrations[ticket.tid] = ticket
        return ticket

    def commit_migration(self, ticket: MigrationTicket) -> List[int]:
        """Atomic cut-over after the caller copied every pair: rewrite the
        component's block tables to the destination blocks, move refcounts
        block-for-block, re-key prefix-registry chains onto the destination
        partition's hash seed, and free the source blocks.  Returns them."""
        t = self._migrations.pop(ticket.tid)
        # 1. read the registered prefix chains against the pristine registry
        #    (chain hash = fold of chunk contents from the partition seed)
        moves: Dict[int, Tuple[Tuple[int, int], Tuple[int, ...]]] = {}
        for s in t.seqs:
            h_old, h_new = t.src_partition, t.dst_partition
            for b in self._seqs[s].blocks:
                if self._block_prefix_key.get(b) != (t.src_partition, h_old):
                    break            # unregistered tail / diverged chain
                chunk = next((c for bb, c
                              in self._prefix.get((t.src_partition, h_old),
                                                  []) if bb == b), None)
                if chunk is None:
                    break
                moves.setdefault(b, ((t.dst_partition, h_new), chunk))
                if len(chunk) < self.block_size:
                    break
                h_old = hash((h_old, chunk))
                h_new = hash((h_new, chunk))
        # 2. re-key matched chains; 3. drop any stragglers (stale entries
        #    must not reference blocks returning to the free list)
        for b_src, (new_key, chunk) in moves.items():
            self._unregister_block(b_src)
            b_dst = t.mapping[b_src]
            self._prefix.setdefault(new_key, []).append((b_dst, chunk))
            self._block_prefix_key[b_dst] = new_key
        for b_src in t.mapping:
            if b_src in self._block_prefix_key:
                self._unregister_block(b_src)
        # 4. refcounts + tables
        for b_src, b_dst in t.mapping.items():
            self._refcount[b_dst] = self._refcount.pop(b_src)
        for s in t.seqs:
            sb = self._seqs[s]
            sb.blocks = [t.mapping[b] for b in sb.blocks]
            sb.partition = t.dst_partition
        released = sorted(t.mapping)
        self._free[t.src_partition].extend(released)
        self.migrated_blocks += len(t.pairs)
        return released

    def abort_migration(self, ticket: MigrationTicket) -> None:
        """Drop the reservation; sequence state never changed, so this is a
        pure free-list unwind (idempotent for an already-resolved ticket)."""
        t = self._migrations.pop(ticket.tid, None)
        if t is None:
            return
        self._free[t.dst_partition].extend(d for _, d in t.pairs)

    # ------------------------------------------------------------- checking
    def check_invariants(self) -> None:
        """No block leaked, double-owned, or double-free (property tests)."""
        bpp = self.blocks_per_partition
        holders: Dict[int, int] = {}
        for sb in self._seqs.values():
            assert len(set(sb.blocks)) == len(sb.blocks), \
                f"seq {sb.seq} holds a block twice"
            for b in sb.blocks:
                assert b // bpp == sb.partition, \
                    f"seq {sb.seq} holds foreign block {b}"
                holders[b] = holders.get(b, 0) + 1
        assert holders == self._refcount, (holders, self._refcount)
        seen = set(holders)
        reserved = set()
        for t in self._migrations.values():
            srcs = set()
            for s in t.seqs:
                assert s in self._seqs, f"migrating seq {s} vanished"
                srcs |= set(self._seqs[s].blocks)
            assert srcs == set(t.mapping), (srcs, t.mapping)
            for _, d in t.pairs:
                assert d // bpp == t.dst_partition, (d, t.dst_partition)
                assert d not in holders and d not in reserved, \
                    f"migration-reserved block {d} double-owned"
                reserved.add(d)
        seen |= reserved
        for p, free in enumerate(self._free):
            assert len(set(free)) == len(free), f"double-free in partition {p}"
            for b in free:
                assert b // bpp == p and b not in holders \
                    and b not in reserved, b
                seen.add(b)
        assert seen == set(range(self.num_blocks)), "blocks leaked"
        for block, key in self._block_prefix_key.items():
            assert block in self._refcount, \
                f"prefix index references freed block {block}"
            assert any(b == block for b, _ in self._prefix.get(key, []))

    def stats(self) -> Dict[str, float]:
        return {
            "num_blocks": self.num_blocks,
            "used_blocks": self.used_blocks(),
            "utilization": self.utilization(),
            "preemptions": self.preemptions,
            "cow_copies": self.cow_copies,
            "shared_block_hits": self.shared_block_hits,
            "live_seqs": len(self._seqs),
            "migrated_blocks": self.migrated_blocks,
            "migrations_pending": self.migrations_pending,
        }
