"""Discrete-event cluster simulator for paper-scale serving experiments.

This container has no NPUs, so Figs 9/10 and Table 2 (SLO dynamics /
compliance / throughput windows at CloudMatrix scale) are reproduced with a
calibrated discrete-event model.  What is *measured* vs *modelled*:

* scaling latency / downtime / peak memory — from the real planner
  (scaling_plan) + cost model (costmodel), byte-exact;
* per-step serving time — a roofline-flavoured performance model
  (weights-read memory bound for decode, compute bound for prefill) with a
  single system-efficiency fudge calibrated once against Table 2's
  "6 rps before scaling on 6 NPUs" for DeepSeek-V2-Lite and reused
  everywhere;
* engine semantics (continuous batching, drain-free switchover, admission
  pause during scaling) — identical logic to the real JAX engine
  (serving/engine.py), which the integration tests validate on host devices.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.costmodel import DEFAULT_HW, HardwareModel, ScalingCost, plan_cost
from repro.core.scaling_plan import STRATEGIES, placement
from repro.core.topology import ElasticConfig, kv_cache_bytes, model_tensors
from repro.serving.workload import Request


@dataclasses.dataclass
class PerfModel:
    mcfg: ModelConfig
    hbm_bw: float = 1.6e12          # Ascend 910C-class HBM bandwidth
    chip_flops: float = 350e12      # bf16
    sys_eff: float = 0.4            # end-to-end efficiency (calibrated once:
                                    # ~9 rps sustainable for DeepSeek-V2-Lite
                                    # on 6 NPUs with 2000/500-750 workload)
    step_overhead_s: float = 0.004
    max_batch_per_dev: int = 12
    kv_seq_len: int = 4096

    def __post_init__(self):
        bpe = 2
        self._weight_bytes = self.mcfg.param_count() * bpe
        self._active_flops_per_tok = 2 * self.mcfg.param_count(active_only=True)
        self._kv_bytes_per_seq = kv_cache_bytes(self.mcfg, 1, self.kv_seq_len)

    def decode_step_s(self, batch: int, ndev: int) -> float:
        """Memory-bound: every step streams the (sharded) weights."""
        t_mem = self._weight_bytes / (ndev * self.hbm_bw * self.sys_eff)
        t_comp = (batch * self._active_flops_per_tok
                  / (ndev * self.chip_flops * self.sys_eff))
        return self.step_overhead_s + max(t_mem, t_comp)

    def prefill_s(self, prompt: int, ndev: int) -> float:
        return self.step_overhead_s + (
            prompt * self._active_flops_per_tok
            / (ndev * self.chip_flops * self.sys_eff * 4))  # prefill batches well

    def max_batch(self, ndev: int, kv_frac: float = 1.0) -> int:
        free = ndev * DEFAULT_HW.device_hbm * 0.9 - self._weight_bytes
        hbm_limit = int(free * kv_frac / self._kv_bytes_per_seq)
        return max(1, min(hbm_limit, int(self.max_batch_per_dev * ndev
                                         * kv_frac)))


@dataclasses.dataclass
class SimScaleEvent:
    t_command: float
    t_ready: float
    downtime_until: float
    old_ndev: int
    new_ndev: int
    cost: ScalingCost


class ServingSimulator:
    """One logical serving instance with strategy-dependent scaling."""

    def __init__(self, mcfg: ModelConfig, tp: int, ndev: int, *,
                 strategy: str = "elastic", perf: Optional[PerfModel] = None,
                 hw: Optional[HardwareModel] = None, kv_seq_len: int = 4096,
                 preinit: bool = True):
        self.mcfg = mcfg
        self.tp = tp
        self.ndev = ndev
        self.strategy = strategy
        self.perf = perf or PerfModel(mcfg, kv_seq_len=kv_seq_len)
        self.hw = hw or DEFAULT_HW
        # note: baselines also run with a warm engine (pre-provisioned
        # instance); the '-PreInit' ablation isolates the cold-boot add-on
        self.preinit = preinit
        # colocated keeps a resident standby copy -> halved KV capacity and
        # degraded stability (paper §7.6: memory pressure)
        self.kv_frac = 0.5 if strategy == "colocated" else 1.0
        if strategy == "colocated":
            self.perf = dataclasses.replace(self.perf,
                                            sys_eff=self.perf.sys_eff * 0.6)
        self._pending: List[Request] = []
        self._pi = 0
        self.t = 0.0
        self.queue: List[Request] = []
        self.running: List[Tuple[float, Request]] = []  # (finish_est, req)
        self.finished: List[Request] = []
        self.scale: Optional[SimScaleEvent] = None
        self.events: List[SimScaleEvent] = []
        self.extra_devices_during_scale = 0

    # ------------------------------------------------------------- scaling
    def command_scale(self, new_ndev: int):
        assert self.scale is None
        kvb = kv_cache_bytes(self.mcfg, 8, self.perf.kv_seq_len)
        tensors = model_tensors(self.mcfg, self.tp, kv_bytes_per_replica=kvb)
        old = ElasticConfig(self.ndev // self.tp, self.tp,
                            tuple(range(self.ndev)))
        if self.strategy in ("extravagant", "horizontal"):
            base = self.ndev
            new = ElasticConfig(new_ndev // self.tp, self.tp,
                                tuple(range(base, base + new_ndev)))
            self.extra_devices_during_scale = new_ndev
        else:
            new = ElasticConfig(new_ndev // self.tp, self.tp,
                                tuple(range(new_ndev)))
        plan = STRATEGIES[self.strategy](tensors, old, new)
        resident = {d: sum(s.values())
                    for d, s in placement(tensors, old).items()}
        cost = plan_cost(plan, hw=self.hw, preinit=self.preinit,
                         strategy=self.strategy,
                         resident_bytes_per_device=resident)
        self.scale = SimScaleEvent(
            t_command=self.t, t_ready=self.t + cost.scale_time_s,
            downtime_until=self.t + cost.downtime_s if cost.downtime_s else 0,
            old_ndev=self.ndev, new_ndev=new_ndev, cost=cost)
        self.events.append(self.scale)
        if cost.downtime_s:
            # in-flight requests are stalled for the whole outage (§3 L2)
            self.running = [(f + cost.scale_time_s, rid, r)
                            for f, rid, r in self.running]
            heapq.heapify(self.running)

    # -------------------------------------------------------------- engine
    def _serving_capacity(self) -> Tuple[int, bool]:
        """(effective ndev, admitting_new) given any in-flight scale."""
        if self.scale is None:
            return self.ndev, True
        if self.t >= self.scale.t_ready:
            self.ndev = self.scale.new_ndev
            self.scale = None
            self.extra_devices_during_scale = 0
            return self.ndev, True
        if self.strategy == "cold_restart":
            return 0, False                      # downtime
        if self.strategy in ("extravagant", "horizontal"):
            return self.ndev, True               # old untouched
        # elastic / colocated: old serves but pauses NEW admissions (§C)
        return self.ndev, False

    def run(self, requests: List[Request], until: float, dt: float = 0.05):
        """Advance to ``until``; ``requests`` are *added* to the pending set
        (arrivals persist across calls)."""
        if requests:
            self._pending = sorted(self._pending[self._pi:] + list(requests),
                                   key=lambda r: r.arrival_s)
            self._pi = 0
        pending, i = self._pending, self._pi
        while self.t < until:
            ndev, admit = self._serving_capacity()
            while i < len(pending) and pending[i].arrival_s <= self.t:
                self.queue.append(pending[i])
                i += 1
            self._pi = i
            if ndev > 0:
                cap = self.perf.max_batch(ndev, self.kv_frac)
                # admit from queue
                while admit and self.queue and len(self.running) < cap:
                    req = self.queue.pop(0)
                    t_first = self.t + self.perf.prefill_s(req.prompt_len,
                                                           ndev)
                    req.first_token_s = t_first
                    dur = req.output_len * self.perf.decode_step_s(
                        max(len(self.running) + 1, 1), ndev)
                    heapq.heappush(self.running,
                                   (t_first + dur, req.rid, req))
                # complete requests
                while self.running and self.running[0][0] <= self.t:
                    _, _, req = heapq.heappop(self.running)
                    req.finish_s = self.t
                    self.finished.append(req)
            self.t += dt
        return self.finished

    def throughput(self, t0: float, t1: float) -> float:
        n = sum(1 for r in self.finished
                if r.finish_s is not None and t0 <= r.finish_s < t1)
        return n / max(t1 - t0, 1e-9)
