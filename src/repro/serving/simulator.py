"""Discrete-event cluster simulator for paper-scale serving experiments.

This container has no NPUs, so Figs 9/10 and Table 2 (SLO dynamics /
compliance / throughput windows at CloudMatrix scale) are reproduced with a
calibrated discrete-event model.  What is *measured* vs *modelled*:

* scaling latency / downtime / peak memory — from the real planner
  (scaling_plan) + cost model (costmodel), byte-exact;
* per-step serving time — a roofline-flavoured performance model
  (weights-read memory bound for decode, compute bound for prefill) with a
  single system-efficiency fudge calibrated once against Table 2's
  "6 rps before scaling on 6 NPUs" for DeepSeek-V2-Lite and reused
  everywhere;
* engine semantics (continuous batching, drain-free switchover, admission
  pause during scaling) — *shared* code with the real JAX engine: the
  admission gate during a transition is ``driver.admission_during_scale``
  (the same function the ClusterDriver applies to ``ElasticServer``), and
  scaling runs as a ``SimScalingTask`` implementing the same
  ``ScalingTask`` phases the engine path uses, so a ``ClusterDriver`` loop
  runs unchanged over either backend.

Measured vs modelled (the README table is generated from this docstring):

| quantity                         | source                                  |
|----------------------------------|-----------------------------------------|
| scaling latency / downtime       | planner bytes x cost model (byte-exact) |
| peak memory during transition    | planner placement (byte-exact)          |
| per-step decode/prefill time     | roofline model, one calibrated sys_eff  |
| engine/scaling semantics         | shared code with serving/engine.py      |
| KV admission (dense vs paged)    | same policies as the engine: full-length|
|                                  | reservation vs block occupancy with     |
|                                  | preemption (kv_blocks.py, DESIGN.md §7) |
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.costmodel import DEFAULT_HW, HardwareModel, ScalingCost
from repro.core.expert_pages import ExpertPageTable
from repro.core.topology import ElasticConfig, kv_cache_bytes
from repro.serving.driver import (ScalePhase, admission_during_scale,
                                  projected_migration_blocks,
                                  transition_cost, unpark_transition_cost)
from repro.serving.kv_blocks import blocks_for as kv_blocks_for
from repro.serving.metrics import latency_percentiles
from repro.serving.rebalance import RebalancePolicy
from repro.serving.scheduler import PrefillJob, TokenBudgetScheduler
from repro.serving.workload import Request, merge_arrivals


@dataclasses.dataclass
class PerfModel:
    mcfg: ModelConfig
    hbm_bw: float = 1.6e12          # Ascend 910C-class HBM bandwidth
    chip_flops: float = 350e12      # bf16
    sys_eff: float = 0.4            # end-to-end efficiency (calibrated once:
                                    # ~9 rps sustainable for DeepSeek-V2-Lite
                                    # on 6 NPUs with 2000/500-750 workload)
    step_overhead_s: float = 0.004
    max_batch_per_dev: int = 12
    kv_seq_len: int = 4096
    kv_block_size: int = 256        # paged mode: tokens per KV block
    kv_dtype: Optional[str] = None  # 'int8': quantized KV pool byte sizing

    def __post_init__(self):
        bpe = 2
        self._weight_bytes = self.mcfg.param_count() * bpe
        self._active_flops_per_tok = 2 * self.mcfg.param_count(active_only=True)
        self._kv_bytes_per_seq = kv_cache_bytes(self.mcfg, 1, self.kv_seq_len,
                                                kv_dtype=self.kv_dtype)
        self._kv_block_bytes = kv_cache_bytes(self.mcfg, 1, self.kv_block_size,
                                              kv_dtype=self.kv_dtype)

    def decode_step_s(self, batch: int, ndev: int) -> float:
        """Memory-bound: every step streams the (sharded) weights."""
        t_mem = self._weight_bytes / (ndev * self.hbm_bw * self.sys_eff)
        t_comp = (batch * self._active_flops_per_tok
                  / (ndev * self.chip_flops * self.sys_eff))
        return self.step_overhead_s + max(t_mem, t_comp)

    def prefill_s(self, prompt: int, ndev: int) -> float:
        return self.step_overhead_s + (
            prompt * self._active_flops_per_tok
            / (ndev * self.chip_flops * self.sys_eff * 4))  # prefill batches well

    def _free_kv_bytes(self, ndev: int, kv_frac: float) -> float:
        return (ndev * DEFAULT_HW.device_hbm * 0.9
                - self._weight_bytes) * kv_frac

    def max_batch(self, ndev: int, kv_frac: float = 1.0) -> int:
        """Dense admission: every sequence reserves a full ``kv_seq_len``
        row up front."""
        hbm_limit = int(self._free_kv_bytes(ndev, kv_frac)
                        / self._kv_bytes_per_seq)
        return max(1, min(hbm_limit, int(self.max_batch_per_dev * ndev
                                         * kv_frac)))

    def pool_blocks(self, ndev: int, kv_frac: float = 1.0) -> int:
        """Paged admission: the same KV budget carved into blocks
        (serving/kv_blocks.py) — a sequence only occupies blocks for the
        tokens it currently holds."""
        return max(1, int(self._free_kv_bytes(ndev, kv_frac)
                          // self._kv_block_bytes))

    def blocks_for(self, num_tokens: int) -> int:
        # the engine's exact admission granularity (kv_blocks.blocks_for)
        return kv_blocks_for(int(num_tokens), self.kv_block_size)


@dataclasses.dataclass
class SimRoutingModel:
    """Synthesized router telemetry for a Zipf-skewed expert workload.

    The roofline model has no router, so for rebalancer experiments the
    sim draws per-(layer, expert) token counts from a Zipf(``skew``)
    share, permuted per layer with a seeded RNG so layers disagree about
    *which* experts are hot (exactly the shape the real histograms show).
    ``stats()`` matches ``InferenceEngine.routing_stats()`` key-for-key,
    so the shared ``RebalancePolicy`` and ``metrics.summarize`` consume
    either backend's telemetry unchanged."""
    num_moe_layers: int
    num_experts: int
    skew: float = 1.2
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.num_experts + 1,
                          dtype=np.float64) ** -self.skew
        share = ranks / ranks.sum()
        self._share = np.stack([share[rng.permutation(self.num_experts)]
                                for _ in range(self.num_moe_layers)])
        self._counts = np.zeros_like(self._share)
        self.samples = 0

    def observe(self, tokens: int) -> None:
        """Account one sampled decode tick routing ``tokens`` tokens."""
        if tokens <= 0:
            return
        self._counts += self._share * tokens
        self.samples += 1

    def stats(self) -> Optional[dict]:
        if self.samples == 0:
            return None
        tot = self._counts.sum(axis=1, keepdims=True)
        share = self._counts / np.maximum(tot, 1.0)
        mean = share.mean(axis=1)
        return {"samples": self.samples, "counts": self._counts.copy(),
                "top_expert_share": float(share.max(axis=1).mean()),
                "expert_cv": float((share.std(axis=1)
                                    / np.maximum(mean, 1e-12)).mean())}

    def reset(self) -> None:
        """Same contract as ``InferenceEngine.reset_routing_stats``."""
        self._counts[:] = 0.0
        self.samples = 0


@dataclasses.dataclass
class SimScaleEvent:
    t_command: float
    t_ready: float
    downtime_until: float
    old_ndev: int
    new_ndev: int
    cost: ScalingCost
    # zero-drain scale-down (scaledown="migrate", paged KV): live KV blocks
    # modelled as moving off doomed partitions (shared policy:
    # driver.projected_migration_blocks); 0 for scale-up / drain mode
    migrated_blocks: int = 0
    migration_bytes: int = 0
    # serving-latency snapshot at command time (finished requests so far;
    # NaN until the first finish): metrics.latency_percentiles
    ttft_p50: float = float("nan")
    ttft_p99: float = float("nan")
    itl_p50: float = float("nan")
    itl_p99: float = float("nan")


class SimScalingTask:
    """driver.ScalingTask over modelled time — already the poll semantics
    the protocol specifies: ``advance`` never performs work, it observes
    modelled time, stays in STAGING until the cost model's ``t_ready`` and
    then commits instantaneously.  The same object is advanced by a
    ClusterDriver (closed loop) or by the simulator itself (scripted
    ``command_scale`` benchmarks) — whichever observes ``t_ready`` first.

    ``stall_s`` / ``overlap_efficiency`` mirror the real engine task's
    completion metrics: the modelled decode stall (whole transfer time for
    serial staging, the HBM-contention share when overlapped) and the
    Σ-op-time / staging-window ratio from the cost breakdown."""

    def __init__(self, sim: "ServingSimulator", target: ElasticConfig,
                 event: SimScaleEvent):
        self.sim = sim
        self.target = target
        self.event = event
        self.phase = ScalePhase.STAGING
        # plan_cost zeroes decode_stall_s on downtime transitions (the
        # outage subsumes the stall), so no re-guarding here
        self.stall_s = event.cost.decode_stall_s
        # mirror the engine task's completion metrics (DriverEvent fill-in)
        self.migrated_blocks = event.migrated_blocks
        self.migration_bytes = event.migration_bytes

    @property
    def done(self) -> bool:
        return self.phase.terminal

    @property
    def overlap_efficiency(self) -> Optional[float]:
        op = self.event.cost.breakdown.get("op_s", 0.0)
        if not op:
            return None
        return op / max(self.event.cost.scale_time_s, 1e-9)

    def advance(self, now: float) -> ScalePhase:
        if self.phase is ScalePhase.STAGING and now >= self.event.t_ready:
            self.phase = ScalePhase.COMMITTING
            # sim-time span, explicit timestamps (tracer clock domain is
            # whatever the installed clock reads — see DESIGN.md §9)
            obs.get_tracer().complete(
                "scale.STAGING", self.event.t_command, self.event.t_ready,
                cat="scale", tid="sim-scale",
                args={"old_ndev": self.event.old_ndev,
                      "new_ndev": self.event.new_ndev})
        if self.phase is ScalePhase.COMMITTING:
            self.sim.ndev = self.event.new_ndev
            self.sim.extra_devices_during_scale = 0
            self.sim.scale = None
            if self.sim.expert_pages is not None \
                    and self.sim.strategy == "elastic":
                # track the placement the pooled engine would commit:
                # min-move remap keeps experts via ANY resident copy and
                # retires the losing replicas (expert_pages.commit)
                self.sim.expert_pages.stage_remap(self.target, min_move=True)
                self.sim.expert_pages.commit()
            if self.sim.routing is not None:
                # same staleness rule as ElasticServer.switchover: the
                # histogram described the old placement
                self.sim.routing.reset()
            self.phase = ScalePhase.DONE
            obs.get_tracer().instant(
                "scale.commit", cat="scale", t=now, tid="sim-scale",
                args={"new_ndev": self.event.new_ndev})
        return self.phase


class SimUnparkTask:
    """driver.ScalingTask for a modelled cold start from the pinned-host
    tier (scale-from-zero, DESIGN.md §12).  STAGING until the unpark cost
    model's ``t_ready`` — the whole-snapshot H2D window priced at
    ``hw.h2d_bw`` with the AOT compile hidden underneath (overlap mode) —
    then an instantaneous commit: devices return, a fresh expert placement
    is laid out, and admission resumes.  Mirrors the real ``UnparkTask``
    phase-for-phase so a fleet loop drives either backend unchanged."""

    def __init__(self, sim: "ServingSimulator", target: ElasticConfig,
                 event: SimScaleEvent):
        self.sim = sim
        self.target = target
        self.event = event
        self.phase = ScalePhase.STAGING
        self.stall_s = 0.0

    @property
    def done(self) -> bool:
        return self.phase.terminal

    def advance(self, now: float) -> ScalePhase:
        if self.phase is ScalePhase.STAGING and now >= self.event.t_ready:
            self.phase = ScalePhase.COMMITTING
            obs.get_tracer().complete(
                "unpark.STAGING", self.event.t_command, self.event.t_ready,
                cat="scale", tid="sim-scale",
                args={"new_ndev": self.event.new_ndev})
        if self.phase is ScalePhase.COMMITTING:
            sim = self.sim
            sim.ndev = self.event.new_ndev
            sim.parked = False
            sim.scale = None
            if sim.expert_pages is not None:
                # nothing survived the park on-device: fresh table, fresh
                # balanced placement at the cold-start width (the real HMM
                # initial_places the unpark table the same way)
                n_moe = sim.mcfg.num_layers - sim.mcfg.first_k_dense
                sim.expert_pages = ExpertPageTable(
                    n_moe, sim.mcfg.num_experts,
                    host_pool_pages=sim._expert_host_pages)
                sim.expert_pages.initial_place(sim.current_config())
            if sim.routing is not None:
                sim.routing.reset()
            self.phase = ScalePhase.DONE
            obs.get_tracer().instant(
                "unpark.commit", cat="scale", t=now, tid="sim-scale",
                args={"new_ndev": self.event.new_ndev})
        return self.phase


class ServingSimulator:
    """One logical serving instance with strategy-dependent scaling."""

    def __init__(self, mcfg: ModelConfig, tp: int, ndev: int, *,
                 strategy: str = "elastic", perf: Optional[PerfModel] = None,
                 hw: Optional[HardwareModel] = None, kv_seq_len: int = 4096,
                 preinit: bool = True, kv_mode: str = "dense",
                 pool_blocks: Optional[int] = None,
                 expert_mode: str = "dense", staging: str = "serial",
                 scaledown: str = "migrate",
                 prefill_chunk: Optional[int] = None,
                 prefill_budget: Optional[int] = None,
                 rebalance: Optional[RebalancePolicy] = None,
                 routing_skew: Optional[float] = None,
                 routing_seed: int = 0,
                 expert_slot_slack: Optional[int] = None,
                 expert_host_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None,
                 expert_dtype: Optional[str] = None):
        self.mcfg = mcfg
        self.tp = tp
        self.ndev = ndev
        self.strategy = strategy
        # quantized pools (mirrors ElasticServer(kv_dtype/expert_dtype)):
        # KV and expert-page bytes are sized at the int8 storage width (plus
        # scale sidecars), so modelled admission capacity roughly doubles
        # and scale events move ~half the expert/KV bytes
        assert kv_dtype in (None, "int8") and expert_dtype in (None, "int8")
        self.kv_dtype = kv_dtype
        self.expert_dtype = expert_dtype
        self.perf = perf or PerfModel(mcfg, kv_seq_len=kv_seq_len,
                                      kv_dtype=kv_dtype)
        self.hw = hw or DEFAULT_HW
        # 'overlap' models the background TransferEngine (mirrors
        # ElasticServer(staging="overlap")): scale events are costed with
        # the overlap pipeline — warmup hidden under the transfer window,
        # decode stall reduced to the HBM-contention share (DESIGN.md §3)
        assert staging in ("serial", "overlap")
        self.staging_mode = staging
        # 'pooled' models the min-move vpage remap: elastic scale events are
        # costed with plan_elastic_paged via the shared transition_cost path
        # (mirrors ElasticServer(expert_mode="pooled"); DESIGN.md §2)
        assert expert_mode in ("dense", "pooled")
        self.expert_mode = expert_mode
        # KV admission: 'dense' reserves a full-length row per admitted
        # request (PerfModel.max_batch); 'paged' admits by block occupancy —
        # a request holds blocks for its *current* tokens, growing as it
        # decodes, and the youngest lowest-priority request is preempted
        # (re-queued, recomputed) when the pool overflows.  Mirrors the real
        # engine's kv_blocks-gated admission so the closed-loop driver sees
        # the same memory-pressure signal on both backends.
        assert kv_mode in ("dense", "paged")
        self.kv_mode = kv_mode
        # scale-down policy, mirroring ElasticServer(scaledown=...):
        # 'migrate' (default, paged only) costs scale-downs as live
        # KV-block migration bytes via the shared
        # projected_migration_blocks policy; 'drain' extends t_ready until
        # the doomed share of in-flight requests would have finished —
        # latency bounded by the longest evicted sequence, the behaviour
        # migration replaces.  Dense KV is coerced to 'drain' exactly like
        # the engine (no block indirection to migrate), so projection and
        # execution report — and cost — the same policy.
        assert scaledown in ("migrate", "drain")
        self.scaledown_mode = scaledown if kv_mode == "paged" else "drain"
        self._pool_blocks_override = pool_blocks
        self.preemptions = 0
        # note: baselines also run with a warm engine (pre-provisioned
        # instance); the '-PreInit' ablation isolates the cold-boot add-on
        self.preinit = preinit
        # colocated keeps a resident standby copy -> halved KV capacity and
        # degraded stability (paper §7.6: memory pressure)
        self.kv_frac = 0.5 if strategy == "colocated" else 1.0
        if strategy == "colocated":
            self.perf = dataclasses.replace(self.perf,
                                            sys_eff=self.perf.sys_eff * 0.6)
        # continuous batching (mirrors InferenceEngine.prefill_chunk):
        #   None -> legacy instant-prefill admission (bit-identical to the
        #           pre-chunking simulator; no token_times synthesized);
        #   0    -> monolithic prefill with decode-stall modelling: admitting
        #           a prompt stalls every running decode for the whole
        #           prefill_s(prompt_len) — the ITL spike chunking removes;
        #   > 0  -> chunked prefill through the SAME TokenBudgetScheduler
        #           the real engine runs (serving/scheduler.py), stalling
        #           decodes one token-budget chunk at a time.
        self.prefill_chunk = prefill_chunk
        self.scheduler = (TokenBudgetScheduler(prefill_chunk, prefill_budget)
                          if prefill_chunk else None)
        self._prefilling: List[PrefillJob] = []
        self._prefill_reqs: Dict[int, Request] = {}
        self._itl_base: Dict[int, float] = {}
        self._stall_gaps: Dict[int, List[float]] = {}
        self._pending: List[Request] = []
        self._pi = 0
        self.t = 0.0
        self.queue: List[Request] = []
        # (finish_est, rid, req, t_decode_start) — t_decode_start tracks the
        # *current* attempt (reset when a preempted request is re-admitted)
        self.running: List[Tuple[float, int, Request, float]] = []
        self.finished: List[Request] = []
        self.scale: Optional[SimScalingTask] = None
        self.events: List[SimScaleEvent] = []
        self.extra_devices_during_scale = 0
        # skew-aware expert rebalancing, sim side (DESIGN.md §10): the
        # SAME RebalancePolicy the engine runs decides over a synthesized
        # Zipf routing histogram and applies its actions to a sim-owned
        # ExpertPageTable (stage + commit in one quantum — the byte cost
        # of a rebalance pass is negligible at model scale), so allocator
        # behaviour (replica sets, host tier, pool conservation, min-move
        # over replicas at scale events) is testable with no devices.
        self.rebalance_policy = rebalance
        if rebalance is not None and routing_skew is None:
            routing_skew = 1.2      # rebalancing needs telemetry to read
        n_moe = mcfg.num_layers - mcfg.first_k_dense
        self.routing = (SimRoutingModel(n_moe, mcfg.num_experts,
                                        skew=routing_skew, seed=routing_seed)
                        if routing_skew is not None and mcfg.num_experts
                        else None)
        if expert_slot_slack is None:
            expert_slot_slack = 1 if rebalance is not None else 0
        self.expert_slot_slack = expert_slot_slack
        self.expert_pages: Optional[ExpertPageTable] = None
        if expert_mode == "pooled" and mcfg.num_experts:
            self.expert_pages = ExpertPageTable(
                n_moe, mcfg.num_experts,
                host_pool_pages=expert_host_pages)
            self.expert_pages.initial_place(self.current_config())
        self.rebalance_events: List[dict] = []
        self._expert_host_pages = expert_host_pages
        # scale-to-zero (DESIGN.md §12): parked = whole model lives in the
        # pinned-host tier, ndev == 0, queue accrues, nothing serves until
        # a SimUnparkTask commits.  park_events: {"t", "kind", ["wall_s"]}.
        self.parked = False
        self.park_events: List[dict] = []
        # one expert page across the three banks: bf16 (PerfModel's bpe) or
        # int8 + three per-page f32 scales when the pool is quantized
        ebpe = 1 if expert_dtype == "int8" else 2
        escale = 3 * 4 if expert_dtype == "int8" else 0
        self._expert_page_bytes = (3 * mcfg.d_model * mcfg.moe_d_ff * ebpe
                                   + escale)

    # ------------------------------------------------------------- scaling
    def start_scale(self, target: ElasticConfig) -> SimScalingTask:
        """Open a scaling task toward ``target`` (driver.ServingBackend).
        Byte counts come from the real planner; durations from the cost
        model.  The task commits when modelled time reaches ``t_ready``."""
        assert self.scale is None, "scaling already in flight"
        assert not self.parked, "parked: use start_unpark, not start_scale"
        old = ElasticConfig(self.ndev // self.tp, self.tp,
                            tuple(range(self.ndev)))
        if self.strategy in ("extravagant", "horizontal"):
            self.extra_devices_during_scale = target.ndev
        down = target.ndev < self.ndev
        mig_blocks = 0
        if down and self.kv_mode == "paged" \
                and self.scaledown_mode == "migrate":
            mig_blocks = projected_migration_blocks(
                self.used_blocks(), old.dp, target.dp)
        mig_bytes = mig_blocks * self.perf._kv_block_bytes
        cost = transition_cost(self.mcfg, self.tp, old, target,
                               strategy=self.strategy, hw=self.hw,
                               preinit=self.preinit,
                               kv_seq_len=self.perf.kv_seq_len,
                               expert_mode=self.expert_mode,
                               # cost from the sim's live placement: replica
                               # keeps are zero-copy, host-tier experts
                               # stream H2D instead of P2P (DESIGN.md §10)
                               page_table=self.expert_pages,
                               staging=self.staging_mode,
                               kv_migration_bytes=mig_bytes,
                               kv_dtype=self.kv_dtype,
                               expert_dtype=self.expert_dtype)
        t_ready = self.t + cost.scale_time_s
        if down and self.scaledown_mode == "drain" and self.running:
            # legacy drain: the doomed share of in-flight requests (the
            # youngest, mirroring eviction order) must run to completion
            # before their devices release — overlapping the staging window
            n_doomed = math.ceil(len(self.running)
                                 * (old.dp - target.dp) / old.dp)
            doomed = sorted(self.running, key=lambda e: -e[1])[:n_doomed]
            if doomed:
                # the doomed sequences' finishes are about to be shifted by
                # the modelled decode stall (below) — drain must wait for
                # the SHIFTED completion, or devices release early
                t_ready = max(t_ready,
                              max(f for f, _, _, _ in doomed)
                              + cost.decode_stall_s)
        event = SimScaleEvent(
            t_command=self.t, t_ready=t_ready,
            downtime_until=self.t + cost.downtime_s if cost.downtime_s else 0,
            old_ndev=self.ndev, new_ndev=target.ndev, cost=cost,
            migrated_blocks=mig_blocks, migration_bytes=mig_bytes,
            **latency_percentiles(self.finished))
        self.events.append(event)
        if cost.downtime_s:
            # in-flight requests are stalled for the whole outage (§3 L2)
            self.running = [(f + cost.scale_time_s, rid, r,
                             s + cost.scale_time_s)
                            for f, rid, r, s in self.running]
            heapq.heapify(self.running)
            if self.prefill_chunk is not None:
                for _, rid, _, _ in self.running:
                    self._stall_gaps.setdefault(rid, []).append(
                        cost.scale_time_s)
        elif cost.decode_stall_s:
            # decode stalls while staging contends for HBM/links: serial
            # staging blocks a serve-loop quantum per increment (the whole
            # transfer time); overlapped staging only the contention share.
            # Modelled as a finish-time shift of the in-flight requests.
            self._stall_running(cost.decode_stall_s)
        self.scale = SimScalingTask(self, target, event)
        return self.scale

    # -------------------------------------------------------- scale-to-zero
    def park(self) -> None:
        """Scale to ZERO devices: the model's snapshot moves to the
        pinned-host tier and every device releases.  Legal only when fully
        drained (no running/prefilling/queued requests) and no scale event
        is in flight — the same preconditions as ``ElasticServer.park``."""
        assert self.scale is None, "cannot park during a scale event"
        assert not self.parked, "already parked"
        assert not self.running and not self._prefilling and not self.queue, \
            "park requires a drained instance"
        self.parked = True
        self.ndev = 0
        self.park_events.append({"t": self.t, "kind": "park"})
        obs.get_tracer().instant("park", cat="scale", t=self.t,
                                 tid="sim-scale")

    def start_unpark(self, target: ElasticConfig) -> SimUnparkTask:
        """Open a modelled cold start toward ``target`` — the shared
        ``unpark_transition_cost`` pricing (whole snapshot H2D at
        ``h2d_bw``, fresh KV INIT, compile hidden under the transfer in
        overlap mode) sets ``t_ready``; until then ndev stays 0 and the
        queue accrues (the cold-start wall the fleet benchmark reports)."""
        assert self.parked, "not parked"
        assert self.scale is None
        cost = unpark_transition_cost(
            self.mcfg, self.tp, target, hw=self.hw, preinit=self.preinit,
            staging=self.staging_mode, kv_seq_len=self.perf.kv_seq_len,
            kv_dtype=self.kv_dtype, expert_dtype=self.expert_dtype)
        t_ready = self.t + cost.scale_time_s
        event = SimScaleEvent(
            t_command=self.t, t_ready=t_ready,
            downtime_until=self.t + cost.downtime_s if cost.downtime_s else 0,
            old_ndev=0, new_ndev=target.ndev, cost=cost,
            **latency_percentiles(self.finished))
        self.events.append(event)
        self.park_events.append({"t": self.t, "kind": "unpark",
                                 "wall_s": cost.scale_time_s})
        self.scale = SimUnparkTask(self, target, event)
        return self.scale

    def command_scale(self, new_ndev: int) -> SimScalingTask:
        """Scripted-benchmark entry point: scale to ``new_ndev`` devices
        (extravagant/horizontal get a disjoint device range)."""
        base = self.ndev if self.strategy in ("extravagant",
                                              "horizontal") else 0
        target = ElasticConfig(new_ndev // self.tp, self.tp,
                               tuple(range(base, base + new_ndev)))
        return self.start_scale(target)

    # -------------------------------------------------------------- engine
    def _serving_capacity(self) -> Tuple[int, bool]:
        """(effective ndev, admitting_new) given any in-flight scale.
        Gating policy is the shared ``driver.admission_during_scale`` — the
        exact code the real-engine driver applies."""
        if self.scale is not None:
            self.scale.advance(self.t)        # commits at/after t_ready
        if self.scale is None:
            return self.ndev, True
        mode, admit = admission_during_scale(self.strategy)
        return (0 if mode == "none" else self.ndev), admit

    # ------------------------------------------------- paged KV occupancy
    def pool_blocks(self, ndev: Optional[int] = None) -> int:
        if self._pool_blocks_override is not None:
            return self._pool_blocks_override
        return self.perf.pool_blocks(ndev if ndev is not None else self.ndev,
                                     self.kv_frac)

    def _tokens_now(self, finish: float, req: Request, t_start: float) -> int:
        """Tokens a running request currently holds: prompt + the fraction
        of its output generated so far (decode progresses linearly between
        ``t_start`` and its estimated finish)."""
        if finish <= t_start:
            return req.prompt_len + req.output_len
        frac = min(max((self.t - t_start) / (finish - t_start), 0.0), 1.0)
        return req.prompt_len + int(req.output_len * frac)

    def used_blocks(self) -> int:
        live = sum(self.perf.blocks_for(self._tokens_now(f, r, s))
                   for f, _, r, s in self.running)
        # chunked mode: sequences mid-prefill already hold their prompt's
        # blocks (the engine allocates at admission and registers chunks as
        # they are written; serving/kv_blocks.py)
        live += sum(self.perf.blocks_for(j.total) for j in self._prefilling)
        return live

    def _preempt_for_pressure(self, pool: int) -> None:
        """Evict lowest-priority / youngest running requests until the pool
        fits (recompute mode: back to the queue front, restarted on
        re-admission).  The last running request is never evicted — an
        oversubscribed singleton must be allowed to finish."""
        while len(self.running) > 1 and self.used_blocks() > pool:
            victim = min(self.running,
                         key=lambda e: (e[2].priority, -e[2].rid))
            self.running.remove(victim)
            heapq.heapify(self.running)
            self.queue.insert(0, victim[2])
            self._itl_base.pop(victim[2].rid, None)
            self._stall_gaps.pop(victim[2].rid, None)
            self.preemptions += 1
            obs.get_tracer().instant("preempt", cat="serve", t=self.t,
                                     tid="sim", args={"rid": victim[2].rid})

    def _stall_running(self, delta: float) -> None:
        """Shift every in-flight finish by ``delta`` (a modelled decode
        stall — prefill compute or staging contention) and record the gap
        per request so synthesized token_times carry the ITL spike."""
        if delta <= 0 or not self.running:
            return
        self.running = [(f + delta, rid, r, s)
                        for f, rid, r, s in self.running]
        heapq.heapify(self.running)
        if self.prefill_chunk is not None:
            for _, rid, _, _ in self.running:
                self._stall_gaps.setdefault(rid, []).append(delta)

    def _synth_token_times(self, req: Request) -> None:
        """Reconstruct per-token wall-clock times from the modelled decode
        rate plus any recorded stall gaps, so ``metrics.iter_itls`` sees
        the same ITL surface the real engine measures."""
        base = self._itl_base.pop(req.rid, None)
        gaps = self._stall_gaps.pop(req.rid, [])
        if base is None or req.first_token_s is None:
            return
        n = max(req.output_len - 1, 0)
        deltas = [base + g for g in gaps[:n]]
        deltas += [base] * (n - len(deltas))
        times = [req.first_token_s]
        for d in deltas:
            times.append(times[-1] + d)
        req.token_times = times

    def scaling_summary(self) -> Optional[Dict[str, float]]:
        """Modelled staging-overlap metrics over completed scale events
        (mirrors ``ElasticServer.scaling_summary``; metrics.summarize)."""
        if not self.events:
            return None
        effs = [e.cost.breakdown["op_s"] / max(e.cost.scale_time_s, 1e-9)
                for e in self.events if e.cost.breakdown.get("op_s")]
        return {"staging_mode": self.staging_mode,
                "scaledown_mode": self.scaledown_mode,
                "decode_stall_s": sum(e.cost.decode_stall_s
                                      for e in self.events),
                "overlap_efficiency":
                    sum(effs) / len(effs) if effs else None,
                "migrated_blocks": sum(e.migrated_blocks
                                       for e in self.events),
                "migration_bytes": sum(e.migration_bytes
                                       for e in self.events)}

    def routing_stats(self) -> Optional[Dict[str, float]]:
        """ServingBackend parity with ``ElasticServer.routing_stats``:
        with a ``SimRoutingModel`` (``routing_skew=``) the synthesized
        Zipf histogram, key-compatible with the engine's; otherwise None
        (the driver and ``metrics.summarize`` treat None as
        telemetry-absent)."""
        if self.routing is None:
            return None
        return self.routing.stats()

    def _elm(self) -> int:
        """Compiled table width per rank (mirrors HMM._pooled_index_arrays:
        ceil(E / ndev) + slack) — the replication slot budget."""
        return (math.ceil(self.mcfg.num_experts / max(self.ndev, 1))
                + self.expert_slot_slack)

    def _drive_rebalance(self, now: float) -> None:
        """Modelled rebalance pass: the shared policy decides over the
        synthesized histogram and the actions commit on the sim-owned page
        table within the quantum (rebalance bytes are negligible next to a
        scale event, so no modelled latency) — then the histogram restarts,
        exactly like the engine's RebalanceTask commit."""
        if (self.rebalance_policy is None or self.expert_pages is None
                or self.routing is None or self.scale is not None):
            return
        actions = self.rebalance_policy.decide(
            self.routing.stats(), self.expert_pages, self.current_config(),
            now, slots_per_rank=self._elm())
        if not actions:
            return
        try:
            ops = self.expert_pages.stage_rebalance(actions)
        except MemoryError:
            return                      # pool full this pass; retry later
        self.expert_pages.commit_rebalance()
        self.routing.reset()
        kinds = [op.kind for op in ops]
        page = self._expert_page_bytes
        self.rebalance_events.append(
            {"t": now, "actions": len(ops),
             "replicated": kinds.count("replicate"),
             "demoted": kinds.count("demote"),
             "dropped": kinds.count("drop_replica"),
             "promoted": kinds.count("promote"),
             "replica_bytes": kinds.count("replicate") * page,
             "d2h_bytes": kinds.count("demote") * page})
        obs.get_tracer().instant(
            "rebalance.commit", cat="rebalance", t=now, tid="sim",
            args={"actions": len(ops)})

    def rebalance_summary(self) -> Optional[dict]:
        """Mirror of ``ElasticServer.rebalance_summary`` over the modelled
        passes (None before the first one)."""
        if not self.rebalance_events:
            return None
        evs = self.rebalance_events
        return {"passes": len(evs), "aborted": 0,
                "replicated": sum(e["replicated"] for e in evs),
                "demoted": sum(e["demoted"] for e in evs),
                "dropped": sum(e["dropped"] for e in evs),
                "promoted": sum(e["promoted"] for e in evs),
                "replica_bytes": sum(e["replica_bytes"] for e in evs),
                "d2h_bytes": sum(e["d2h_bytes"] for e in evs),
                "host_tier_bytes": (len(self.expert_pages.host)
                                    * self._expert_page_bytes
                                    if self.expert_pages else 0)}

    def kv_stats(self) -> Optional[Dict[str, float]]:
        """Block-pool stats (None in dense mode); serving/metrics.py."""
        if self.kv_mode != "paged":
            return None
        pool = self.pool_blocks()
        used = self.used_blocks()
        return {"num_blocks": pool, "used_blocks": used,
                "utilization": used / max(pool, 1),
                "preemptions": self.preemptions,
                "live_seqs": len(self.running) + len(self._prefilling),
                "block_bytes": self.perf._kv_block_bytes,
                "migrated_blocks": sum(e.migrated_blocks
                                       for e in self.events)}

    def step(self, now: float) -> List[Request]:
        """One simulation quantum at time ``now`` (driver.ServingBackend):
        admit from the queue under the shared gating policy, then complete
        any requests whose modelled finish time has passed.  Paged mode
        first resolves pool pressure by preemption, then admits by block
        occupancy instead of the fixed ``max_batch``."""
        self.t = now
        done: List[Request] = []
        ndev, admit = self._serving_capacity()
        tr = obs.get_tracer()
        if self.routing is not None and ndev > 0 and self.running:
            # synthesized router telemetry: one sampled tick per quantum,
            # one token per running decode (matches the real sampler's
            # batch-token granularity)
            self.routing.observe(len(self.running))
        self._drive_rebalance(now)
        if tr.enabled and ndev > 0 and self.running:
            # one modelled decode step per quantum — explicit sim-time span
            # at the roofline-modelled duration, so an overlap trace reads
            # the same on both backends (DESIGN.md §9)
            tr.complete(
                "decode.tick", now,
                now + self.perf.decode_step_s(len(self.running), ndev),
                cat="serve", tid="sim",
                args={"batch": len(self.running), "ndev": ndev})
        if ndev > 0:
            slot_cap = int(self.perf.max_batch_per_dev * ndev * self.kv_frac)
            if self.kv_mode == "paged":
                pool = self.pool_blocks(ndev)
                self._preempt_for_pressure(pool)
                used = self.used_blocks()
            # admit from queue
            while admit and self.queue \
                    and len(self.running) + len(self._prefilling) < slot_cap:
                req = self.queue[0]
                if self.kv_mode == "paged":
                    need = self.perf.blocks_for(req.prompt_len + 1)
                    if used + need > pool:
                        break
                    used += need
                elif (len(self.running) + len(self._prefilling)
                      >= self.perf.max_batch(ndev, self.kv_frac)):
                    break
                self.queue.pop(0)
                tr.instant("req.admit", cat="req", t=self.t, tid="sim",
                           args={"rid": req.rid})
                if self.scheduler is not None:
                    # chunked: prefill advances chunk-by-chunk below; the
                    # first token only lands when the last chunk does
                    self._prefilling.append(PrefillJob(
                        slot=req.rid, rid=req.rid, pos=0,
                        total=req.prompt_len))
                    self._prefill_reqs[req.rid] = req
                    continue
                t_first = self.t + self.perf.prefill_s(req.prompt_len, ndev)
                if req.first_token_s is None:
                    req.first_token_s = t_first
                    tr.instant("req.first_token", cat="req", t=t_first,
                               tid="sim", args={"rid": req.rid})
                base = self.perf.decode_step_s(
                    max(len(self.running) + 1, 1), ndev)
                if self.prefill_chunk == 0:
                    # monolithic prefill blocks the serve loop: every
                    # running decode stalls for the whole prompt — the
                    # long-tail ITL spike chunked prefill bounds
                    self._stall_running(t_first - self.t)
                    self._itl_base[req.rid] = base
                heapq.heappush(self.running,
                               (t_first + req.output_len * base,
                                req.rid, req, t_first))
            # chunked prefill: run this quantum's token-budget plan (the
            # SAME scheduler.plan the engine tick uses).  Each chunk's
            # compute stalls the running decodes for one chunk — not a
            # whole prompt — and a job landing its final chunk starts
            # decoding immediately (engine._run_prefill_chunks cadence).
            if self.scheduler is not None and self._prefilling:
                plans = self.scheduler.plan(self._prefilling)
                jobs = {j.rid: j for j in self._prefilling}
                self._stall_running(sum(self.perf.prefill_s(p.take, ndev)
                                        for p in plans))
                done_t = self.t
                for plan in plans:
                    done_t += self.perf.prefill_s(plan.take, ndev)
                    job = jobs[plan.rid]
                    job.pos = plan.start + plan.take
                    if plan.final:
                        self._prefilling.remove(job)
                        req = self._prefill_reqs.pop(plan.rid)
                        if req.first_token_s is None:
                            req.first_token_s = done_t
                            tr.instant("req.first_token", cat="req",
                                       t=done_t, tid="sim",
                                       args={"rid": req.rid})
                        base = self.perf.decode_step_s(
                            max(len(self.running) + 1, 1), ndev)
                        self._itl_base[req.rid] = base
                        heapq.heappush(
                            self.running,
                            (done_t + req.output_len * base,
                             req.rid, req, done_t))
            # complete requests
            while self.running and self.running[0][0] <= self.t:
                _, _, req, _ = heapq.heappop(self.running)
                req.finish_s = self.t
                tr.instant("req.finish", cat="req", t=self.t, tid="sim",
                           args={"rid": req.rid})
                if self.prefill_chunk is not None:
                    self._synth_token_times(req)
                done.append(req)
        self.finished.extend(done)
        return done

    def run(self, requests: List[Request], until: float, dt: float = 0.05):
        """Advance to ``until``; ``requests`` are *added* to the pending set
        (arrivals persist across calls)."""
        if requests:
            self._pending = merge_arrivals(self._pending, self._pi, requests)
            self._pi = 0
        while self.t < until:
            while self._pi < len(self._pending) \
                    and self._pending[self._pi].arrival_s <= self.t:
                self.submit(self._pending[self._pi])
                self._pi += 1
            t = self.t
            self.step(t)
            self.t = t + dt
        return self.finished

    # --------------------------------------------- ServingBackend protocol
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def queue_depth(self) -> int:
        return len(self.queue)

    def utilization(self) -> float:
        if self.kv_mode == "paged":
            return self.used_blocks() / max(self.pool_blocks(), 1)
        cap = self.perf.max_batch(self.ndev, self.kv_frac)
        return (len(self.running) + len(self._prefilling)) / max(cap, 1)

    def current_config(self) -> ElasticConfig:
        return ElasticConfig(self.ndev // self.tp, self.tp,
                             tuple(range(self.ndev)))

    def prewarm(self, target: ElasticConfig) -> None:
        pass  # modelled: pre-init cost is already a plan_cost flag

    def capacity(self, cfg: ElasticConfig) -> int:
        if self.kv_mode == "paged":
            # conservative: full-length sequences; the real paged win shows
            # up in admission (occupancy-based) rather than this bound
            per_seq = self.perf.blocks_for(self.perf.kv_seq_len)
            return max(1, min(
                int(self.perf.max_batch_per_dev * cfg.ndev * self.kv_frac),
                self.pool_blocks(cfg.ndev) // per_seq))
        return self.perf.max_batch(cfg.ndev, self.kv_frac)

    def throughput(self, t0: float, t1: float) -> float:
        n = sum(1 for r in self.finished
                if r.finish_s is not None and t0 <= r.finish_s < t1)
        return n / max(t1 - t0, 1e-9)
