"""Skew-aware expert rebalance policy (DESIGN.md §10).

Pure host-side decision logic, shared — like ``serving/scheduler.py`` — by
the real serving stack (``ElasticServer`` drives it through an HMM
rebalance session) and the analytic simulator (``serving/simulator.py``
applies it to a sim-owned page table), so ``ClusterDriver`` projections and
allocator tests exercise exactly the policy the engine runs.

The policy reads the routing histogram (``routing_stats()``: [L_moe, E]
token counts, PR 7) and emits ``ExpertPageTable.stage_rebalance`` actions:

* **replicate** a hot expert (per-layer share > ``hot_factor``/E) onto the
  device currently carrying the least routed load, up to ``max_replicas``
  extra copies — bounded by the compiled table-width slack;
* **demote** a cold expert (share < ``cold_factor``/E) into the pinned-host
  tier — its device primary keeps serving, the host copy pre-pays the
  H2D stream so the expert costs zero P2P at the next scale event;
* **drop_replica** / **promote** undo the above when an expert's share
  falls back below / climbs back above average.

Hysteresis is structural: with ``hot_factor > 1 > cold_factor`` an expert
must cross *different* thresholds to gain and to lose a copy (gain at
``hot_factor``/E, lose at 1/E; demote at ``cold_factor``/E, promote at
1/E), so shares hovering near either threshold cannot flap.  ``cooldown_s``
adds a time floor between passes, and ``min_samples`` keeps the policy from
acting on a histogram too young to trust.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class RebalancePolicy:
    """Decides rebalance actions from a routing histogram.

    Thresholds are factors of the uniform share 1/E (per layer):
    ``hot_factor=2.0`` means "twice the fair share".  ``max_actions``
    bounds one pass so a single decision never stages an unbounded
    transfer batch."""
    hot_factor: float = 2.0
    cold_factor: float = 0.25
    min_samples: int = 4
    cooldown_s: float = 0.0
    max_replicas: int = 1
    max_actions: int = 8
    _last_t: Optional[float] = dataclasses.field(default=None, repr=False)

    def decide(self, stats: Optional[dict], page_table, cfg, now: float,
               slots_per_rank: Optional[int] = None) -> List[Tuple]:
        """Actions for ``ExpertPageTable.stage_rebalance`` (possibly empty).

        ``stats``: ``routing_stats()`` dict (``counts`` [L_moe, E] aligned
        with the page table's layer indices).  ``slots_per_rank``: compiled
        table width per rank; replications that would overflow any rank's
        slot budget are skipped (the table-width slack is the hard bound).
        An accepted pass records ``now`` for the cooldown clock."""
        if stats is None or stats.get("samples", 0) < self.min_samples:
            return []
        if self._last_t is not None and self.cooldown_s > 0 \
                and now - self._last_t < self.cooldown_s:
            return []
        counts = np.asarray(stats["counts"], np.float64)
        L, E = counts.shape
        ndev = cfg.ndev
        if ndev < 2:
            return []          # nowhere to replicate, nothing to balance
        fair = 1.0 / E
        # per-rank copy counts (primary + replicas) per layer, for the
        # slot-budget feasibility check
        copies: Dict[Tuple[int, int], int] = {}
        for (l, e), ref in page_table.active.items():
            r = cfg.slot(ref.device)
            copies[(l, r)] = copies.get((l, r), 0) + 1
        for (l, e), refs in page_table.replicas.items():
            for ref in refs:
                r = cfg.slot(ref.device)
                copies[(l, r)] = copies.get((l, r), 0) + 1
        # routed load per rank per layer under the CURRENT placement — the
        # least-loaded rank is the replication target
        rank_load = np.zeros((L, ndev), np.float64)
        for (l, e), ref in page_table.active.items():
            if l < L:
                rank_load[l, cfg.slot(ref.device)] += counts[l, e]

        actions: List[Tuple] = []

        def room(l: int, r: int) -> bool:
            return (slots_per_rank is None
                    or copies.get((l, r), 0) < slots_per_rank)

        for l in range(L):
            tot = max(counts[l].sum(), 1.0)
            share = counts[l] / tot
            # hottest-first so the bounded action budget goes to the worst
            # offenders; coldest-first for demotions likewise
            for e in np.argsort(-share):
                e = int(e)
                if len(actions) >= self.max_actions:
                    break
                key = (l, e)
                nrep = page_table.replica_count(l, e)
                holders = {page_table.active[key].device}
                holders.update(ref.device
                               for ref in page_table.replicas.get(key, ()))
                if share[e] > self.hot_factor * fair:
                    if key in page_table.host:
                        actions.append(("promote", l, e))   # hot again
                        continue
                    if nrep >= self.max_replicas:
                        continue
                    cand = [r for r in range(ndev)
                            if cfg.devices[r] not in holders and room(l, r)]
                    if cand:
                        r = min(cand, key=lambda r: (rank_load[l, r], r))
                        copies[(l, r)] = copies.get((l, r), 0) + 1
                        actions.append(
                            ("replicate", l, e, cfg.devices[r]))
                elif share[e] < fair and nrep > 0:
                    # fell back below average: retire the newest replica
                    ref = page_table.replicas[key][-1]
                    copies[(l, cfg.slot(ref.device))] -= 1
                    actions.append(("drop_replica", l, e, ref.device))
                elif share[e] < self.cold_factor * fair \
                        and key not in page_table.host and nrep == 0:
                    actions.append(("demote", l, e))
                elif share[e] > fair and key in page_table.host:
                    actions.append(("promote", l, e))
            if len(actions) >= self.max_actions:
                break
        if actions:
            self._last_t = now
        return actions[: self.max_actions]


def max_rank_load(counts: np.ndarray, edest: np.ndarray,
                  ndev: int) -> float:
    """Layer-averaged max per-rank routed-token share under a serving
    assignment — the imbalance metric the rebalancer minimizes and
    ``benchmarks/expert_skew.py`` reports.  ``counts`` [L, E] token counts,
    ``edest`` [L, E] serving rank per expert."""
    L, E = counts.shape
    out = 0.0
    for l in range(L):
        tot = max(float(counts[l].sum()), 1.0)
        loads = np.zeros(ndev, np.float64)
        for e in range(E):
            loads[int(edest[l, e])] += counts[l, e]
        out += loads.max() / tot
    return out / max(L, 1)
