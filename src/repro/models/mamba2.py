"""Mamba2 / SSD (state-space duality) block.  [arXiv:2405.21060]

TPU adaptation note (DESIGN.md §2): the CUDA reference implements SSD with a
fused Triton kernel over (chunk-diagonal matmul + inter-chunk recurrence).
Here the *chunked* formulation is kept — it is exactly the matmul-dominant
decomposition the MXU wants — expressed as a `lax.scan` over chunks with
dense intra-chunk einsums; the intra-chunk part is also provided as a Pallas
kernel (`kernels/ssd_scan.py`).  Decode is the O(1) recurrent update.

Shapes: x [B,S,D]; d_inner = expand*D; heads H = d_inner/head_dim (P);
state N = ssm_state; single B/C group (G=1).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import apply_norm, linear, linear_init, norm_init


def mamba2_init(rng, cfg, dtype):
    D = cfg.d_model
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * N
    ks = jax.random.split(rng, 4)
    return {
        "in_proj": linear_init(ks[0], D, 2 * di + 2 * N + H, dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), dtype)
                  * (1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "norm": norm_init(di, "rmsnorm", dtype),
        "out_proj": linear_init(ks[2], di, D, dtype),
    }


def _split_in_proj(cfg, h):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = h[..., :di]
    xBC = h[..., di:di + di + 2 * N]
    dt = h[..., di + di + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, width K: xBC [B,S,C], w [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + xBC.shape[1], :] * w[i][None, None] for i in range(K))
    return jax.nn.silu(y + b[None, None])


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk, init_state=None):
    """Chunked SSD scan.

    xh [B,S,H,P], dt [B,S,H] (>0), A [H] (<0), Bm/Cm [B,S,N].
    Returns (y [B,S,H,P], final_state [B,H,N,P]).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"S={S} not divisible by chunk={Q}"
    nc = S // Q

    def r(t):  # [B,S,...] -> [nc, B, Q, ...]
        return t.reshape(Bsz, nc, Q, *t.shape[2:]).swapaxes(0, 1)

    xh_c, dt_c, B_c, C_c = r(xh), r(dt.astype(jnp.float32)), r(Bm), r(Cm)
    a_c = dt_c * A[None, None]                        # [nc,B,Q,H] log-decays

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, N, P), jnp.float32)

    def body(state, inp):
        xq, dq, aq, bq, cq = inp                      # [B,Q,...]
        acs = jnp.cumsum(aq, axis=1)                  # [B,Q,H]
        # ---- off-diagonal: contribution of the carried state
        decay_in = jnp.exp(acs)                       # decay from chunk start
        y_off = jnp.einsum("bqn,bhnp,bqh->bqhp", cq, state, decay_in,
                           preferred_element_type=jnp.float32)
        # ---- intra-chunk (quadratic in Q — the MXU-friendly part)
        seg = acs[:, :, None, :] - acs[:, None, :, :]       # [B,Q,Q,H]
        iq = jnp.arange(Q)
        causal = (iq[:, None] >= iq[None, :])[None, :, :, None]
        # mask in log-space BEFORE exp: masked entries have seg > 0 and would
        # overflow, poisoning gradients through the 0*inf product
        L = jnp.exp(jnp.where(causal, seg, -jnp.inf))
        scores = jnp.einsum("bqn,bkn->bqk", cq, bq,
                            preferred_element_type=jnp.float32)
        M = scores[..., None] * L * dq[:, None]             # [B,Q,K,H]
        y_diag = jnp.einsum("bqkh,bkhp->bqhp", M, xq.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
        # ---- new carried state
        decay_out = jnp.exp(acs[:, -1:, :] - acs)           # [B,Q,H]
        state_new = jnp.einsum("bkn,bkhp,bkh->bhnp", bq, xq.astype(jnp.float32),
                               decay_out * dq,
                               preferred_element_type=jnp.float32)
        state = state * jnp.exp(acs[:, -1])[:, :, None, None] + state_new
        return state, (y_off + y_diag)

    state, ys = jax.lax.scan(body, init_state, (xh_c, dt_c, a_c, B_c, C_c))
    y = ys.swapaxes(0, 1).reshape(Bsz, S, H, P)
    return y, state


def mamba2_forward(cfg, p, x, init_cache=None, return_cache=False):
    """Full-sequence SSD.  x [B,S,D] -> y [B,S,D] (and optionally the decode
    cache {'conv': [B,K-1,convdim], 'state': [B,H,N,P]})."""
    Bsz, S, D = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = linear(p["in_proj"], x)
    z, xBC_raw, dt = _split_in_proj(cfg, h)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xh = xBC[..., :di].reshape(Bsz, S, H, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y, state = _ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + (p["D_skip"][None, None, :, None]
             * xh.astype(jnp.float32))
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm")
    out = linear(p["out_proj"], y)
    if not return_cache:
        return out
    K = cfg.ssm_conv
    conv_tail = xBC_raw[:, -(K - 1):, :] if S >= K - 1 else jnp.pad(
        xBC_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, {"conv": conv_tail, "state": state}


def mamba2_decode(cfg, p, x, cache):
    """Single-token recurrent update.  x [B,1,D]."""
    Bsz = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    K = cfg.ssm_conv
    h = linear(p["in_proj"], x)
    z, xBC_new, dt = _split_in_proj(cfg, h)

    conv_buf = jnp.concatenate([cache["conv"], xBC_new], axis=1)  # [B,K,C]
    xBC = jnp.einsum("bkc,kc->bc", conv_buf, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(xBC)[:, None, :]
    new_conv = conv_buf[:, 1:, :]

    xh = xBC[..., :di].reshape(Bsz, H, P)
    Bm = xBC[:, 0, di:di + N]
    Cm = xBC[:, 0, di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]   # [B,H]
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None])                                       # [B,H]
    state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bn,bhp,bh->bhnp", Bm, xh.astype(jnp.float32), dt,
        preferred_element_type=jnp.float32)
    y = jnp.einsum("bn,bhnp->bhp", Cm, state,
                   preferred_element_type=jnp.float32)
    y = y + p["D_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, 1, di).astype(x.dtype)
    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm")
    return linear(p["out_proj"], y), {"conv": new_conv, "state": state}
