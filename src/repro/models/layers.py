"""Core transformer layers as pure functions over parameter pytrees.

Conventions
-----------
* params are nested dicts of jnp arrays; init fns take an rng and return them.
* activations: [B, S, D]; attention heads materialized as [B, S, H, hd].
* KV caches: {'k': [B, S_max, KVH, hd], 'v': ...}; the valid length / write
  index is passed explicitly (the serving engine owns it).
* all matmuls accumulate in float32 (``preferred_element_type``) — bf16 params
  with f32 accumulation is the TPU-native convention.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------- utils

def dot(x, w):
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


def linear_init(rng, d_in, d_out, dtype, bias=False, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(rng, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = dot(x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


# --------------------------------------------------------------------- norms

def norm_init(d, norm_type, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, norm_type, eps=1e-5):
    xf = x.astype(jnp.float32)
    if norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------- rope

def rope_tables(positions, rot_dim, base=10000.0):
    """positions [..., S] -> cos,sin [..., S, rot_dim/2]."""
    inv = 1.0 / (base ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, rot_dim):
    """x [B,S,H,hd]; rotary applied to the first ``rot_dim`` dims (pairwise)."""
    if rot_dim == 0:
        return x
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1 = xr[..., 0::2]
    x2 = xr[..., 1::2]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    r1 = x1 * c - x2 * s
    r2 = x1 * s + x2 * c
    xr = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([xr, xp], axis=-1).astype(x.dtype) if rot_dim < x.shape[-1] else xr.astype(x.dtype)


# ----------------------------------------------------------------- attention

def _mha_block(q, k, v, *, q_pos, kv_pos, causal, window, kv_valid_len):
    """One dense attention block.

    q [B,Sq,KVH,G,hd], k/v [B,Skv,KVH,hd]; positions are int arrays [B,Sq]/[B,Skv].
    Returns [B,Sq,KVH,G,hd].
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqkgh,btkh->bkgqt", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.ones(scores.shape[-2:], bool)[None, None, None]
    dq = q_pos[:, None, None, :, None]
    dk = kv_pos[:, None, None, None, :]
    if causal:
        mask = mask & (dk <= dq)
    if window is not None:
        mask = mask & (dq - dk < window)
    if kv_valid_len is not None:
        mask = mask & (dk < kv_valid_len[:, None, None, None, None])
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqt,btkh->bqkgh", p, v,
                      preferred_element_type=jnp.float32).astype(v.dtype)


def mha(q, k, v, *, q_pos, kv_pos, causal=True, window=None,
        kv_valid_len=None, q_chunk=1024):
    """Grouped-query attention with q-chunking (keeps the [Sq,Skv] score
    matrix bounded — the memory-roofline-friendly formulation).

    q [B,Sq,H,hd], k/v [B,Skv,KVH,hd] -> [B,Sq,H,hd]
    """
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, hd)
    if Sq <= q_chunk:
        out = _mha_block(qg, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
                         window=window, kv_valid_len=kv_valid_len)
        return out.reshape(B, Sq, H, hd)

    n = Sq // q_chunk
    assert Sq % q_chunk == 0, f"Sq={Sq} not divisible by q_chunk={q_chunk}"
    qs = qg.reshape(B, n, q_chunk, KVH, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ps = q_pos.reshape(B, n, q_chunk).transpose(1, 0, 2)

    def body(_, qc_pc):
        qc, pc = qc_pc
        o = _mha_block(qc, k, v, q_pos=pc, kv_pos=kv_pos, causal=causal,
                       window=window, kv_valid_len=kv_valid_len)
        return None, o

    _, outs = jax.lax.scan(body, None, (qs, ps))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KVH, G, hd)
    return out.reshape(B, Sq, H, hd)


def attention_init(rng, cfg, dtype):
    D = cfg.d_model
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    return {
        "q": linear_init(ks[0], D, H * hd, dtype, bias=cfg.qkv_bias),
        "k": linear_init(ks[1], D, KVH * hd, dtype, bias=cfg.qkv_bias),
        "v": linear_init(ks[2], D, KVH * hd, dtype, bias=cfg.qkv_bias),
        "o": linear_init(ks[3], H * hd, D, dtype),
    }


def attention_apply(cfg, p, x, positions, *, cache=None, write_pos=None,
                    kv_valid_len=None, kv_x=None, causal=None, window=None,
                    rope=True):
    """Self- or cross-attention with optional KV cache.

    * forward/prefill: cache=None -> uses computed k/v; returns (y, (k, v)).
    * decode: cache=(k_cache, v_cache), write_pos [B] int32 -> writes the new
      kv of each sequence at its own slot and attends over the cache;
      returns (y, cache').
    * cross-attention: kv_x = encoder states (no rope on kv, not causal).
    """
    B, Sq, D = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    causal = cfg.causal if causal is None else causal
    window = cfg.attn_window if window is None else window

    q = linear(p["q"], x).reshape(B, Sq, H, hd)
    src = x if kv_x is None else kv_x
    k = linear(p["k"], src).reshape(B, src.shape[1], KVH, hd)
    v = linear(p["v"], src).reshape(B, src.shape[1], KVH, hd)

    rot_dim = int(cfg.resolved_head_dim * cfg.rope_fraction) // 2 * 2
    if rope and rot_dim and kv_x is None:
        cos_q, sin_q = rope_tables(positions, rot_dim)
        q = apply_rope(q, cos_q, sin_q, rot_dim)
        k = apply_rope(k, cos_q, sin_q, rot_dim)

    if cache is not None:
        k_cache, v_cache = cache
        if write_pos is not None:
            b_idx = jnp.arange(B)
            k_cache = k_cache.at[b_idx, write_pos].set(
                k[:, 0].astype(k_cache.dtype), mode="drop")
            v_cache = v_cache.at[b_idx, write_pos].set(
                v[:, 0].astype(v_cache.dtype), mode="drop")
        k, v = k_cache, v_cache
        kv_pos = jnp.broadcast_to(jnp.arange(k.shape[1])[None], (B, k.shape[1]))
        new_cache = (k_cache, v_cache)
    else:
        kv_pos = (positions if kv_x is None else
                  jnp.broadcast_to(jnp.arange(src.shape[1])[None],
                                   (B, src.shape[1])))
        new_cache = (k, v)

    y = mha(q, k, v, q_pos=positions, kv_pos=kv_pos, causal=causal,
            window=window, kv_valid_len=kv_valid_len)
    return linear(p["o"], y.reshape(B, Sq, H * hd)), new_cache


def paged_attention_apply(cfg, p, x, positions, *, cache, block_tables,
                          write_block, lengths):
    """Decode-step attention over the paged KV pool (serving/kv_blocks.py).

    x [B,1,D]; ``cache`` = this layer's pool leaves {'k','v':
    [NB,bs,KVH,hd]} — plus per-token f32 scale pools {'k_scale','v_scale':
    [NB,bs]} when the pool is int8 (DESIGN.md §11); block_tables [B,MB];
    write_block [B] = pool row receiving this step's k/v (the engine
    guarantees it is uniquely owned — CoW happened before the step; entries
    == NB mark inactive slots and are dropped); lengths [B] = tokens already
    cached (the new token lands at offset ``lengths % bs``).  On a quantized
    pool the new token row is quantized at write time and attention runs
    through the fused-dequant kernel.  Returns (y [B,1,D], cache').
    """
    from repro.kernels import ops

    B, _, D = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    bs = cache["k"].shape[1]

    q = linear(p["q"], x).reshape(B, 1, H, hd)
    k = linear(p["k"], x).reshape(B, 1, KVH, hd)
    v = linear(p["v"], x).reshape(B, 1, KVH, hd)
    rot_dim = int(cfg.resolved_head_dim * cfg.rope_fraction) // 2 * 2
    if rot_dim:
        cos, sin = rope_tables(positions, rot_dim)
        q = apply_rope(q, cos, sin, rot_dim)
        k = apply_rope(k, cos, sin, rot_dim)

    off = lengths % bs
    quant = "k_scale" in cache
    if quant:
        from repro.kernels.quant import quantize_rows
        cache = dict(cache)
        for name, new in (("k", k), ("v", v)):
            qr, s = quantize_rows(new[:, 0], (-2, -1))   # [B,KVH,hd] rows
            cache[name] = cache[name].at[write_block, off].set(
                qr, mode="drop")
            cache[name + "_scale"] = cache[name + "_scale"].at[
                write_block, off].set(s, mode="drop")
    else:
        cache = {
            "k": cache["k"].at[write_block, off].set(
                k[:, 0].astype(cache["k"].dtype), mode="drop"),
            "v": cache["v"].at[write_block, off].set(
                v[:, 0].astype(cache["v"].dtype), mode="drop")}
    # table padding holds the NB sentinel (never a valid pool row); active
    # sequences only dereference owned entries (< lengths), but inactive
    # slots stream their padding — clamp so the gather stays in-bounds on
    # kernels that index the pool directly (their output is discarded)
    NB = cache["k"].shape[0]
    bt = jnp.minimum(block_tables, NB - 1)
    if quant:
        o = ops.quant_block_paged_decode_attention(
            q[:, 0], cache["k"], cache["k_scale"], cache["v"],
            cache["v_scale"], bt, lengths + 1)
    else:
        o = ops.block_paged_decode_attention(q[:, 0], cache["k"], cache["v"],
                                             bt, lengths + 1)
    y = linear(p["o"], o.reshape(B, 1, H * hd))
    return y, cache


def paged_chunk_attention_apply(cfg, p, x, positions, *, cache, block_tables,
                                chunk_block_ids, ctx_len, q_len):
    """Chunked-prefill attention over the paged KV pool (one sequence).

    x [1,C,D] is one prefill chunk — the last ``q_len`` (<= C) of the
    sequence's first ``ctx_len`` tokens; ``positions`` [1,C] are their
    absolute positions.  ``cache`` = this layer's pool leaves as in
    :func:`paged_attention_apply`.  The chunk's k/v are scattered into the
    pool rows ``chunk_block_ids`` [C/bs] first (``NB`` marks padding beyond
    the prompt and CoW-shared prefix blocks — those writes drop; quantized
    pools quantize per token and scatter the scales alongside), then the
    chunk attends causally over the whole context through ``block_tables``
    [1,MB] via the mixed prefill/decode kernel.  Returns (y, cache').
    """
    from repro.kernels import ops

    B, C, D = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    bs = cache["k"].shape[1]

    q = linear(p["q"], x).reshape(B, C, H, hd)
    k = linear(p["k"], x).reshape(B, C, KVH, hd)
    v = linear(p["v"], x).reshape(B, C, KVH, hd)
    rot_dim = int(cfg.resolved_head_dim * cfg.rope_fraction) // 2 * 2
    if rot_dim:
        cos, sin = rope_tables(positions, rot_dim)
        q = apply_rope(q, cos, sin, rot_dim)
        k = apply_rope(k, cos, sin, rot_dim)

    quant = "k_scale" in cache
    if quant:
        from repro.kernels.quant import quantize_rows
        cache = dict(cache)
        for name, new in (("k", k), ("v", v)):
            qr, s = quantize_rows(new[0].reshape(C // bs, bs, KVH, hd),
                                  (-2, -1))
            cache[name] = cache[name].at[chunk_block_ids].set(
                qr, mode="drop")
            cache[name + "_scale"] = cache[name + "_scale"].at[
                chunk_block_ids].set(s, mode="drop")
    else:
        cache = {
            "k": cache["k"].at[chunk_block_ids].set(
                k[0].reshape(C // bs, bs, KVH, hd).astype(cache["k"].dtype),
                mode="drop"),
            "v": cache["v"].at[chunk_block_ids].set(
                v[0].reshape(C // bs, bs, KVH, hd).astype(cache["v"].dtype),
                mode="drop")}
    NB = cache["k"].shape[0]
    bt = jnp.minimum(block_tables, NB - 1)
    ctx1, qlen1 = jnp.reshape(ctx_len, (1,)), jnp.reshape(q_len, (1,))
    if quant:
        o = ops.quant_mixed_block_paged_attention(
            q, cache["k"], cache["k_scale"], cache["v"], cache["v_scale"],
            bt, ctx1, qlen1)
    else:
        o = ops.mixed_block_paged_attention(q, cache["k"], cache["v"], bt,
                                            ctx1, qlen1)
    y = linear(p["o"], o.reshape(B, C, H * hd))
    return y, cache


def chunk_attention_apply(cfg, p, x, positions, *, k_row, v_row, start):
    """Chunked-prefill attention over a slot-contiguous dense cache row.

    x [1,C,D] is one prefill chunk at absolute positions ``positions``
    [1,C] (= start..start+C-1); k_row/v_row [1,S_max,KVH,hd] is the slot's
    cache row.  The chunk's k/v are written at [start, start+C) first, then
    the chunk attends causally over the row — position masking keeps stale
    rows beyond each query's position inert, exactly as monolithic prefill
    masks its padding.  Returns (y, (k_row', v_row')).
    """
    B, C, D = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    S_max = k_row.shape[1]

    q = linear(p["q"], x).reshape(B, C, H, hd)
    k = linear(p["k"], x).reshape(B, C, KVH, hd)
    v = linear(p["v"], x).reshape(B, C, KVH, hd)
    rot_dim = int(cfg.resolved_head_dim * cfg.rope_fraction) // 2 * 2
    if rot_dim:
        cos, sin = rope_tables(positions, rot_dim)
        q = apply_rope(q, cos, sin, rot_dim)
        k = apply_rope(k, cos, sin, rot_dim)

    k_row = jax.lax.dynamic_update_slice(k_row, k.astype(k_row.dtype),
                                         (0, start, 0, 0))
    v_row = jax.lax.dynamic_update_slice(v_row, v.astype(v_row.dtype),
                                         (0, start, 0, 0))
    kv_pos = jnp.broadcast_to(jnp.arange(S_max)[None], (B, S_max))
    y = mha(q, k_row, v_row, q_pos=positions, kv_pos=kv_pos, causal=True,
            window=cfg.attn_window)
    return linear(p["o"], y.reshape(B, C, H * hd)), (k_row, v_row)


# ----------------------------------------------------------------------- mlp

def mlp_init(rng, d_model, d_ff, dtype, gated=True):
    ks = jax.random.split(rng, 3)
    p = {"up": linear_init(ks[0], d_model, d_ff, dtype),
         "down": linear_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["gate"] = linear_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp_apply(p, x, gated=True):
    h = linear(p["up"], x)
    if gated:
        h = h * jax.nn.silu(linear(p["gate"], x))
    else:
        h = jax.nn.gelu(h)
    return linear(p["down"], h)
