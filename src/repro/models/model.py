"""Composable model definition: build train/prefill/decode functions from a
:class:`ModelConfig`.

Layer stacks are ``lax.scan``-rolled (stacked parameter pytrees) so the HLO
stays small at any depth — essential for the 80-way multi-pod dry-run compile
matrix.  Architecturally non-uniform layers are factored into separate stacks:

* moe archs with ``first_k_dense``: a small unstacked prefix + a scanned MoE
  stack,
* vlm: superblocks of ``cross_attn_every`` self-attn layers with one
  cross-attn block at the head of each superblock,
* hybrid (zamba2): groups of ``attn_every`` SSM blocks with one *shared*
  attention block (weights shared across all applications) at the head of
  each group.

Public entry points
-------------------
``init_params``, ``forward`` (training), ``loss_fn``, ``prefill``,
``decode_step``, ``init_cache``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba2 as m2
from repro.models import mla as mla_mod
from repro.models.layers import (apply_norm, attention_init, attention_apply,
                                 linear, linear_init, mlp_apply, mlp_init,
                                 norm_init)
from repro.models.moe import moe_ep, moe_init, moe_local, moe_local_pooled

Params = Dict[str, Any]


# ------------------------------------------------------------------ builders

def _block_init(rng, cfg: ModelConfig, dtype, *, moe: bool, cross: bool = False):
    ks = jax.random.split(rng, 6)
    p = {"ln1": norm_init(cfg.d_model, cfg.norm_type, dtype),
         "ln2": norm_init(cfg.d_model, cfg.norm_type, dtype)}
    if cfg.use_mla:
        p["attn"] = mla_mod.mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = attention_init(ks[0], cfg, dtype)
    if cross:
        p["xattn"] = attention_init(ks[1], cfg, dtype)
        p["lnx"] = norm_init(cfg.d_model, cfg.norm_type, dtype)
        p["xgate"] = jnp.zeros((1,), dtype)
    if moe:
        p["moe"] = moe_init(ks[2], cfg, dtype)
        if cfg.dense_residual:
            p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff, dtype,
                                cfg.mlp_gated)
    else:
        p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff, dtype, cfg.mlp_gated)
    return p


def _ssm_block_init(rng, cfg, dtype):
    return {"ln": norm_init(cfg.d_model, cfg.norm_type, dtype),
            "ssm": m2.mamba2_init(rng, cfg, dtype)}


def _stack_init(rng, n, one_init):
    return jax.vmap(one_init)(jax.random.split(rng, n))


def init_params(cfg: ModelConfig, rng, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 8)
    p: Params = {"final_norm": norm_init(cfg.d_model, cfg.norm_type, dtype)}
    if cfg.arch_type != "encoder":
        p["embed"] = (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                        dtype) * 0.02)
    p["lm_head"] = linear_init(ks[1], cfg.d_model, cfg.vocab_size, dtype)

    if cfg.arch_type == "ssm":
        p["blocks"] = _stack_init(
            ks[2], cfg.num_layers, lambda k: _ssm_block_init(k, cfg, dtype))
    elif cfg.arch_type == "hybrid":
        ng = cfg.num_layers // cfg.attn_every
        p["blocks"] = _stack_init(
            ks[2], cfg.num_layers, lambda k: _ssm_block_init(k, cfg, dtype))
        p["shared_attn"] = _block_init(ks[3], cfg, dtype, moe=False)
    elif cfg.arch_type == "vlm":
        # num_layers total = ncross cross-attn layers (one leading each
        # superblock) + the remaining self-attn layers.
        ncross = cfg.num_layers // cfg.cross_attn_every
        p["blocks"] = _stack_init(
            ks[2], cfg.num_layers - ncross,
            lambda k: _block_init(k, cfg, dtype, moe=False))
        p["cross_blocks"] = _stack_init(
            ks[3], ncross,
            lambda k: _block_init(k, cfg, dtype, moe=False, cross=True))
    elif cfg.is_moe:
        nk = cfg.first_k_dense
        if nk:
            p["dense_prefix"] = [
                _block_init(k, cfg, dtype, moe=False)
                for k in jax.random.split(ks[2], nk)]
        p["blocks"] = _stack_init(
            ks[3], cfg.num_layers - nk,
            lambda k: _block_init(k, cfg, dtype, moe=True))
    else:  # dense / encoder
        p["blocks"] = _stack_init(
            ks[2], cfg.num_layers,
            lambda k: _block_init(k, cfg, dtype, moe=False))
    return p


# -------------------------------------------------------------- block apply

def _ffn_part(cfg, bp, h, *, parallel, moe: bool, moe_capacity=None,
              moe_pool=None, return_counts=False):
    """Post-attention feed-forward (+MoE).  Returns (y, aux) — or
    (y, aux, counts [E] int32) with ``return_counts`` (routing telemetry,
    DESIGN.md §9; zeros for non-MoE layers).

    ``moe_pool``: the pooled expert weight store (``params["moe_pool"]``,
    shared across layers) when the HMM runs ``expert_mode="pooled"``; the
    per-layer ``bp["moe"]`` then carries page-table index arrays instead of
    dense [E, D, F] banks (models/moe.py).  The index arrays are the ONLY
    coupling to expert placement: the skew rebalancer (DESIGN.md §10) swaps
    them in place between ticks to re-point hot experts at byte-identical
    replicas, with no change to this forward pass or its compiled shape."""
    aux = jnp.zeros((), jnp.float32)
    counts = jnp.zeros((cfg.num_experts,), jnp.int32)
    if moe:
        if parallel is not None:
            out = moe_ep(cfg, bp["moe"], h, parallel, capacity=moe_capacity,
                         pool=moe_pool, return_counts=return_counts)
            y, aux = out[0], out[1]
            if return_counts:
                counts = out[2]
        else:
            B, S, D = h.shape
            if moe_pool is not None and "tables" in bp["moe"]:
                out = moe_local_pooled(cfg, bp["moe"], moe_pool,
                                       h.reshape(B * S, D),
                                       capacity=moe_capacity,
                                       return_counts=return_counts)
            else:
                out = moe_local(cfg, bp["moe"], h.reshape(B * S, D),
                                capacity=moe_capacity,
                                return_counts=return_counts)
            yf, aux = out[0], out[1]
            if return_counts:
                counts = out[2]
            y = yf.reshape(B, S, D)
        if cfg.dense_residual:
            y = y + mlp_apply(bp["mlp"], h, cfg.mlp_gated)
    else:
        y = mlp_apply(bp["mlp"], h, cfg.mlp_gated)
    if return_counts:
        return y, aux, counts
    return y, aux


def _attn_block(cfg, bp, x, positions, *, cache=None, write_pos=None,
                kv_valid_len=None, image_kv=None, image_x=None,
                parallel=None, moe=False, moe_capacity=None, moe_pool=None,
                collect_routing=False):
    """Generic (self-attn [+cross-attn] + ffn/moe) block.

    Returns (x', new_kv_cache, new_image_kv, aux) — plus a trailing
    per-expert routing-count vector [E] when ``collect_routing``.
    """
    h = apply_norm(bp["ln1"], x, cfg.norm_type)
    if cfg.use_mla:
        if cache is None:
            a, new_kv = mla_mod.mla_prefill(cfg, bp["attn"], h, positions)
        else:
            a, new_kv = mla_mod.mla_decode(cfg, bp["attn"], h, positions,
                                           cache, write_pos, kv_valid_len)
    else:
        a, new_kv = attention_apply(cfg, bp["attn"], h, positions,
                                    cache=cache, write_pos=write_pos,
                                    kv_valid_len=kv_valid_len)
    x = x + a
    new_image_kv = image_kv
    if "xattn" in bp:
        hx = apply_norm(bp["lnx"], x, cfg.norm_type)
        if image_kv is not None:          # decode: attend over cached image kv
            cx, _ = attention_apply(cfg, bp["xattn"], hx, positions,
                                    cache=image_kv, causal=False, rope=False)
        else:                             # prefill: compute image kv
            cx, new_image_kv = attention_apply(
                cfg, bp["xattn"], hx, positions, kv_x=image_x,
                causal=False, rope=False)
        x = x + jnp.tanh(bp["xgate"]) * cx
    h = apply_norm(bp["ln2"], x, cfg.norm_type)
    out = _ffn_part(cfg, bp, h, parallel=parallel, moe=moe,
                    moe_capacity=moe_capacity, moe_pool=moe_pool,
                    return_counts=collect_routing)
    if collect_routing:
        y, aux, counts = out
        return x + y, new_kv, new_image_kv, aux, counts
    y, aux = out
    return x + y, new_kv, new_image_kv, aux


def _ssm_block(cfg, bp, x, *, cache=None):
    h = apply_norm(bp["ln"], x, cfg.norm_type)
    if cache is None:
        y, new_cache = m2.mamba2_forward(cfg, bp["ssm"], h, return_cache=True)
    else:
        y, new_cache = m2.mamba2_decode(cfg, bp["ssm"], h, cache)
    return x + y, new_cache


# ---------------------------------------------------------------- forward

def _embed(cfg, params, batch):
    if cfg.arch_type == "encoder":
        return batch["frames"]
    return jnp.take(params["embed"], batch["tokens"], axis=0)


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, parallel=None, remat: bool = True):
    """Full-sequence forward (training / evaluation).

    batch: tokens [B,S] (or frames [B,S,D] for encoder archs),
           image_embeds [B,T_img,D] for vlm.
    Returns (logits [B,S,V], aux_loss scalar).
    """
    x = _embed(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    aux_total = jnp.zeros((), jnp.float32)

    def maybe_remat(f):
        return jax.checkpoint(f) if remat else f

    if cfg.arch_type == "ssm":
        def body(x, bp):
            x, _ = _ssm_block(cfg, bp, x)
            return x, None
        x, _ = jax.lax.scan(maybe_remat(body), x, params["blocks"])

    elif cfg.arch_type == "hybrid":
        ng = cfg.num_layers // cfg.attn_every
        blocks = jax.tree.map(
            lambda t: t.reshape(ng, cfg.attn_every, *t.shape[1:]),
            params["blocks"])
        shared = params["shared_attn"]

        def group(x, bps):
            x, _, _, _ = _attn_block(cfg, shared, x, positions)
            def inner(x, bp):
                x, _ = _ssm_block(cfg, bp, x)
                return x, None
            x, _ = jax.lax.scan(inner, x, bps)
            return x, None
        x, _ = jax.lax.scan(maybe_remat(group), x, blocks)

    elif cfg.arch_type == "vlm":
        every = cfg.cross_attn_every
        ng = cfg.num_layers // every
        blocks = jax.tree.map(
            lambda t: t.reshape(ng, every - 1, *t.shape[1:]), params["blocks"])
        img = batch["image_embeds"]

        def group(x, bps):
            bcross, bselfs = bps
            x, _, _, _ = _attn_block(cfg, bcross, x, positions, image_x=img)
            def inner(x, bp):
                x, _, _, _ = _attn_block(cfg, bp, x, positions)
                return x, None
            x, _ = jax.lax.scan(inner, x, bselfs)
            return x, None
        x, _ = jax.lax.scan(maybe_remat(group), x,
                            (params["cross_blocks"], blocks))

    else:
        moe = cfg.is_moe
        if moe and cfg.first_k_dense:
            for bp in params["dense_prefix"]:
                x, _, _, _ = _attn_block(cfg, bp, x, positions)

        def body(carry, bp):
            x, aux = carry
            x, _, _, a = _attn_block(cfg, bp, x, positions, parallel=parallel,
                                     moe=moe, moe_pool=params.get("moe_pool"))
            return (x, aux + a), None
        (x, aux_total), _ = jax.lax.scan(maybe_remat(body),
                                         (x, aux_total), params["blocks"])

    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = linear(params["lm_head"], x)
    return logits, aux_total


def loss_fn(cfg, params, batch, *, parallel=None, remat=True):
    logits, aux = forward(cfg, params, batch, parallel=parallel, remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        loss = jnp.mean(nll)
    else:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + cfg.router_aux_coef * aux


# ------------------------------------------------------------------- caches

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Decode cache pytree sized for ``max_len`` tokens."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.num_layers
    if cfg.arch_type == "ssm" or cfg.arch_type == "hybrid":
        di, N = cfg.d_inner, cfg.ssm_state
        H, P = cfg.ssm_heads, cfg.ssm_head_dim
        cache = {
            "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, di + 2 * N), dtype),
            "state": jnp.zeros((L, batch, H, N, P), jnp.float32),
        }
        if cfg.arch_type == "hybrid":
            ng = cfg.num_layers // cfg.attn_every
            KVH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            cache["attn_k"] = jnp.zeros((ng, batch, max_len, KVH, hd), dtype)
            cache["attn_v"] = jnp.zeros((ng, batch, max_len, KVH, hd), dtype)
        return cache
    if cfg.use_mla:
        r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
        return {"c": jnp.zeros((L, batch, max_len, r), dtype),
                "kr": jnp.zeros((L, batch, max_len, dr), dtype)}
    KVH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    eff = max_len if cfg.attn_window is None else min(max_len, cfg.attn_window)
    cache = {"k": jnp.zeros((L, batch, eff, KVH, hd), dtype),
             "v": jnp.zeros((L, batch, eff, KVH, hd), dtype)}
    if cfg.arch_type == "vlm":
        ncross = cfg.num_layers // cfg.cross_attn_every
        cache["img_k"] = jnp.zeros((ncross, batch, cfg.num_image_tokens,
                                    KVH, hd), dtype)
        cache["img_v"] = jnp.zeros_like(cache["img_k"])
    return cache


def routing_stats_supported(cfg: ModelConfig) -> bool:
    """Per-expert routing telemetry rides the decode step as an extra
    [L_moe, E] count output (``decode_step(..., collect_routing=True)``);
    covered family = standard-attention MoE decoders — the same scanned MoE
    decode paths the serving engine compiles; DESIGN.md §9."""
    return (cfg.has_decode and cfg.arch_type == "moe"
            and not cfg.use_mla and cfg.attn_window is None)


def paged_cache_supported(cfg: ModelConfig) -> bool:
    """The block-managed KV layout covers standard-attention decoders
    (dense + MoE).  MLA/SSM/hybrid/VLM state and windowed attention keep the
    dense layout (their caches are not per-token-appendable in the same
    way); DESIGN.md §7."""
    return (cfg.has_decode and cfg.arch_type in ("dense", "moe")
            and not cfg.use_mla and cfg.attn_window is None)


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=None, kv_dtype=None):
    """Block-pool decode cache: {'k','v': [L, NB, bs, KVH, hd]}.

    One pool row per (layer, block); ``serving/kv_blocks.py`` owns which
    sequence maps to which rows.  The block axis is sharded over 'dp'
    (one partition of ``NB/dp`` rows per replica), so growing the instance
    appends partitions and surviving rows are reused zero-copy.

    ``kv_dtype="int8"`` stores entries quantized (DESIGN.md §11): the pools
    become int8 and per-token f32 scale pools ``{'k_scale','v_scale':
    [L, NB, bs]}`` ride beside them.  Every leaf keeps the block axis at
    axis 1, so the engine's CoW copies, per-block byte accounting, growth
    adoption and live migration treat scales exactly like entries — a
    block's scales provably travel with it.
    """
    assert paged_cache_supported(cfg), \
        f"{cfg.name}: paged KV requires a standard-attention decoder"
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.num_layers
    KVH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if kv_dtype is not None and jnp.dtype(kv_dtype) != jnp.dtype(dtype):
        assert jnp.dtype(kv_dtype) == jnp.int8, \
            f"unsupported kv_dtype {kv_dtype} (int8 or the model dtype)"
        return {"k": jnp.zeros((L, num_blocks, block_size, KVH, hd),
                               jnp.int8),
                "v": jnp.zeros((L, num_blocks, block_size, KVH, hd),
                               jnp.int8),
                "k_scale": jnp.zeros((L, num_blocks, block_size),
                                     jnp.float32),
                "v_scale": jnp.zeros((L, num_blocks, block_size),
                                     jnp.float32)}
    return {"k": jnp.zeros((L, num_blocks, block_size, KVH, hd), dtype),
            "v": jnp.zeros((L, num_blocks, block_size, KVH, hd), dtype)}


def write_prefill_to_blocks(cache, dense_cache, block_ids):
    """Scatter one sequence's dense prefill KV ([L, 1, S, KVH, hd]) into its
    pool blocks.  ``block_ids`` [S/bs] holds the pool row per prompt chunk;
    entries == NB are dropped — the engine passes the sentinel both for
    padding chunks beyond the prompt and for CoW-shared prefix blocks, which
    must NOT be rewritten (they hold another live sequence's identical
    prefix, plus possibly its tokens beyond this prompt's length).

    On a quantized pool the f32 prefill rows are quantized per token at
    write time and the scales scatter through the same block ids."""
    bs = cache["k"].shape[2]
    nb = block_ids.shape[0]

    def rows_of(small):
        L = small.shape[0]
        return small[:, 0, :nb * bs].reshape(L, nb, bs, *small.shape[3:])

    if "k_scale" in cache:
        from repro.kernels.quant import quantize_rows
        out = dict(cache)
        for name in ("k", "v"):
            q, s = quantize_rows(rows_of(dense_cache[name]), (-2, -1))
            out[name] = cache[name].at[:, block_ids].set(q, mode="drop")
            out[name + "_scale"] = cache[name + "_scale"].at[
                :, block_ids].set(s, mode="drop")
        return out

    def put(pool, small):
        return pool.at[:, block_ids].set(rows_of(small).astype(pool.dtype),
                                         mode="drop")

    return {"k": put(cache["k"], dense_cache["k"]),
            "v": put(cache["v"], dense_cache["v"])}


def paged_decode_step(cfg: ModelConfig, params: Params, tokens, cache,
                      lengths, block_tables, write_block, *, parallel=None,
                      collect_routing=False):
    """One decode step over the paged KV pool.  tokens [B,1]; lengths [B];
    block_tables [B,MB] (pool rows per sequence, position-ordered);
    write_block [B] = row receiving this token's k/v (== NB for inactive
    slots -> dropped).  Returns (logits [B,V], cache') — plus per-layer
    routing counts [L_moe, E] when ``collect_routing`` (dense-prefix layers
    have no router and contribute no row)."""
    from repro.models.layers import paged_attention_apply

    if collect_routing:
        assert routing_stats_supported(cfg), \
            f"{cfg.name}: routing telemetry unsupported"
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = lengths[:, None]
    moe = cfg.is_moe

    def block(bp, x, lc, want_counts=False):
        # lc: this layer's cache leaves ({'k','v'} + optional int8 scales)
        h = apply_norm(bp["ln1"], x, cfg.norm_type)
        a, lc = paged_attention_apply(
            cfg, bp["attn"], h, positions, cache=lc,
            block_tables=block_tables, write_block=write_block,
            lengths=lengths)
        x = x + a
        h = apply_norm(bp["ln2"], x, cfg.norm_type)
        out = _ffn_part(cfg, bp, h, parallel=parallel,
                        moe=moe and "moe" in bp,
                        moe_pool=params.get("moe_pool"),
                        return_counts=want_counts)
        if want_counts:
            y, _, cnt = out
            return x + y, lc, cnt
        y, _ = out
        return x + y, lc

    nk = cfg.first_k_dense if moe else 0
    prefix = []
    for i in range(nk):
        x, lc = block(params["dense_prefix"][i], x,
                      {n: v[i] for n, v in cache.items()})
        prefix.append(lc)

    def body(x, inp):
        bp, lc = inp
        if collect_routing:
            x, lc, cnt = block(bp, x, lc, want_counts=True)
            return x, (lc, cnt)
        x, lc = block(bp, x, lc)
        return x, lc

    x, scanned = jax.lax.scan(body, x, (params["blocks"],
                                        {n: v[nk:] for n, v in cache.items()}))
    counts = None
    if collect_routing:
        new_cache, counts = scanned
    else:
        new_cache = scanned
    if nk:
        new_cache = {n: jnp.concatenate(
            [jnp.stack([p[n] for p in prefix]), new_cache[n]], 0)
            for n in new_cache}

    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = linear(params["lm_head"], x[:, 0])
    if collect_routing:
        return logits, new_cache, counts
    return logits, new_cache


def chunk_prefill_supported(cfg: ModelConfig) -> bool:
    """Chunked prefill needs per-chunk-appendable KV with explicit position
    masking — the same family the paged layout covers (dense + MoE standard
    attention), for both KV layouts; DESIGN.md §8."""
    return (cfg.has_decode and cfg.arch_type in ("dense", "moe")
            and not cfg.use_mla and cfg.attn_window is None)


def paged_chunk_prefill_step(cfg: ModelConfig, params: Params, tokens, cache,
                             start, length, block_tables, chunk_block_ids,
                             *, parallel=None):
    """One chunked-prefill step for a single sequence over the paged pool.

    tokens [1,C] — one prompt chunk at absolute positions start..start+C-1
    (rows at or beyond the prompt length are padding); ``start`` scalar =
    chunk offset (block-aligned); ``length`` scalar = context tokens after
    this chunk (= min(start+C, prompt_len)); block_tables [1,MB] = the
    sequence's full table; chunk_block_ids [C/bs] = pool rows receiving this
    chunk's k/v (NB for padding/CoW-shared rows -> dropped).  Returns
    (logits [1,V] at position ``length-1``, cache') — the final chunk's
    logits sample the first output token, exactly like monolithic prefill.
    """
    from repro.models.layers import paged_chunk_attention_apply

    C = tokens.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = start + jnp.broadcast_to(jnp.arange(C)[None], (1, C))
    q_len = length - start
    moe = cfg.is_moe

    def block(bp, x, lc):
        h = apply_norm(bp["ln1"], x, cfg.norm_type)
        a, lc = paged_chunk_attention_apply(
            cfg, bp["attn"], h, positions, cache=lc,
            block_tables=block_tables, chunk_block_ids=chunk_block_ids,
            ctx_len=length, q_len=q_len)
        x = x + a
        h = apply_norm(bp["ln2"], x, cfg.norm_type)
        y, _ = _ffn_part(cfg, bp, h, parallel=parallel,
                         moe=moe and "moe" in bp,
                         moe_pool=params.get("moe_pool"))
        return x + y, lc

    nk = cfg.first_k_dense if moe else 0
    prefix = []
    for i in range(nk):
        x, lc = block(params["dense_prefix"][i], x,
                      {n: v[i] for n, v in cache.items()})
        prefix.append(lc)

    def body(x, inp):
        bp, lc = inp
        x, lc = block(bp, x, lc)
        return x, lc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"],
                                          {n: v[nk:]
                                           for n, v in cache.items()}))
    if nk:
        new_cache = {n: jnp.concatenate(
            [jnp.stack([p[n] for p in prefix]), new_cache[n]], 0)
            for n in new_cache}

    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    last = jax.lax.dynamic_index_in_dim(x, q_len - 1, axis=1, keepdims=False)
    logits = linear(params["lm_head"], last)
    return logits, new_cache


def chunk_prefill_step(cfg: ModelConfig, params: Params, tokens, cache,
                       start, length, slot, *, parallel=None):
    """Dense-layout twin of :func:`paged_chunk_prefill_step`: the chunk's
    k/v land in slot row ``slot`` of the slot-contiguous cache
    {'k','v': [L,B,S_max,KVH,hd]} at [start, start+C), and the chunk attends
    causally over the row.  Returns (logits [1,V] at ``length-1``, cache').
    """
    from repro.models.layers import chunk_attention_apply

    C = tokens.shape[1]
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = start + jnp.broadcast_to(jnp.arange(C)[None], (1, C))
    q_len = length - start
    moe = cfg.is_moe

    def block(bp, x, kfull, vfull):
        k_row = jax.lax.dynamic_slice_in_dim(kfull, slot, 1, axis=0)
        v_row = jax.lax.dynamic_slice_in_dim(vfull, slot, 1, axis=0)
        h = apply_norm(bp["ln1"], x, cfg.norm_type)
        a, (k_row, v_row) = chunk_attention_apply(
            cfg, bp["attn"], h, positions, k_row=k_row, v_row=v_row,
            start=start)
        kfull = jax.lax.dynamic_update_slice_in_dim(kfull, k_row, slot, axis=0)
        vfull = jax.lax.dynamic_update_slice_in_dim(vfull, v_row, slot, axis=0)
        x = x + a
        h = apply_norm(bp["ln2"], x, cfg.norm_type)
        y, _ = _ffn_part(cfg, bp, h, parallel=parallel,
                         moe=moe and "moe" in bp,
                         moe_pool=params.get("moe_pool"))
        return x + y, kfull, vfull

    nk = cfg.first_k_dense if moe else 0
    new_k, new_v = [], []
    for i in range(nk):
        x, kf, vf = block(params["dense_prefix"][i], x,
                          cache["k"][i], cache["v"][i])
        new_k.append(kf)
        new_v.append(vf)

    def body(x, inp):
        bp, kf, vf = inp
        x, kf, vf = block(bp, x, kf, vf)
        return x, (kf, vf)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"],
                                         cache["k"][nk:], cache["v"][nk:]))
    if nk:
        ks = jnp.concatenate([jnp.stack(new_k), ks], 0)
        vs = jnp.concatenate([jnp.stack(new_v), vs], 0)
    new_cache = {"k": ks, "v": vs}

    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    last = jax.lax.dynamic_index_in_dim(x, q_len - 1, axis=1, keepdims=False)
    logits = linear(params["lm_head"], last)
    return logits, new_cache


def _cache_slot(cfg, lengths):
    """KV write slot for each sequence (ring-buffered under attn_window)."""
    if cfg.attn_window is None:
        return lengths
    return lengths % cfg.attn_window


# ---------------------------------------------------------------- prefill

def prefill(cfg: ModelConfig, params: Params, batch, max_len: int,
            *, parallel=None):
    """Process the full prompt; returns (last-token logits [B,V], cache).

    All sequences are assumed left-aligned; ``batch['lengths']`` [B] gives the
    true prompt lengths (padding tokens attend causally but their kv entries
    beyond length are masked at decode time via valid-length masking).
    """
    assert cfg.has_decode, f"{cfg.name} is encoder-only (no decode)"
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cache = init_cache(cfg, B, max_len, jnp.dtype(cfg.dtype))

    def pad_to(t, length, axis):
        pads = [(0, 0)] * t.ndim
        pads[axis] = (0, length - t.shape[axis])
        return jnp.pad(t, pads)

    if cfg.arch_type in ("ssm", "hybrid"):
        if cfg.arch_type == "ssm":
            def body(x, bp):
                x, c = _ssm_block(cfg, bp, x)
                return x, c
            x, caches = jax.lax.scan(body, x, params["blocks"])
            cache = {"conv": caches["conv"], "state": caches["state"]}
        else:
            ng = cfg.num_layers // cfg.attn_every
            blocks = jax.tree.map(
                lambda t: t.reshape(ng, cfg.attn_every, *t.shape[1:]),
                params["blocks"])
            shared = params["shared_attn"]
            eff = cache["attn_k"].shape[2]

            def group(x, bps):
                x, kv, _, _ = _attn_block(cfg, shared, x, positions)
                k, v = kv
                def inner(x, bp):
                    x, c = _ssm_block(cfg, bp, x)
                    return x, c
                x, cs = jax.lax.scan(inner, x, bps)
                return x, (cs, pad_to(k[:, -eff:], eff, 1),
                           pad_to(v[:, -eff:], eff, 1))
            x, (cs, ks, vs) = jax.lax.scan(group, x, blocks)
            cache = {
                "conv": jax.tree.map(lambda t: t.reshape(cfg.num_layers,
                                                         *t.shape[2:]),
                                     cs["conv"]),
                "state": cs["state"].reshape(cfg.num_layers,
                                             *cs["state"].shape[2:]),
                "attn_k": ks, "attn_v": vs,
            }
    elif cfg.use_mla:
        moe = cfg.is_moe
        if moe and cfg.first_k_dense:
            prefix_caches = []
            for bp in params["dense_prefix"]:
                x, kv, _, _ = _attn_block(cfg, bp, x, positions)
                prefix_caches.append(kv)
        def body(carry, bp):
            x = carry
            x, kv, _, _ = _attn_block(cfg, bp, x, positions,
                                      parallel=parallel, moe=moe,
                                      moe_pool=params.get("moe_pool"))
            c, kr = kv
            return x, (pad_to(c, max_len, 1), pad_to(kr, max_len, 1))
        x, (cs, krs) = jax.lax.scan(body, x, params["blocks"])
        if cfg.first_k_dense:
            pc = jnp.stack([pad_to(c, max_len, 1) for c, _ in prefix_caches])
            pk = jnp.stack([pad_to(kr, max_len, 1) for _, kr in prefix_caches])
            cs = jnp.concatenate([pc, cs], axis=0)
            krs = jnp.concatenate([pk, krs], axis=0)
        cache = {"c": cs, "kr": krs}
    else:
        moe = cfg.is_moe
        img = batch.get("image_embeds")
        eff = cache["k"].shape[2]

        def body(carry, bp):
            x, aux = carry
            x, kv, _, a = _attn_block(cfg, bp, x, positions,
                                      parallel=parallel, moe=moe,
                                      moe_pool=params.get("moe_pool"))
            k, v = kv
            return (x, aux + a), (pad_to(k[:, -eff:], eff, 1),
                                  pad_to(v[:, -eff:], eff, 1))
        if cfg.arch_type == "vlm":
            every = cfg.cross_attn_every
            ng = cfg.num_layers // every
            blocks = jax.tree.map(
                lambda t: t.reshape(ng, every - 1, *t.shape[1:]),
                params["blocks"])

            def group(carry, bps):
                x = carry
                bcross, bselfs = bps
                x, kvc, imgkv, _ = _attn_block(cfg, bcross, x, positions,
                                               image_x=img)
                kc, vc = kvc
                def inner(x, bp):
                    x, kv, _, _ = _attn_block(cfg, bp, x, positions)
                    return x, kv
                x, (ks, vs) = jax.lax.scan(inner, x, bselfs)
                ks = jnp.concatenate([kc[None], ks], 0)   # [every, B, S, ...]
                vs = jnp.concatenate([vc[None], vs], 0)
                return x, (pad_to(ks, max_len, 2), pad_to(vs, max_len, 2),
                           imgkv[0], imgkv[1])
            x, (ks, vs, imk, imv) = jax.lax.scan(group, x,
                                                 (params["cross_blocks"],
                                                  blocks))
            cache = {"k": ks.reshape(-1, B, max_len, *ks.shape[4:]),
                     "v": vs.reshape(-1, B, max_len, *vs.shape[4:]),
                     "img_k": imk, "img_v": imv}
        else:
            (x, _), (ks, vs) = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                            params["blocks"])
            cache = {"k": ks, "v": vs}

    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    lengths = batch.get("lengths")
    if lengths is None:
        last = x[:, -1]
    else:
        last = x[jnp.arange(B), lengths - 1]
    logits = linear(params["lm_head"], last)
    return logits, cache


# ------------------------------------------------------------------- decode

def decode_step(cfg: ModelConfig, params: Params, tokens, cache, lengths,
                *, parallel=None, collect_routing=False):
    """One decode step.  tokens [B,1]; lengths [B] = number of tokens already
    in the cache (the new token is written at slot ``lengths``).
    Returns (logits [B,V], cache') — plus per-layer routing counts
    [L_moe, E] when ``collect_routing`` (gated on
    :func:`routing_stats_supported`)."""
    assert cfg.has_decode
    if collect_routing:
        assert routing_stats_supported(cfg), \
            f"{cfg.name}: routing telemetry unsupported"
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = lengths[:, None]
    write_pos = _cache_slot(cfg, lengths)
    valid = lengths + 1

    if cfg.arch_type == "ssm":
        def body(x, inp):
            bp, c = inp
            x, c2 = _ssm_block(cfg, bp, x, cache=c)
            return x, c2
        x, new = jax.lax.scan(body, x, (params["blocks"], cache))
        new_cache = new
    elif cfg.arch_type == "hybrid":
        ng = cfg.num_layers // cfg.attn_every
        blocks = jax.tree.map(
            lambda t: t.reshape(ng, cfg.attn_every, *t.shape[1:]),
            params["blocks"])
        ssm_cache = jax.tree.map(
            lambda t: t.reshape(ng, cfg.attn_every, *t.shape[1:]),
            {"conv": cache["conv"], "state": cache["state"]})
        shared = params["shared_attn"]
        win = cache["attn_k"].shape[2]

        def group(x, inp):
            bps, sc, k, v = inp
            wp = lengths % win
            x, kv, _, _ = _attn_block(cfg, shared, x, positions, cache=(k, v),
                                      write_pos=wp,
                                      kv_valid_len=jnp.minimum(valid, win))
            def inner(x, inp2):
                bp, c = inp2
                x, c2 = _ssm_block(cfg, bp, x, cache=c)
                return x, c2
            x, sc2 = jax.lax.scan(inner, x, (bps, sc))
            return x, (sc2, kv[0], kv[1])
        x, (sc2, ks, vs) = jax.lax.scan(
            group, x, (blocks, ssm_cache, cache["attn_k"], cache["attn_v"]))
        new_cache = {
            "conv": sc2["conv"].reshape(cfg.num_layers, *sc2["conv"].shape[2:]),
            "state": sc2["state"].reshape(cfg.num_layers,
                                          *sc2["state"].shape[2:]),
            "attn_k": ks, "attn_v": vs}
    elif cfg.use_mla:
        moe = cfg.is_moe
        nk = cfg.first_k_dense
        cs, krs = cache["c"], cache["kr"]
        new_c, new_kr = [], []
        for i in range(nk):
            x, kv, _, _ = _attn_block(cfg, params["dense_prefix"][i], x,
                                      positions, cache=(cs[i], krs[i]),
                                      write_pos=write_pos, kv_valid_len=valid)
            new_c.append(kv[0]); new_kr.append(kv[1])
        def body(carry, inp):
            x = carry
            bp, c, kr = inp
            x, kv, _, _ = _attn_block(cfg, bp, x, positions, cache=(c, kr),
                                      write_pos=write_pos, kv_valid_len=valid,
                                      parallel=parallel, moe=moe,
                                      moe_pool=params.get("moe_pool"))
            return x, (kv[0], kv[1])
        x, (cs2, krs2) = jax.lax.scan(body, x,
                                      (params["blocks"], cs[nk:], krs[nk:]))
        if nk:
            cs2 = jnp.concatenate([jnp.stack(new_c), cs2], 0)
            krs2 = jnp.concatenate([jnp.stack(new_kr), krs2], 0)
        new_cache = {"c": cs2, "kr": krs2}
    else:
        moe = cfg.is_moe
        win = cache["k"].shape[2]
        wp = write_pos if cfg.attn_window is None else lengths % win
        vl = valid if cfg.attn_window is None else jnp.minimum(valid, win)

        if cfg.arch_type == "vlm":
            every = cfg.cross_attn_every
            ng = cfg.num_layers // every
            # per group: row 0 = cross layer's self-attn kv, rows 1.. = self
            ks = cache["k"].reshape(ng, every, *cache["k"].shape[1:])
            vs = cache["v"].reshape(ng, every, *cache["v"].shape[1:])
            blocks = jax.tree.map(
                lambda t: t.reshape(ng, every - 1, *t.shape[1:]),
                params["blocks"])

            def group(x, inp):
                bcross, bselfs, kg, vg, ik, iv = inp
                x, kv0, _, _ = _attn_block(cfg, bcross, x, positions,
                                           cache=(kg[0], vg[0]), write_pos=wp,
                                           kv_valid_len=vl, image_kv=(ik, iv))
                def inner(carry, inp2):
                    x = carry
                    bp, k, v = inp2
                    x, kv, _, _ = _attn_block(cfg, bp, x, positions,
                                              cache=(k, v), write_pos=wp,
                                              kv_valid_len=vl)
                    return x, (kv[0], kv[1])
                x, (ks2, vs2) = jax.lax.scan(inner, x, (bselfs, kg[1:], vg[1:]))
                return x, (jnp.concatenate([kv0[0][None], ks2], 0),
                           jnp.concatenate([kv0[1][None], vs2], 0))
            x, (ks2, vs2) = jax.lax.scan(
                group, x, (params["cross_blocks"], blocks, ks, vs,
                           cache["img_k"], cache["img_v"]))
            new_cache = {"k": ks2.reshape(-1, *ks2.shape[2:]),
                         "v": vs2.reshape(-1, *vs2.shape[2:]),
                         "img_k": cache["img_k"], "img_v": cache["img_v"]}
        else:
            def body(carry, inp):
                x = carry
                bp, k, v = inp
                out = _attn_block(cfg, bp, x, positions, cache=(k, v),
                                  write_pos=wp, kv_valid_len=vl,
                                  parallel=parallel, moe=moe,
                                  moe_pool=params.get("moe_pool"),
                                  collect_routing=collect_routing)
                if collect_routing:
                    x, kv, _, _, cnt = out
                    return x, (kv[0], kv[1], cnt)
                x, kv, _, _ = out
                return x, (kv[0], kv[1])
            x, scanned = jax.lax.scan(body, x,
                                      (params["blocks"], cache["k"],
                                       cache["v"]))
            if collect_routing:
                ks2, vs2, routed_counts = scanned
            else:
                ks2, vs2 = scanned
            new_cache = {"k": ks2, "v": vs2}

    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = linear(params["lm_head"], x[:, 0])
    if collect_routing:
        return logits, new_cache, routed_counts
    return logits, new_cache
