"""DeepSeek-V2 Multi-head Latent Attention (MLA).

The KV cache stores only the rank-``kv_lora_rank`` latent ``c_kv`` plus the
shared rope key — this is the cache the ElasticMoE HMM reuses zero-copy
across scaling events.

Two compute paths:
* prefill/forward — expand k/v from the latent (clear, matches the paper's
  formulation),
* decode — the *absorbed* formulation (q absorbed into W_uk, output read out
  through W_uv) so per-step FLOPs scale with the latent rank, not with
  H*(d_nope+d_v).  This is the TPU-friendly form (two skinny matmuls feeding
  the MXU instead of a cache-wide expansion).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_norm, apply_rope, linear, linear_init,
                                 mha, norm_init, rope_tables)


def mla_init(rng, cfg, dtype):
    D, H = cfg.d_model, cfg.num_heads
    dn, dr, dv, r = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    ks = jax.random.split(rng, 7)
    p = {}
    if cfg.q_lora_rank:
        p["q_down"] = linear_init(ks[0], D, cfg.q_lora_rank, dtype)
        p["q_norm"] = norm_init(cfg.q_lora_rank, "rmsnorm", dtype)
        p["q_up"] = linear_init(ks[1], cfg.q_lora_rank, H * (dn + dr), dtype)
    else:
        p["q"] = linear_init(ks[0], D, H * (dn + dr), dtype)
    p["kv_down"] = linear_init(ks[2], D, r + dr, dtype)
    p["kv_norm"] = norm_init(r, "rmsnorm", dtype)
    p["k_up"] = linear_init(ks[3], r, H * dn, dtype)
    p["v_up"] = linear_init(ks[4], r, H * dv, dtype)
    p["o"] = linear_init(ks[5], H * dv, D, dtype)
    return p


def _queries(cfg, p, x):
    B, S, _ = x.shape
    H, dn, dr = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        q = linear(p["q_up"], apply_norm(p["q_norm"], linear(p["q_down"], x),
                                         "rmsnorm"))
    else:
        q = linear(p["q"], x)
    q = q.reshape(B, S, H, dn + dr)
    return q[..., :dn], q[..., dn:]


def mla_prefill(cfg, p, x, positions):
    """Returns (y, cache) where cache = (c_kv [B,S,r], k_rope [B,S,dr])."""
    B, S, _ = x.shape
    H, dn, dr, dv, r = (cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    q_nope, q_rope = _queries(cfg, p, x)
    ckr = linear(p["kv_down"], x)
    c_kv = apply_norm(p["kv_norm"], ckr[..., :r], "rmsnorm")
    k_rope = ckr[..., r:]

    cos, sin = rope_tables(positions, dr)
    q_rope = apply_rope(q_rope, cos, sin, dr)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin, dr)[:, :, 0]

    k_nope = linear(p["k_up"], c_kv).reshape(B, S, H, dn)
    v = linear(p["v_up"], c_kv).reshape(B, S, H, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], -1)
    # pad v's head dim up to qk dim so we can reuse the generic mha, then trim
    y = mha(q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv))),
            q_pos=positions, kv_pos=positions, causal=True)[..., :dv]
    out = linear(p["o"], y.reshape(B, S, H * dv))
    return out, (c_kv, k_rope)


def mla_decode(cfg, p, x, positions, cache, write_pos, kv_valid_len):
    """Absorbed-form single-token decode.

    cache = (c_kv [B,Smax,r], k_rope [B,Smax,dr]); x [B,1,D];
    write_pos [B] int32 per-sequence slot.
    """
    B, S, _ = x.shape
    H, dn, dr, dv, r = (cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    c_cache, kr_cache = cache

    q_nope, q_rope = _queries(cfg, p, x)
    ckr = linear(p["kv_down"], x)
    c_new = apply_norm(p["kv_norm"], ckr[..., :r], "rmsnorm")
    kr_new = ckr[..., r:]
    cos, sin = rope_tables(positions, dr)
    q_rope = apply_rope(q_rope, cos, sin, dr)
    kr_new = apply_rope(kr_new[:, :, None, :], cos, sin, dr)[:, :, 0]

    b_idx = jnp.arange(B)
    c_cache = c_cache.at[b_idx, write_pos].set(
        c_new[:, 0].astype(c_cache.dtype), mode="drop")
    kr_cache = kr_cache.at[b_idx, write_pos].set(
        kr_new[:, 0].astype(kr_cache.dtype), mode="drop")

    # absorb: q_eff[b,s,h,r] = q_nope · W_uk[h]   (W_uk: [r, H*dn])
    w_uk = p["k_up"]["w"].reshape(r, H, dn)
    q_eff = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / math.sqrt(dn + dr)
    scores = (jnp.einsum("bshr,btr->bhst", q_eff, c_cache,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshd,btd->bhst", q_rope, kr_cache,
                           preferred_element_type=jnp.float32)) * scale
    t = jnp.arange(c_cache.shape[1])[None, None, None]
    mask = t < kv_valid_len[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    prob = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", prob, c_cache,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    # read out through W_uv: [r, H*dv]
    w_uv = p["v_up"]["w"].reshape(r, H, dv)
    y = jnp.einsum("bshr,rhd->bshd", ctx, w_uv,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    out = linear(p["o"], y.reshape(B, S, H * dv))
    return out, (c_cache, kr_cache)
