"""Mixture-of-Experts layer: router + three execution paths.

* ``moe_local``  — single-shard capacity-based dispatch (scatter → grouped
  matmul → combine).  Used by the elastic serving engine, smoke tests, and as
  the oracle for the Pallas paged-GMM kernel.
* ``moe_ep``     — expert-parallel path for production meshes, written with
  ``shard_map``: per-data-shard dispatch into a [n_ep, E_local, C, D] buffer,
  ``all_to_all`` over the EP axis, grouped expert matmul with the expert FFN
  hidden dim TP-sharded over the model axis, reverse ``all_to_all``, combine.
  This is the paper's "unified token routing" (§2.1/§3 L4) mapped onto
  jax-native collectives.
* **pooled** (``expert_mode="pooled"`` on the HMM; DESIGN.md §2) — expert
  weights live as per-device page *pools* ``[pages, D, F]`` plus page-table
  index arrays (``core/expert_pages.pooled_layout``) instead of dense
  ``[E, D, F]`` banks.  ``moe_ep`` detects the pooled parameter layout
  (``"tables" in p``) and dispatches by the table's — possibly
  non-contiguous, min-move — expert placement; the grouped matmul goes
  through ``kernels.ops.paged_expert_ffn`` (Pallas paged GMM on
  accelerators, jnp gather oracle on CPU via ``REPRO_POOLED_IMPL``).
  ``moe_local_pooled`` is the single-shard equivalent over global pool rows.
  Per-expert math is identical to the dense paths, so pooled and dense
  decode agree bit-for-bit at f32 (asserted in tests/test_pooled_experts.py).

Capacity convention: every (expert) gets a fixed per-source-shard capacity
``C = ceil(T_local * top_k / E * capacity_factor)``; overflow tokens are
dropped (standard GShard semantics).  FLOPs therefore track the *active*
parameter count — this is what the roofline's MODEL_FLOPS ratio checks.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import dot, linear, linear_init

# jax >= 0.5 exposes shard_map at top level (replication check kw is
# ``check_vma``); 0.4.x only has the experimental module (``check_rep``).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SM_NOCHECK = {"check_vma": False}
else:                                     # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_NOCHECK = {"check_rep": False}


# -------------------------------------------------------------------- router

def router_init(rng, d_model, num_experts, dtype):
    # router math is always f32 for stability
    return {"w": (jax.random.normal(rng, (d_model, num_experts), jnp.float32)
                  * (1.0 / math.sqrt(d_model)))}


def route(p, x, top_k):
    """x [T, D] -> (topk_idx [T,k] int32, topk_w [T,k] f32, aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, top_k)
    topk_w = topk_w / jnp.sum(topk_w, axis=-1, keepdims=True)
    # GShard/Switch load-balance auxiliary loss
    E = probs.shape[-1]
    me = jnp.mean(probs, axis=0)
    one = jax.nn.one_hot(topk_idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one, axis=0)
    aux = E * jnp.sum(me * ce)
    return topk_idx.astype(jnp.int32), topk_w, aux


def _dispatch_indices(topk_idx, num_experts, capacity):
    """Flattened (token, k) entries -> (expert, slot) with capacity dropping.

    Returns (expert_flat [T*k], slot [T*k], keep [T*k] bool); dropped entries
    get slot == capacity (out of range -> 'drop' scatter mode discards them).
    """
    Tk = topk_idx.size
    expert_flat = topk_idx.reshape(Tk)
    onehot = jax.nn.one_hot(expert_flat, num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                    # [Tk, E]
    slot = jnp.sum(pos * onehot, axis=-1)                   # [Tk]
    keep = slot < capacity
    slot = jnp.where(keep, slot, capacity)
    return expert_flat, slot, keep


def _expert_ffn(xg, wi, wg, wo):
    """xg [E, C, D]; wi/wg [E, D, F]; wo [E, F, D] -> [E, C, D]."""
    h = jnp.einsum("ecd,edf->ecf", xg, wi,
                   preferred_element_type=jnp.float32).astype(xg.dtype)
    g = jnp.einsum("ecd,edf->ecf", xg, wg,
                   preferred_element_type=jnp.float32)
    h = h * jax.nn.silu(g).astype(xg.dtype)
    return jnp.einsum("ecf,efd->ecd", h, wo,
                      preferred_element_type=jnp.float32).astype(xg.dtype)


# ---------------------------------------------------------------- moe params

def moe_init(rng, cfg, dtype):
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(rng, 5)
    s = 1.0 / math.sqrt(D)
    p = {
        "router": router_init(ks[0], D, E, dtype),
        "wi": jax.random.normal(ks[1], (E, D, F), dtype) * s,
        "wg": jax.random.normal(ks[2], (E, D, F), dtype) * s,
        "wo": jax.random.normal(ks[3], (E, F, D), dtype) * (1.0 / math.sqrt(F)),
    }
    if cfg.num_shared_experts:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks[4], D, cfg.moe_d_ff * cfg.num_shared_experts,
                               dtype, gated=True)
    return p


def capacity_for(tokens, cfg):
    return max(1, int(math.ceil(tokens * cfg.top_k / cfg.num_experts
                                * cfg.capacity_factor)))


# ------------------------------------------------------------- local path

def routing_counts(topk_idx, num_experts):
    """topk_idx [T, k] -> per-expert routed-token counts [E] int32 — the
    telemetry histogram (obs, DESIGN.md §9).  Pure bincount: capacity
    dropping is intentionally ignored, this measures router demand."""
    return jnp.zeros((num_experts,), jnp.int32).at[
        topk_idx.reshape(-1)].add(1)


def _moe_local_body(cfg, p, x, capacity, expert_ffn, return_counts=False):
    """Shared single-shard dispatch/combine; ``expert_ffn(xg [E, C, D]) ->
    [E, C, D]`` is the only thing that differs between the dense banks and
    the pooled store (which is what makes their outputs bit-identical).

    ``return_counts`` additionally returns the router's per-expert token
    counts [E] (routing telemetry; the default two-tuple return is
    untouched so every existing call site is byte-identical)."""
    T, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    C = capacity or capacity_for(T, cfg)
    topk_idx, topk_w, aux = route(p["router"], x, k)
    expert_flat, slot, keep = _dispatch_indices(topk_idx, E, C)
    token_idx = jnp.repeat(jnp.arange(T), k)

    xg = jnp.zeros((E, C, D), x.dtype).at[expert_flat, slot].set(
        x[token_idx], mode="drop")
    yg = expert_ffn(xg)

    w_flat = topk_w.reshape(T * k).astype(x.dtype)
    gathered = yg.at[expert_flat, slot].get(mode="fill", fill_value=0.0)
    y = jnp.zeros((T, D), x.dtype).at[token_idx].add(
        gathered * (w_flat * keep)[:, None])
    if "shared" in p:
        from repro.models.layers import mlp_apply
        y = y + mlp_apply(p["shared"], x)
    if return_counts:
        return y, aux, routing_counts(topk_idx, E)
    return y, aux


def moe_local(cfg, p, x, capacity=None, return_counts=False):
    """x [T, D] -> ([T, D], aux_loss).  Single-shard dispatch/combine."""
    return _moe_local_body(
        cfg, p, x, capacity,
        lambda xg: _expert_ffn(xg, p["wi"], p["wg"], p["wo"]),
        return_counts=return_counts)


def moe_local_pooled(cfg, p, pool, x, capacity=None, return_counts=False):
    """Single-shard MoE over the pooled weight store.

    ``p`` holds the per-layer index arrays (``gtable`` [E]: global pool row
    per expert) and ``pool`` the three banks ``{wi, wg, wo}`` as
    ``[pages_total, D, F]`` / ``[pages_total, F, D]``.  Dispatch/combine are
    shared with ``moe_local``; only the weight *addressing* differs — the
    grouped matmul reads pages through the table (``ops.paged_expert_ffn``),
    so an expert remap rewrites ``gtable`` and moves no weight bytes."""
    from repro.kernels import ops

    gt = p["gtable"]
    if "wi_scale" in pool:
        # int8 store: per-page f32 scale banks ride beside the pools and are
        # addressed by the same table (kernels/moe_gmm.quant_paged_gmm)
        ffn = lambda xg: ops.quant_paged_expert_ffn(
            gt, gt, gt, pool["wi"], pool["wg"], pool["wo"],
            pool["wi_scale"], pool["wg_scale"], pool["wo_scale"], xg)
    else:
        ffn = lambda xg: ops.paged_expert_ffn(gt, gt, gt, pool["wi"],
                                              pool["wg"], pool["wo"], xg)
    return _moe_local_body(cfg, p, x, capacity, ffn,
                           return_counts=return_counts)


# ---------------------------------------------------------------- EP path

def _moe_ep_shard(cfg, ep_axes, tp_axis, dp_axes, router_w, wi, wg, wo, x,
                  capacity, n_ep):
    """Body run per (ep, tp) shard under shard_map.

    x        [T_local, D]        (token-sharded over ep_axes)
    wi/wg    [E_local, D, F_tp]  wo [E_local, F_tp, D]

    ``n_ep`` is threaded in statically from the mesh (jax 0.4.x has no
    ``jax.lax.axis_size``, and buffer shapes need it concrete anyway).
    """
    E, k = cfg.num_experts, cfg.top_k
    E_local = E // n_ep
    T, D = x.shape
    C = capacity

    topk_idx, topk_w, aux = route({"w": router_w}, x, k)
    expert_flat, slot, keep = _dispatch_indices(topk_idx, E, C)
    dest = expert_flat // E_local
    e_loc = expert_flat % E_local
    token_idx = jnp.repeat(jnp.arange(T), k)

    send = jnp.zeros((n_ep, E_local, C, D), x.dtype).at[
        dest, e_loc, slot].set(x[token_idx], mode="drop")
    # all-to-all over the EP axes: rows <-> shards
    recv = jax.lax.all_to_all(send, ep_axes, split_axis=0, concat_axis=0,
                              tiled=False)
    xg = recv.transpose(1, 0, 2, 3).reshape(E_local, n_ep * C, D)
    yg = _expert_ffn(xg, wi, wg, wo)
    if tp_axis is not None:
        # expert hidden dim is TP-sharded -> partial sums over tp_axis
        yg = jax.lax.psum(yg, tp_axis)
    back = yg.reshape(E_local, n_ep, C, D).transpose(1, 0, 2, 3)
    ret = jax.lax.all_to_all(back, ep_axes, split_axis=0, concat_axis=0,
                             tiled=False)

    w_flat = topk_w.reshape(T * k).astype(x.dtype)
    gathered = ret.at[dest, e_loc, slot].get(mode="fill", fill_value=0.0)
    y = jnp.zeros((T, D), x.dtype).at[token_idx].add(
        gathered * (w_flat * keep)[:, None])
    aux = jax.lax.pmean(aux, dp_axes)
    return y, aux


def _moe_ep_shard_packed(cfg, ep_axes, tp_axis, dp_axes, router_w, wi, wg, wo,
                         x, capacity, n_ep):
    """Packed-dispatch variant (beyond-paper, EXPERIMENTS.md §Perf B).

    Buffers are sized per (src, dst) shard pair — [n_ep, C2, D] with
    C2 ~ T*k/n_ep — instead of per (src, dst, expert) slot, which shrinks the
    all-to-all payload by ~E_local/k when experts-per-shard exceed top_k
    (decode: 4-8x on arctic/deepseek).  Expert FFN results return as TP
    partials and are reduced once on the combined [T, D] output instead of
    per capacity slot.  Cost: the expert matmul computes all local experts
    per token (one-hot select) — E_local x FLOP waste, negligible at decode
    arithmetic intensity.  Use for decode; keep expert-slot dispatch for
    train/prefill.
    """
    E, k = cfg.num_experts, cfg.top_k
    E_local = E // n_ep
    T, D = x.shape
    C2 = capacity

    topk_idx, topk_w, aux = route({"w": router_w}, x, k)
    Tk = T * k
    expert_flat = topk_idx.reshape(Tk)
    dest = expert_flat // E_local
    e_loc = expert_flat % E_local
    onehot = jax.nn.one_hot(dest, n_ep, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.sum(pos * onehot, axis=-1)
    keep = slot < C2
    slot = jnp.where(keep, slot, C2)
    token_idx = jnp.repeat(jnp.arange(T), k)

    send_x = jnp.zeros((n_ep, C2, D), x.dtype).at[dest, slot].set(
        x[token_idx], mode="drop")
    send_e = jnp.full((n_ep, C2), E_local, jnp.int32).at[dest, slot].set(
        e_loc, mode="drop")
    recv_x = jax.lax.all_to_all(send_x, ep_axes, 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, ep_axes, 0, 0, tiled=False)
    xg = recv_x.reshape(n_ep * C2, D)
    eid = recv_e.reshape(n_ep * C2)

    # all-local-experts compute + one-hot select (E_local x flops, tiny at
    # decode); invalid slots (eid == E_local) select zero
    h = jnp.einsum("sd,edf->esf", xg, wi,
                   preferred_element_type=jnp.float32)
    g = jnp.einsum("sd,edf->esf", xg, wg,
                   preferred_element_type=jnp.float32)
    h = (h * jax.nn.silu(g)).astype(x.dtype)
    y_all = jnp.einsum("esf,efd->esd", h, wo,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    sel = jax.nn.one_hot(eid, E_local, dtype=x.dtype)        # [S2, E_local]
    yg = jnp.einsum("esd,se->sd", y_all, sel)                # TP-partial

    back = yg.reshape(n_ep, C2, D)
    ret = jax.lax.all_to_all(back, ep_axes, 0, 0, tiled=False)
    w_flat = topk_w.reshape(Tk).astype(x.dtype)
    gathered = ret.at[dest, slot].get(mode="fill", fill_value=0.0)
    y = jnp.zeros((T, D), x.dtype).at[token_idx].add(
        gathered * (w_flat * keep)[:, None])
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)      # single reduction on [T, D]
    aux = jax.lax.pmean(aux, dp_axes)
    return y, aux


def _moe_ep_shard_pooled(cfg, ep_axes, tp_axis, dp_axes, router_w, table,
                         edest, eslot, pool_i, pool_g, pool_o, x,
                         capacity, n_ep, scales=None):
    """Pooled-store EP shard body (paper vpage-remap in the serving path).

    Differs from ``_moe_ep_shard`` only in *addressing*: the expert → device
    map comes from the page table's (possibly non-contiguous, min-move)
    placement — ``edest``/``eslot`` [E] replace the contiguous
    ``expert // E_local`` arithmetic — and the grouped matmul reads weight
    pages through the local table instead of a dense [E_local, D, F] bank.
    Per-expert math is unchanged, so tokens match the dense path exactly.

    This same indirection is what makes dispatch *replica-aware* for free
    (DESIGN.md §10): when the skew rebalancer replicates a hot expert onto
    extra devices, ``pooled_layout`` simply points ``edest``/``eslot`` at
    the least-loaded byte-identical copy — this body never knows replicas
    exist, and since every copy holds identical bytes, tokens stay
    bit-identical to the unreplicated layout.  ``elm = table.shape[-1]``
    is read from the array, so replication slack (extra table-width slots
    baked at boot) flows through without any kernel change.

    table  [1, Elm] int32   local pool-page per owned expert (this shard)
    pools  [ppd, D|F, F|D]  this device's page pools (all three banks)
    """
    from repro.kernels import ops

    E, k = cfg.num_experts, cfg.top_k
    elm = table.shape[-1]
    T, D = x.shape
    C = capacity

    topk_idx, topk_w, aux = route({"w": router_w}, x, k)
    expert_flat, slot, keep = _dispatch_indices(topk_idx, E, C)
    dest = edest[expert_flat]
    e_loc = eslot[expert_flat]
    token_idx = jnp.repeat(jnp.arange(T), k)

    send = jnp.zeros((n_ep, elm, C, D), x.dtype).at[
        dest, e_loc, slot].set(x[token_idx], mode="drop")
    recv = jax.lax.all_to_all(send, ep_axes, split_axis=0, concat_axis=0,
                              tiled=False)
    xg = recv.transpose(1, 0, 2, 3).reshape(elm, n_ep * C, D)
    t = table[0]
    if scales is not None:
        yg = ops.quant_paged_expert_ffn(t, t, t, pool_i, pool_g, pool_o,
                                        scales[0], scales[1], scales[2], xg)
    else:
        yg = ops.paged_expert_ffn(t, t, t, pool_i, pool_g, pool_o, xg)
    if tp_axis is not None:
        yg = jax.lax.psum(yg, tp_axis)
    back = yg.reshape(elm, n_ep, C, D).transpose(1, 0, 2, 3)
    ret = jax.lax.all_to_all(back, ep_axes, split_axis=0, concat_axis=0,
                             tiled=False)

    w_flat = topk_w.reshape(T * k).astype(x.dtype)
    gathered = ret.at[dest, e_loc, slot].get(mode="fill", fill_value=0.0)
    y = jnp.zeros((T, D), x.dtype).at[token_idx].add(
        gathered * (w_flat * keep)[:, None])
    aux = jax.lax.pmean(aux, dp_axes)
    return y, aux


def _moe_ep_shard_pooled_quant(cfg, ep_axes, tp_axis, dp_axes, router_w,
                               table, edest, eslot, pool_i, pool_g, pool_o,
                               scale_i, scale_g, scale_o, x, capacity, n_ep):
    """Int8 pooled shard body: the three per-page f32 scale banks arrive as
    extra shard_map operands (page-axis sharded like their pools) and feed
    the fused-dequant paged GMM; dispatch/combine are shared."""
    return _moe_ep_shard_pooled(cfg, ep_axes, tp_axis, dp_axes, router_w,
                                table, edest, eslot, pool_i, pool_g, pool_o,
                                x, capacity, n_ep,
                                scales=(scale_i, scale_g, scale_o))


def moe_ep(cfg, p, x, parallel, capacity=None, pool=None,
           return_counts=False):
    """Expert-parallel MoE over a mesh described by ``parallel``
    (repro.distributed.sharding.ParallelCtx).

    x [B, S, D]; tokens are flattened and sharded over ``parallel.ep_axes``
    for dispatch; expert weights are sharded E over ``ep_axes`` and (if
    ``tp_axis`` is set) F over ``tp_axis``.

    ``pool``: the pooled weight store ``{wi, wg, wo}`` when ``p`` carries
    the pooled index arrays (``expert_mode="pooled"``); pools are page-axis
    sharded over ``ep_axes`` and the pooled shard body is used.  Pooled
    mode keeps the expert FFN dim unsharded (the serving engine's
    ``moe_tp=False`` convention — EP spans every device, paper §4.1).
    """
    from jax.sharding import PartitionSpec as P

    mesh = parallel.mesh
    ep_axes = tuple(a for a in parallel.ep_axes if a in mesh.axis_names)
    tp_axis = parallel.tp_axis if (parallel.tp_axis in mesh.axis_names
                                   and parallel.moe_tp) else None
    B, S, D = x.shape
    n_ep = math.prod(mesh.shape[a] for a in ep_axes)
    T = B * S
    T_pad = -(-T // n_ep) * n_ep          # shard_map needs even token shards
    t_local = max(1, T_pad // n_ep)
    pooled = pool is not None and "tables" in p
    packed = (getattr(parallel, "moe_dispatch", "expert_slots") == "packed"
              and not pooled)
    if packed:
        C = capacity or max(1, math.ceil(t_local * cfg.top_k / n_ep
                                         * cfg.capacity_factor))
        shard_body = _moe_ep_shard_packed
    else:
        C = capacity or capacity_for(t_local, cfg)
        shard_body = _moe_ep_shard

    xf = x.reshape(T, D)
    if T_pad != T:
        xf = jnp.pad(xf, ((0, T_pad - T), (0, 0)))
    x_spec = P(ep_axes, None)
    if pooled:
        assert tp_axis is None, \
            "pooled expert store requires moe_tp=False (EP-only sharding)"
        pool_spec = P(ep_axes, None, None)
        if "wi_scale" in pool:
            body = partial(_moe_ep_shard_pooled_quant, cfg, ep_axes, tp_axis,
                           ep_axes, capacity=C, n_ep=n_ep)
            scale_spec = P(ep_axes)
            y, aux = _shard_map(
                body, mesh=mesh,
                in_specs=(P(None, None), P(ep_axes, None), P(None), P(None),
                          pool_spec, pool_spec, pool_spec,
                          scale_spec, scale_spec, scale_spec, x_spec),
                out_specs=(x_spec, P()),
                **_SM_NOCHECK,
            )(p["router"]["w"], p["tables"], p["edest"], p["eslot"],
              pool["wi"], pool["wg"], pool["wo"],
              pool["wi_scale"], pool["wg_scale"], pool["wo_scale"], xf)
        else:
            body = partial(_moe_ep_shard_pooled, cfg, ep_axes, tp_axis,
                           ep_axes, capacity=C, n_ep=n_ep)
            y, aux = _shard_map(
                body, mesh=mesh,
                in_specs=(P(None, None), P(ep_axes, None), P(None), P(None),
                          pool_spec, pool_spec, pool_spec, x_spec),
                out_specs=(x_spec, P()),
                **_SM_NOCHECK,
            )(p["router"]["w"], p["tables"], p["edest"], p["eslot"],
              pool["wi"], pool["wg"], pool["wo"], xf)
    else:
        body = partial(shard_body, cfg, ep_axes, tp_axis, ep_axes,
                       capacity=C, n_ep=n_ep)
        w_spec_if = P(ep_axes, None, tp_axis)
        w_spec_of = P(ep_axes, tp_axis, None)
        y, aux = _shard_map(
            body, mesh=mesh,
            in_specs=(P(None, None), w_spec_if, w_spec_if, w_spec_of, x_spec),
            out_specs=(x_spec, P()),
            **_SM_NOCHECK,
        )(p["router"]["w"], p["wi"], p["wg"], p["wo"], xf)
    if T_pad != T:
        y = y[:T]
    y = y.reshape(B, S, D)
    if "shared" in p:
        from repro.models.layers import mlp_apply
        y = y + mlp_apply(p["shared"], x)
    if return_counts:
        # Telemetry replays the router on the replicated activations outside
        # shard_map (one tiny [T, E] matmul; decode T = B).  Restricting to
        # the first T rows excludes the zero-padding rows, whose uniform
        # softmax would otherwise pollute the first-k experts' bins.
        topk_idx, _, _ = route(p["router"], xf, cfg.top_k)
        counts = routing_counts(topk_idx[:T], cfg.num_experts)
        return y, jnp.mean(aux), counts
    return y, jnp.mean(aux)
