"""Virtual expert pages end-to-end: EP remap via page-table update + the
Pallas paged-GMM kernel consuming the table — no weight buffer is rebuilt.

Shows the O(1) remap: after 'migrating' experts between devices, only the
page table changes and migrated pages are written into free pool slots; the
kernel output is bit-identical.

Run:  PYTHONPATH=src python examples/paged_experts_demo.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.expert_pages import ExpertPageTable
from repro.core.topology import ElasticConfig
from repro.kernels import ops, ref


def main():
    L, E, D, F, C = 1, 8, 64, 128, 128
    pool_pages = 2 * E
    rng = np.random.default_rng(0)
    weights = rng.standard_normal((E, D, F)).astype(np.float32)

    table = ExpertPageTable(L, E, pool_pages)
    c2 = ElasticConfig(dp=1, tp=2, devices=(0, 1))
    table.initial_place(c2)

    # device pools (simulated HBM): page -> weight block
    pools = {d: np.zeros((pool_pages, D, F), np.float32) for d in (0, 1, 2)}
    for (l, e), pr in table.active.items():
        pools[pr.device][pr.page] = weights[e]

    def run_device(d, x):
        owned = sorted(e for (l, e), pr in table.active.items()
                       if pr.device == d)
        pages = jnp.asarray([table.active[(0, e)].page for e in owned],
                            jnp.int32)
        out = ops.paged_gmm(pages, jnp.asarray(pools[d]), x[jnp.asarray(owned)])
        return dict(zip(owned, out))

    x = jnp.asarray(rng.standard_normal((E, C, D)), jnp.float32)
    before = {}
    for d in (0, 1):
        before.update(run_device(d, x))

    print("scaling EP2 -> EP3 (min-move page remap) ...")
    c3 = ElasticConfig(dp=1, tp=3, devices=(0, 1, 2))
    migrations = table.stage_remap(c3)
    print(f"  migrations: {len(migrations)} of {E} experts "
          f"(only the imbalance moves)")
    for m in migrations:          # p2p-copy pages into free slots
        pools[m.dst.device][m.dst.page] = pools[m.src.device][m.src.page]
    table.commit()

    after = {}
    for d in (0, 1, 2):
        after.update(run_device(d, x))
    for e in range(E):
        np.testing.assert_array_equal(np.asarray(before[e]),
                                      np.asarray(after[e]))
    want = ref.paged_gmm_ref(jnp.arange(E, dtype=jnp.int32),
                             jnp.asarray(weights), x)
    got = jnp.stack([after[e] for e in range(E)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("  outputs bit-identical across the remap; kernel matches oracle")
    print("  placement:", {d: sorted(e for (l, e), pr in table.active.items()
                                     if pr.device == d) for d in (0, 1, 2)})


if __name__ == "__main__":
    main()
