"""End-to-end elastic serving driver: bursty traffic + SLO-aware autoscaler.

The Coordinator's load estimator watches windowed SLO attainment and queue
depth; on violations it scales up (4->6->8 devices), on idle it scales down —
the full paper §5 lifecycle, on real JAX host devices.

Run:  PYTHONPATH=src python examples/elastic_serving.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.coordinator import ScalingPolicy
from repro.core.elastic_engine import ElasticServer
from repro.core.topology import ElasticConfig
from repro.serving.metrics import SLO, summarize
from repro.serving.workload import Request


def main():
    mcfg = ModelConfig(
        name="elastic-moe", arch_type="moe", num_layers=2, d_model=64,
        vocab_size=256, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        num_experts=24, top_k=2, moe_d_ff=32, dtype="float32",
        capacity_factor=100.0)
    slo = SLO(ttft_s=1.5, tpot_s=0.5)
    policy = ScalingPolicy(slo=slo, window=8, cooldown_s=3.0,
                           queue_scale_up=3)
    srv = ElasticServer(mcfg, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), policy=policy, seed=0)
    ladder = [ElasticConfig(dp=d, tp=2, devices=tuple(range(2 * d)))
              for d in (2, 3, 4)]
    srv.boot(ladder[0])
    for cfg in ladder[1:]:
        srv.preinitialize(cfg)     # standby instances (IMM LRU)
    level = 0

    # bursty arrivals: calm -> burst -> calm
    rng = np.random.default_rng(1)
    reqs = []
    rid = 0
    for t_arr, n in [(0.0, 2), (1.0, 1), (2.0, 8), (2.3, 6), (6.0, 1)]:
        for _ in range(n):
            reqs.append(Request(rid, t_arr, 16, int(rng.integers(10, 24)),
                                prompt=rng.integers(0, 256, 16)))
            rid += 1

    t, i, done = 0.0, 0, 0
    while done < len(reqs):
        while i < len(reqs) and reqs[i].arrival_s <= t:
            srv.submit(reqs[i]); i += 1
        decision = srv.autoscale_decision(t)
        if decision == "up" and level + 1 < len(ladder):
            level += 1
            print(f"[t={t:5.2f}] SCALE UP -> {ladder[level].describe()}")
            srv.stage_scale(ladder[level])
            srv.tick(t); t += 0.05          # keep serving while staging
            srv.switchover()
        elif decision == "down" and level > 0:
            tgt = ladder[level - 1]
            keep = tgt.dp * srv.engine.batch_per_replica
            srv.stage_scale(tgt)
            while not srv.engine.drained(keep):
                done += len(srv.tick(t)); t += 0.05
            srv.switchover()
            level -= 1
            print(f"[t={t:5.2f}] SCALE DOWN -> {ladder[level].describe()}")
        done += len(srv.tick(t))
        t += 0.05
        if t > 120:
            raise RuntimeError("stalled")

    print("\nscale events:")
    for ev in srv.events:
        print(f"  {ev.src} -> {ev.dst}: zero-copy "
              f"{ev.stats.zero_copy_bytes/1e6:.1f}MB, p2p "
              f"{ev.stats.p2p_bytes/1e6:.1f}MB, stage {ev.stage_s:.2f}s")
    print("\nsummary:", summarize(reqs, slo))
    print("final config:", srv.hmm.active_cfg.describe())


if __name__ == "__main__":
    main()
