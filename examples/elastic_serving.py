"""Closed-loop elastic serving: bursty traffic + the ClusterDriver.

Unlike the scripted quickstart, nothing here issues a scale command: the
SLO-aware LoadEstimator watches windowed attainment and queue depth, the
ClusterDriver picks the next config with the cost model and executes it as a
resumable ScalingTask polled once per engine tick — and with
``staging="overlap"`` the weight transfers ride the HMM's background
TransferEngine, so tokens keep flowing *concurrently* with the memory ops
through the whole reconfiguration (paper §4.3 + §5, on real JAX host
devices; DESIGN.md §3).

The same ``ClusterDriver.run`` loop drives the paper-scale discrete-event
simulator — see benchmarks/slo_dynamics.py.

Run:  PYTHONPATH=src python examples/elastic_serving.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from repro.configs.base import ModelConfig
from repro.core.coordinator import ScalingPolicy
from repro.core.elastic_engine import ElasticServer
from repro.core.topology import ElasticConfig
from repro.serving.driver import ClusterDriver, DriverConfig
from repro.serving.metrics import SLO, summarize
from repro.serving.workload import scripted_burst


def main():
    mcfg = ModelConfig(
        name="elastic-moe", arch_type="moe", num_layers=2, d_model=64,
        vocab_size=256, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        num_experts=24, top_k=2, moe_d_ff=32, dtype="float32",
        capacity_factor=100.0)
    slo = SLO(ttft_s=1.5, tpot_s=0.5)
    policy = ScalingPolicy(slo=slo, window=8, cooldown_s=3.0,
                           queue_scale_up=3)
    srv = ElasticServer(mcfg, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), seed=0, staging="overlap")
    srv.boot(ElasticConfig(dp=2, tp=2, devices=(0, 1, 2, 3)))
    # standby instance for the anticipated next rung (IMM LRU)
    srv.preinitialize(ElasticConfig(dp=3, tp=2, devices=(0, 1, 2, 3, 4, 5)))

    driver = ClusterDriver(
        srv, policy, mcfg=mcfg, tp=2, device_pool=range(8),
        config=DriverConfig(dt=0.05, settle_s=2.0, min_dp=2))

    # bursty arrivals: calm -> burst -> calm
    reqs = scripted_burst([(0.0, 2), (1.0, 1), (2.0, 8), (2.3, 6), (6.0, 1)],
                          prompt_len=16, output_range=(10, 24),
                          vocab_size=256, seed=1)
    until = 0.0
    while any(r.finish_s is None for r in reqs):
        until += 5.0
        driver.run(reqs if until == 5.0 else [], until=until)
        if until > 120:
            raise RuntimeError("stalled")

    print("driver decisions:")
    for de in driver.events:
        print(f"  [t={de.t:5.2f}] {de.direction.upper():4s} {de.src} -> "
              f"{de.dst} (projected {de.projected_scale_s:.2f}s at scale)")
    print("\nexecuted scale events:")
    for ev in srv.events:
        print(f"  {ev.src} -> {ev.dst}: zero-copy "
              f"{ev.stats.zero_copy_bytes/1e6:.1f}MB, p2p "
              f"{ev.stats.p2p_bytes/1e6:.1f}MB, stage {ev.stage_s:.2f}s, "
              f"serve-loop stall {ev.stall_s:.3f}s, "
              f"compile hit: {ev.compile_hit}")
    print("\nsummary:", summarize(reqs, slo, backend=srv))
    print("final config:", srv.hmm.active_cfg.describe())


if __name__ == "__main__":
    main()
