"""Quickstart: boot an elastic MoE serving instance, serve a few requests,
scale up 4->6 devices with zero downtime, keep serving.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/quickstart.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.elastic_engine import ElasticServer
from repro.core.topology import ElasticConfig
from repro.serving.workload import Request


def main():
    mcfg = ModelConfig(
        name="quickstart-moe", arch_type="moe", num_layers=2, d_model=64,
        vocab_size=256, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        num_experts=24, top_k=2, moe_d_ff=32, dtype="float32",
        capacity_factor=100.0)

    srv = ElasticServer(mcfg, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), seed=0)
    c4 = ElasticConfig(dp=2, tp=2, devices=(0, 1, 2, 3))
    c6 = ElasticConfig(dp=3, tp=2, devices=(0, 1, 2, 3, 4, 5))

    print("booting DP2-TP2-EP4 on 4 devices ...")
    srv.boot(c4)
    print("pre-initializing the anticipated 6-device config (IMM standby) ...")
    srv.preinitialize(c6)

    rng = np.random.default_rng(0)
    for i in range(4):
        srv.submit(Request(i, 0.0, 16, 20, prompt=rng.integers(0, 256, 16)))

    t = 0.0
    for tick in range(6):
        srv.tick(t); t += 0.1

    print("scaling up to DP3-TP2-EP6 while serving ...")
    ev = srv.stage_scale(c6)      # concurrent: weights staged, engine live
    srv.tick(t); t += 0.1         # <- a decode step DURING scaling
    srv.switchover()              # drain-free handover, shared KV cache
    print(f"  zero-copied {ev.stats.zero_copy_bytes/1e6:.1f} MB, "
          f"P2P-moved {ev.stats.p2p_bytes/1e6:.1f} MB, "
          f"stage {ev.stats.wall_s:.2f}s, switch {ev.switch_s:.2f}s, "
          f"compile cache hit: {ev.compile_hit}")

    while any(r.finish_s is None for r in srv.requests.values()):
        srv.tick(t); t += 0.1
    for rid, toks in sorted(srv.engine.generated.items()):
        print(f"  request {rid}: {len(toks)} tokens, first 8: {toks[:8]}")
    print(f"now serving on {srv.hmm.active_cfg.describe()}")


if __name__ == "__main__":
    main()
