"""Train a ~100M-param MoE (deepseek-v2-lite family, scaled down) for a few
hundred steps on synthetic data — exercises the full training substrate
(model zoo, router aux loss, AdamW, data pipeline, checkpointing).

Run:  PYTHONPATH=src python examples/train_moe.py [--steps 300]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.training import checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_moe_ckpt.npz")
    args = ap.parse_args()

    # ~100M-param member of the deepseek-v2-lite family
    cfg = ModelConfig(
        name="dsv2-lite-100m", arch_type="moe", num_layers=6, d_model=384,
        vocab_size=8192, num_heads=6, num_kv_heads=6, d_ff=1024,
        num_experts=8, top_k=2, moe_d_ff=256, num_shared_experts=1,
        first_k_dense=1, use_mla=True, kv_lora_rank=128, qk_nope_dim=48,
        qk_rope_dim=16, v_head_dim=64, dtype="float32")
    n = cfg.param_count()
    print(f"model: {cfg.name}  params={n/1e6:.1f}M "
          f"(active {cfg.param_count(active_only=True)/1e6:.1f}M)")

    out = train(cfg, steps=args.steps, batch=args.batch, seq_len=args.seq,
                opt=AdamWConfig(lr=1e-3, warmup_steps=30,
                                total_steps=args.steps),
                log_every=max(args.steps // 15, 1))
    first, last = out["history"][0][1], out["history"][-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} in {args.steps} steps "
          f"({out['wall_s']:.0f}s)")
    assert last < first, "training did not improve"
    checkpoint.save(args.ckpt, out["params"])
    print(f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
