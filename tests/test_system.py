"""End-to-end behaviour test: elastic serving under autoscaling policy —
boots small, load spikes, SLO-aware estimator triggers scale-up, service
continues uninterrupted (subprocess, 8 host devices)."""
import pytest

from helpers import TEST_MOE, run_with_devices

pytestmark = pytest.mark.slow


def test_autoscaled_serving_end_to_end():
    out = run_with_devices(TEST_MOE + """
import numpy as np
from repro.core.coordinator import ScalingPolicy
from repro.core.elastic_engine import ElasticServer
from repro.core.topology import ElasticConfig
from repro.serving.metrics import SLO, summarize
from repro.serving.workload import Request

policy = ScalingPolicy(slo=SLO(ttft_s=1.0, tpot_s=1.0), window=8,
                       cooldown_s=0.0, queue_scale_up=3)
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), policy=policy, seed=0)
c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
srv.boot(c4)
srv.preinitialize(c6)

rng = np.random.default_rng(0)
reqs = [Request(i, 0.05*i, 16, 12, prompt=rng.integers(0,128,16))
        for i in range(12)]
t, n, scaled = 0.0, 0, False
pending = list(reqs)
served_during_scale = 0
while any(r.finish_s is None for r in reqs):
    while pending and pending[0].arrival_s <= t:
        srv.submit(pending.pop(0))
    if not scaled and srv.autoscale_decision(t) == "up":
        srv.stage_scale(c6)
        served_during_scale += len(srv.tick(t)); t += 0.05
        srv.switchover()
        scaled = True
        continue
    srv.tick(t); t += 0.05; n += 1
    assert n < 2000
assert scaled, "autoscaler never triggered"
assert srv.engine.num_slots == 6
s = summarize(reqs)
assert s["finished"] == 12
print("E2E-OK", s)
""")
    assert "E2E-OK" in out
