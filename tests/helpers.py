"""Test helpers: multi-device subprocess runner.

The main pytest session keeps the default 1 CPU device (per the brief);
elastic/distributed tests spawn a subprocess with
``--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, ndev: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={r.returncode})\n--- stdout:\n"
            f"{r.stdout}\n--- stderr:\n{r.stderr}")
    return r.stdout


# Shared tiny MoE model used by the elastic integration tests: 24 experts so
# EP degrees 4, 6 and 8 all divide evenly.
TEST_MOE = """
from repro.configs.base import ModelConfig
MCFG = ModelConfig(name="test-moe", arch_type="moe", num_layers=2, d_model=64,
                   vocab_size=128, num_heads=4, num_kv_heads=4, head_dim=16,
                   d_ff=128, num_experts=24, top_k=2, moe_d_ff=32,
                   dtype="float32", capacity_factor=100.0)
"""
