"""Property tests on the cost model and topology helpers."""
import math

import pytest
pytest.importorskip("hypothesis")  # optional test extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core.costmodel import plan_cost
from repro.core.scaling_plan import STRATEGIES, Op, plan_elastic
from repro.core.topology import ElasticConfig, kv_cache_bytes, model_tensors

MCFG = get_config("qwen3-30b-a3b")
TENSORS = model_tensors(MCFG, tp=2,
                        kv_bytes_per_replica=kv_cache_bytes(MCFG, 8, 4096))

sizes = st.sampled_from([2, 4, 8, 16])


def cfg_of(n, base=0):
    return ElasticConfig(dp=n // 2, tp=2,
                         devices=tuple(range(base, base + n)))


@settings(max_examples=15, deadline=None)
@given(n0=sizes, n1=sizes)
def test_elastic_fastest_and_never_downtime(n0, n1):
    """Elastic has the lowest projected latency of all feasible strategies
    and zero downtime; cold restart always has downtime."""
    from repro.core.scaling_plan import placement
    old, new = cfg_of(n0), cfg_of(n1)
    resident = {d: sum(s.values())
                for d, s in placement(TENSORS, old).items()}
    ce = plan_cost(plan_elastic(TENSORS, old, new),
                   resident_bytes_per_device=resident)
    assert ce.downtime_s == 0
    cc = plan_cost(STRATEGIES["cold_restart"](TENSORS, old, new),
                   strategy="cold_restart", resident_bytes_per_device=resident)
    assert cc.downtime_s > 0
    assert ce.scale_time_s < cc.scale_time_s
    cv = plan_cost(STRATEGIES["colocated"](TENSORS, old, new),
                   strategy="colocated", resident_bytes_per_device=resident)
    assert ce.scale_time_s < cv.scale_time_s
    # colocated doubles weights on shared devices -> strictly higher peak
    assert cv.peak_mem_gb > ce.peak_mem_gb


@settings(max_examples=15, deadline=None)
@given(n=sizes)
def test_peak_memory_monotone_in_resident(n):
    old, new = cfg_of(n), cfg_of(min(n * 2, 32))
    plan = plan_elastic(TENSORS, old, new)
    c0 = plan_cost(plan)
    c1 = plan_cost(plan, resident_bytes_per_device={d: 10 ** 9
                                                    for d in old.devices})
    assert c1.peak_mem_gb >= c0.peak_mem_gb


@settings(max_examples=20, deadline=None)
@given(batch=st.integers(1, 64), length=st.sampled_from([512, 4096, 32768]))
def test_kv_bytes_linear_in_batch_and_length(batch, length):
    one = kv_cache_bytes(MCFG, 1, length)
    assert kv_cache_bytes(MCFG, batch, length) == batch * one
    assert kv_cache_bytes(MCFG, batch, 2 * length) \
        == 2 * kv_cache_bytes(MCFG, batch, length)


def test_ssm_kv_bytes_constant_in_length():
    ssm = get_config("mamba2-1.3b")
    assert kv_cache_bytes(ssm, 4, 1024) == kv_cache_bytes(ssm, 4, 524288)


@settings(max_examples=15, deadline=None)
@given(n=sizes)
def test_elastic_config_ranks(n):
    cfg = cfg_of(n)
    assert cfg.ep == n
    for d in cfg.devices:
        assert cfg.slot(d) == cfg.dp_rank(d) * cfg.tp + cfg.tp_rank(d)
