"""Int8 quantized KV blocks & expert pages (DESIGN.md §11) — the
dequant-parity and exactness suite pinned by the quantization PR.

Fast (single device):

* ``quantize_rows`` round-trip error is bounded by scale/2 per element;
* each fused-dequant Pallas kernel (interpret mode) matches its
  dequant-then-delegate jnp oracle tightly, and the int8 path tracks the
  f32 kernel within the quantization tolerance;
* remap invariance: permuting int8 pool rows TOGETHER with their scale
  rows and rewriting the tables leaves outputs bit-identical — the
  zero-copy vpage remap is exact on quantized pools;
* the engine's CoW block copy moves a quantized block's scale rows with
  its int8 entries;
* the ``_clamp_block_f`` non-128-divisible lane fallback warns (and stays
  correct) on both f32 and int8 pools;
* pooled int8 experts reproduce the dense f32 MoE block within tolerance
  through the model layer.

Slow (subprocess, 8 host devices): int8 KV + int8 experts serve end to
end across a live scale-up; every expert page (entries AND scales)
survives migration + zero-copy remap bit-identically; surviving KV pool
rows are adopted bit-identically by cache growth; byte accounting
(engine block_nbytes, expert_page_nbytes, TransferStats) matches the
quantized projections exactly.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from helpers import TEST_MOE, run_with_devices

from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.quant import dequantize_rows, quantize_rows

RNG = np.random.default_rng(7)

TEST_MOE_CFG = None


def _mcfg():
    global TEST_MOE_CFG
    if TEST_MOE_CFG is None:
        ns = {}
        exec(TEST_MOE, ns)
        TEST_MOE_CFG = ns["MCFG"]
    return TEST_MOE_CFG


def _f32(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


def rel_err(got, want):
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    return np.linalg.norm(got - want) / max(np.linalg.norm(want), 1e-12)


def _quant_pool(n_pages, d, f):
    w = _f32(n_pages, d, f)
    q, s = quantize_rows(w, (-2, -1))
    return w, q, s


# ------------------------------------------------------------ quantize_rows

def test_quantize_rows_roundtrip_error_bound():
    x = _f32(6, 4, 16)
    q, s = quantize_rows(x, (-2, -1))
    assert q.dtype == jnp.int8 and s.shape == (6,) and s.dtype == jnp.float32
    y = dequantize_rows(q, s, (-2, -1))
    bound = np.asarray(s)[:, None, None] * 0.5 + 1e-6
    assert (np.abs(np.asarray(y) - np.asarray(x)) <= bound).all()


def test_quantize_rows_zero_rows_stay_finite():
    q, s = quantize_rows(jnp.zeros((3, 8)), (-1,))
    assert np.isfinite(np.asarray(s)).all()
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(np.asarray(dequantize_rows(q, s, (-1,))), 0)


# -------------------------------------------------- kernel vs oracle parity

def test_quant_paged_gmm_kernel_matches_ref():
    w, qp, sp = _quant_pool(8, 32, 128)
    table = jnp.asarray(RNG.permutation(8)[:3], jnp.int32)
    x = _f32(3, 96, 32)                     # C % block_c -> zero-pad path
    got = ops.quant_paged_gmm(table, qp, sp, x, impl="kernel",
                              block_c=64, block_f=128)
    want = R.quant_paged_gmm_ref(table, qp, sp, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # the int8 path tracks the unquantized f32 pool within quant tolerance
    assert rel_err(got, R.paged_gmm_ref(table, w, x)) < 2e-2


def test_quant_paged_expert_ffn_kernel_matches_ref():
    wi, qi, si = _quant_pool(6, 64, 128)
    wg, qg, sg = _quant_pool(6, 64, 128)
    wo, qo, so = _quant_pool(6, 128, 64)
    ti = jnp.asarray([4, 0], jnp.int32)
    tg = jnp.asarray([1, 5], jnp.int32)
    to = jnp.asarray([3, 2], jnp.int32)
    x = _f32(2, 64, 64)
    got = ops.quant_paged_expert_ffn(ti, tg, to, qi, qg, qo, si, sg, so, x,
                                     impl="kernel", block_c=64, block_f=128)
    want = R.quant_paged_expert_ffn_ref(ti, tg, to, qi, qg, qo,
                                        si, sg, so, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
    assert rel_err(got, R.paged_expert_ffn_ref(ti, tg, to, wi, wg, wo, x)) \
        < 5e-2


def _quant_kv(nb, bs, kvh, hd):
    kp, vp = _f32(nb, bs, kvh, hd), _f32(nb, bs, kvh, hd)
    kq, ks = quantize_rows(kp, (-2, -1))
    vq, vs = quantize_rows(vp, (-2, -1))
    return kp, vp, kq, ks, vq, vs


def test_quant_block_paged_decode_kernel_matches_ref():
    B, H, KVH, hd, nb, bs, MB = 4, 8, 4, 64, 16, 16, 4
    kp, vp, kq, ks, vq, vs = _quant_kv(nb, bs, KVH, hd)
    q = _f32(B, H, hd)
    bt = jnp.asarray(RNG.permutation(nb)[:B * MB].reshape(B, MB), jnp.int32)
    lengths = jnp.asarray([64, 37, 16, 1], jnp.int32)
    got = ops.quant_block_paged_decode_attention(q, kq, ks, vq, vs, bt,
                                                 lengths, impl="kernel")
    want = R.quant_block_paged_decode_attention_ref(q, kq, ks, vq, vs, bt,
                                                    lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
    f32 = R.block_paged_decode_attention_ref(q, kp, vp, bt, lengths)
    assert rel_err(got, f32) < 2e-2


def test_quant_mixed_block_paged_kernel_matches_ref():
    B, Sq, H, KVH, hd, nb, bs, MB = 2, 8, 8, 4, 64, 16, 16, 4
    kp, vp, kq, ks, vq, vs = _quant_kv(nb, bs, KVH, hd)
    q = _f32(B, Sq, H, hd)
    bt = jnp.asarray(RNG.permutation(nb)[:B * MB].reshape(B, MB), jnp.int32)
    ctx = jnp.asarray([40, 9], jnp.int32)
    qlen = jnp.asarray([8, 1], jnp.int32)
    got = ops.quant_mixed_block_paged_attention(q, kq, ks, vq, vs, bt, ctx,
                                                qlen, impl="kernel")
    want = R.quant_mixed_block_paged_attention_ref(q, kq, ks, vq, vs, bt,
                                                   ctx, qlen)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
    f32 = R.mixed_block_paged_attention_ref(q, kp, vp, bt, ctx, qlen)
    assert rel_err(got, f32) < 2e-2


# ------------------------------------------------- zero-copy remap exactness

def test_quant_paged_gmm_remap_invariance():
    """Permuting int8 pages TOGETHER with their scale rows and rewriting
    the table is invisible to the kernel — the vpage remap moves no bytes
    and changes no bits on a quantized pool."""
    _, qp, sp = _quant_pool(8, 32, 128)
    table = jnp.asarray([5, 1, 7], jnp.int32)
    x = _f32(3, 64, 32)
    base = ops.quant_paged_gmm(table, qp, sp, x, impl="kernel")
    perm = RNG.permutation(8)
    inv = np.argsort(perm)
    got = ops.quant_paged_gmm(
        jnp.asarray(inv[np.asarray(table)], jnp.int32),
        jnp.asarray(np.asarray(qp)[perm]),
        jnp.asarray(np.asarray(sp)[perm]), x, impl="kernel")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_quant_block_paged_decode_remap_invariance():
    B, H, KVH, hd, nb, bs, MB = 4, 8, 4, 64, 16, 16, 4
    _, _, kq, ks, vq, vs = _quant_kv(nb, bs, KVH, hd)
    q = _f32(B, H, hd)
    bt = jnp.asarray(RNG.permutation(nb)[:B * MB].reshape(B, MB), jnp.int32)
    lengths = jnp.asarray([64, 37, 16, 1], jnp.int32)
    base = ops.quant_block_paged_decode_attention(q, kq, ks, vq, vs, bt,
                                                  lengths, impl="kernel")
    perm = RNG.permutation(nb)
    inv = np.argsort(perm)
    shuf = [jnp.asarray(np.asarray(a)[perm]) for a in (kq, ks, vq, vs)]
    got = ops.quant_block_paged_decode_attention(
        q, shuf[0], shuf[1], shuf[2], shuf[3],
        jnp.asarray(inv[np.asarray(bt)], jnp.int32), lengths, impl="kernel")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_cow_copy_moves_quant_scales_with_entries():
    """The engine's jitted CoW block copy is a tree.map over the cache
    dict: on a quantized pool the per-token scale rows must travel with
    the int8 entries, and untouched blocks must not change."""
    import repro.core  # noqa: F401  (core/__init__ -> imm -> engine cycle)
    from repro.models.model import init_paged_cache
    from repro.serving.engine import _cow_copy

    mcfg = _mcfg()
    cache = init_paged_cache(mcfg, 8, 16, kv_dtype="int8")
    assert set(cache) == {"k", "v", "k_scale", "v_scale"}
    cache = {
        "k": jnp.asarray(RNG.integers(-127, 128, cache["k"].shape), jnp.int8),
        "v": jnp.asarray(RNG.integers(-127, 128, cache["v"].shape), jnp.int8),
        "k_scale": jnp.asarray(
            RNG.random(cache["k_scale"].shape), jnp.float32),
        "v_scale": jnp.asarray(
            RNG.random(cache["v_scale"].shape), jnp.float32)}
    before = {k: np.asarray(v).copy() for k, v in cache.items()}
    out = _cow_copy(cache, jnp.asarray(2, jnp.int32),
                    jnp.asarray(5, jnp.int32))
    for name, old in before.items():
        new = np.asarray(out[name])
        np.testing.assert_array_equal(new[:, 5], old[:, 2], err_msg=name)
        keep = [b for b in range(8) if b != 5]
        np.testing.assert_array_equal(new[:, keep], old[:, keep],
                                      err_msg=name)


# ------------------------------------- non-128-divisible lane dim (satellite)

def test_paged_gmm_unaligned_f_warns_and_stays_correct_f32():
    pool = _f32(4, 32, 192)                 # no 128-aligned block divides 192
    table = jnp.asarray([3, 1], jnp.int32)
    x = _f32(2, 16, 32)
    with pytest.warns(UserWarning, match="128-aligned"):
        got = ops.paged_gmm(table, pool, x, block_f=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(R.paged_gmm_ref(table, pool, x)),
                               rtol=5e-4, atol=5e-4)


def test_paged_gmm_unaligned_f_warns_and_stays_correct_int8():
    _, qp, sp = _quant_pool(4, 32, 192)
    table = jnp.asarray([0, 2], jnp.int32)
    x = _f32(2, 16, 32)
    with pytest.warns(UserWarning, match="128-aligned"):
        got = ops.quant_paged_gmm(table, qp, sp, x, impl="kernel",
                                  block_f=128)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(R.quant_paged_gmm_ref(table, qp, sp, x)),
        rtol=1e-4, atol=1e-4)


# ------------------------------------------------- model-layer dequant parity

def test_moe_local_pooled_int8_tracks_dense_f32():
    """Pooled int8 experts through the model layer: ``moe_local_pooled``
    detects the ``*_scale`` banks and routes through the fused-dequant
    FFN; the output tracks the dense f32 MoE block within the
    quantization tolerance."""
    from repro.core.expert_pages import ExpertPageTable, pooled_layout
    from repro.core.topology import ElasticConfig
    from repro.models.moe import moe_init, moe_local, moe_local_pooled

    mcfg = _mcfg()
    cfg = ElasticConfig(dp=1, tp=1, devices=(0,))
    E, L = mcfg.num_experts, mcfg.num_layers
    ppd = L * E
    p = moe_init(jax.random.PRNGKey(0), mcfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, mcfg.d_model))
    y_ref, _ = moe_local(mcfg, p, x)

    t = ExpertPageTable(L, E, pool_pages_per_device=ppd)
    t.initial_place(cfg)
    lay = pooled_layout(t.active, cfg, L, E, ppd)
    pool = {k: np.zeros((cfg.ndev * ppd,) + np.asarray(p[k]).shape[1:],
                        np.int8) for k in ("wi", "wg", "wo")}
    scales = {k: np.zeros((cfg.ndev * ppd,), np.float32)
              for k in ("wi", "wg", "wo")}
    for (l, e), ref in t.active.items():
        if l == 0:
            row = cfg.slot(ref.device) * ppd + ref.page
            for k in pool:
                q, s = quantize_rows(jnp.asarray(p[k])[e], (-2, -1))
                pool[k][row] = np.asarray(q)
                scales[k][row] = float(s)
    pp = {"router": p["router"],
          **{k: jnp.asarray(v[0]) for k, v in lay.items()}}
    qpool = {**{k: jnp.asarray(v) for k, v in pool.items()},
             **{k + "_scale": jnp.asarray(v) for k, v in scales.items()}}
    y_q, _ = moe_local_pooled(mcfg, pp, qpool, x)
    assert rel_err(y_q, y_ref) < 5e-2


# --------------------------------------------------- slow subprocess serving

QUANT_COMMON = TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request

c2 = ElasticConfig(dp=1, tp=2, devices=(0,1))
c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))

def serve(kv_dtype=None, expert_dtype=None, scale=True, hook=None):
    srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), seed=0,
                        expert_mode="pooled", kv_mode="paged",
                        kv_block_size=16, kv_dtype=kv_dtype,
                        expert_dtype=expert_dtype)
    srv.boot(c2)
    rng = np.random.default_rng(0)
    reqs = [Request(i, 0.0, 16, 32, prompt=rng.integers(0, 128, 16))
            for i in range(3)]
    for r in reqs: srv.submit(r)
    t, n, task = 0.0, 0, None
    while any(r.finish_s is None for r in reqs):
        if scale and n == 4 and task is None:
            if hook is not None: hook(srv)
            task = srv.start_scale(c4)
        srv.tick(t); t += .1; n += 1
        if task is not None and not task.done:
            task.advance(t)
        assert n < 500
    while task is not None and not task.done:
        srv.tick(t); task.advance(t); t += .1
    return srv, task

def pool_snapshot(srv):
    # {(layer, expert): {bank: row}} straight off the device pool, keyed by
    # the LOGICAL page — rows may move across a scale event, contents not
    banks = {k: np.asarray(v) for k, v in srv.hmm.params["moe_pool"].items()}
    cfg = srv.hmm.active_cfg
    ppd = next(iter(banks.values())).shape[0] // cfg.ndev
    out = {}
    for (l, e), ref in srv.hmm.page_table.active.items():
        row = cfg.slot(ref.device) * ppd + ref.page
        out[(l, e)] = {k: v[row] for k, v in banks.items()}
    return out
"""


@pytest.mark.slow
def test_quant_serving_scaleup_bytes_and_page_exactness():
    """Int8 KV + int8 experts serve end to end across a live 2->4 scale
    event; every expert page (int8 entries AND f32 scales) survives
    migration + zero-copy remap bit-identically; TransferStats /
    block_nbytes / expert_page_nbytes all match the quantized
    projections exactly, at ~4x below the f32 run."""
    out = run_with_devices(QUANT_COMMON + """
from repro.serving.kv_blocks import block_bytes

snaps = {}
srv, task = serve(kv_dtype="int8", expert_dtype="int8",
                  hook=lambda s: snaps.update(before=pool_snapshot(s)))
fsrv, ftask = serve()

# quantized pool layouts: int8 banks + f32 scale sidecars, int8 KV pools
pool = srv.hmm.params["moe_pool"]
assert {str(pool[k].dtype) for k in ("wi", "wg", "wo")} == {"int8"}
assert {str(pool[k + "_scale"].dtype) for k in ("wi", "wg", "wo")} \\
    == {"float32"}
assert str(srv.engine.cache["k"].dtype) == "int8"
assert "k_scale" in srv.engine.cache

# byte accounting agrees with the quantized projections exactly
page_q, page_f = srv.hmm.expert_page_nbytes(), fsrv.hmm.expert_page_nbytes()
assert page_q == 3 * (64 * 32 * 1 + 4), page_q          # int8 + f32 scale
assert page_f == 3 * 64 * 32 * 4, page_f
assert srv.engine.block_nbytes() == block_bytes(MCFG, 16, kv_dtype="int8")
assert fsrv.engine.block_nbytes() == block_bytes(MCFG, 16)
st, stf = task.stage_stats, ftask.stage_stats
assert st.expert_p2p_bytes == len(srv.hmm.last_migrations) * page_q
assert stf.expert_p2p_bytes == len(fsrv.hmm.last_migrations) * page_f
assert st.expert_p2p_bytes * 3 < stf.expert_p2p_bytes   # ~3.9x cheaper

# every expert page survived the scale event bit-identically — entries
# and scale sidecars moved together through migration + remap
after = pool_snapshot(srv)
before = snaps["before"]
assert set(after) == set(before) and before
for key in sorted(before):
    for bank in before[key]:
        np.testing.assert_array_equal(after[key][bank], before[key][bank],
                                      err_msg=str((key, bank)))
print("QUANT-SCALEUP-OK", len(srv.hmm.last_migrations),
      st.expert_p2p_bytes, stf.expert_p2p_bytes)
""")
    assert "QUANT-SCALEUP-OK" in out


@pytest.mark.slow
def test_quant_scaledown_migration_tokens_exact_bytes_quantized():
    """Zero-drain scale-down on the fully quantized backend: live int8 KV
    blocks (entries + scale rows, one jitted CoW copy per block) migrate
    off the doomed partition mid-decode and every token matches an
    unscaled run at the target config bit for bit — migrated quantized
    blocks are provably intact.  Migration bytes are accounted at the
    quantized block size."""
    out = run_with_devices(QUANT_COMMON + """
from repro.serving.kv_blocks import block_bytes

c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
OUTS = [6, 6, 30, 30, 60, 60]

def run(scale):
    srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), seed=0,
                        expert_mode="pooled", kv_mode="paged",
                        kv_block_size=16, kv_dtype="int8",
                        expert_dtype="int8")
    assert srv.scaledown_mode == "migrate"
    srv.boot(c6 if scale else c4)
    rng = np.random.default_rng(0)
    reqs = [Request(i, 0.0, 16, o, prompt=rng.integers(0, 128, 16))
            for i, o in enumerate(OUTS)]
    for r in reqs: srv.submit(r)
    t, n, task = 0.0, 0, None
    while any(r.finish_s is None for r in reqs):
        if scale and n == 10 and task is None:
            assert all(srv.engine.slots[s].active for s in (4, 5))
            task = srv.start_scale(c4)
        srv.tick(t); t += .1; n += 1
        if task is not None and not task.done:
            task.advance(t)
        assert n < 2000, [r.finish_s for r in reqs]
    return {r.rid: srv.engine.generated[r.rid] for r in reqs}, srv, task

ref, _, _ = run(scale=False)
got, srv, task = run(scale=True)
assert srv.hmm.active_cfg.ndev == 4
assert task.migrated_blocks > 0
assert srv.engine.block_nbytes() == block_bytes(MCFG, 16, kv_dtype="int8")
assert task.migration_bytes == task.migrated_blocks * \\
    srv.engine.block_nbytes()
assert srv.engine.preemptions == 0          # migrated, not recomputed
srv.hmm.kv_blocks.check_invariants()
for rid in ref:
    assert ref[rid] == got[rid], rid
print("QUANT-MIGRATE-OK", task.migrated_blocks, task.migration_bytes)
""")
    assert "QUANT-MIGRATE-OK" in out


@pytest.mark.slow
def test_quant_matrix_serves_and_driver_projects_quant_bytes():
    """The (int8 KV | f32) x (int8 experts | f32) matrix all serves to
    completion on the same workload, and the driver's transition-cost
    projection adopts the backend dtypes (quantized arms project
    strictly fewer scale-up bytes)."""
    out = run_with_devices(QUANT_COMMON + """
from repro.core.coordinator import ScalingPolicy
from repro.serving.driver import ClusterDriver, transition_cost
from repro.serving.metrics import SLO
from repro.serving.kv_blocks import block_bytes

arms = {"f32": (None, None), "qkv": ("int8", None),
        "qexp": (None, "int8"), "both": ("int8", "int8")}
done = {}
for name, (kvd, exd) in arms.items():
    srv, task = serve(kv_dtype=kvd, expert_dtype=exd)
    assert srv.hmm.active_cfg.ndev == 4
    assert srv.kv_dtype == kvd and srv.expert_dtype == exd
    done[name] = srv

def proj(name):
    srv = done[name]
    c = transition_cost(MCFG, 2, c2, c4, expert_mode="pooled",
                        kv_dtype=srv.kv_dtype, expert_dtype=srv.expert_dtype)
    return c.breakdown["p2p"]

# int8 expert pages halve (and then some) the projected scale-up P2P;
# the KV dtype does not touch weight P2P
assert proj("both") == proj("qexp") < proj("f32")
assert proj("qkv") == proj("f32")

# a migrate-mode scale-down moves quantized KV blocks: the projection at
# the int8 block size is strictly cheaper than the f32 one
down_q = transition_cost(
    MCFG, 2, c4, c2, expert_mode="pooled", kv_dtype="int8",
    expert_dtype="int8",
    kv_migration_bytes=50 * block_bytes(MCFG, 16, kv_dtype="int8"))
down_f = transition_cost(
    MCFG, 2, c4, c2, expert_mode="pooled",
    kv_migration_bytes=50 * block_bytes(MCFG, 16))
assert down_q.scale_time_s < down_f.scale_time_s

# the ClusterDriver adopts the backend's dtypes for its projections
drv = ClusterDriver(done["both"], ScalingPolicy(slo=SLO(1.0, 1.0)),
                    mcfg=MCFG, tp=2, device_pool=range(8))
assert drv._kv_dtype == "int8" and drv._expert_dtype == "int8"
# projects from the backend's LIVE page table (the server sits at c4)
assert 0 < drv.projected_cost_s(c4, c2) < float("inf")
print("QUANT-MATRIX-OK")
""")
    assert "QUANT-MATRIX-OK" in out
