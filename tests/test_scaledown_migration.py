"""Zero-drain scale-down: live KV-block migration (real JAX, subprocess):

* determinism matrix mirroring the scale-up one — tokens bit-identical for
  sequences migrated mid-decode vs an unscaled run at the target config,
  across (dense | pooled experts) x paged KV,
* abort-mid-migration restores tables, resumes the paused sequences in
  place, and leaks no blocks (``check_invariants``),
* survivors lacking free blocks fall back to recompute-preemption (the
  only case that still recomputes),
* the coordinator cooldown regression (stale confirm timer) and the
  admissible-capacity utilization signal,
* simulator + driver share the migration policy and surface
  ``migrated_blocks`` / ``migration_bytes`` on their events.
"""
import pytest

from helpers import TEST_MOE, run_with_devices

MIG_COMMON = TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.driver import ScalePhase
from repro.serving.workload import Request

c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))

def mixed_reqs(outs, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, 0.0, 16, o, prompt=rng.integers(0, 128, 16))
            for i, o in enumerate(outs)]
"""


@pytest.mark.slow
def test_scaledown_migration_determinism_matrix():
    """Scale 6->4 mid-decode with live sequences in the doomed slots: the
    MIGRATING phase re-homes them onto survivors and every token matches
    the unscaled run bit for bit — for dense AND pooled expert weights
    over paged KV.  No drain: the long doomed sequences are still decoding
    when the devices release."""
    out = run_with_devices(MIG_COMMON + """
from repro.serving.metrics import summarize

# short rids 0-1 free their survivor slots early; long rids 4-5 sit in the
# doomed partition and are still mid-decode when the scale-down commits
OUTS = [6, 6, 30, 30, 60, 60]

def run(expert_mode, scale):
    srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), seed=0, kv_mode="paged",
                        kv_block_size=16, expert_mode=expert_mode)
    assert srv.scaledown_mode == "migrate"      # the default for paged KV
    srv.boot(c6 if scale else c4)
    reqs = mixed_reqs(OUTS)
    for r in reqs: srv.submit(r)
    t, n, task, mig_polls = 0.0, 0, None, 0
    while any(r.finish_s is None for r in reqs):
        if scale and n == 10 and task is None:
            # the doomed sequences have decoded for several ticks already
            assert all(srv.engine.slots[s].active for s in (4, 5))
            task = srv.start_scale(c4)
        srv.tick(t); t += .1; n += 1
        if task is not None and not task.done:
            task.advance(t)
            if task.phase is ScalePhase.MIGRATING:
                mig_polls += 1
        assert n < 2000, [r.finish_s for r in reqs]
    return {r.rid: srv.engine.generated[r.rid] for r in reqs}, srv, task, \
        mig_polls, reqs

for mode in ("dense", "pooled"):
    ref, _, _, _, _ = run(mode, scale=False)
    got, srv, task, mig_polls, reqs = run(mode, scale=True)
    assert srv.hmm.active_cfg.ndev == 4
    assert srv.hmm.kv_blocks.num_partitions == 2
    assert mig_polls > 0, "MIGRATING phase never observed"
    assert task.migrated_blocks > 0
    assert task.migration_bytes == task.migrated_blocks * \
        srv.engine.block_nbytes()
    assert srv.engine.preemptions == 0          # migrated, not recomputed
    srv.hmm.kv_blocks.check_invariants()
    assert srv.engine.kv_stats()["used_blocks"] == 0
    ev = srv.events[-1]
    assert ev.migrated_blocks == task.migrated_blocks
    assert ev.migration_bytes == task.migration_bytes
    summ = summarize(reqs, backend=srv)
    assert summ["scaledown_mode"] == "migrate"
    assert summ["migrated_blocks"] == task.migrated_blocks
    for rid in ref:
        assert ref[rid] == got[rid], (mode, rid)
    print(f"MATRIX-{mode}-OK", task.migrated_blocks)
print("SCALEDOWN-DETERMINISM-OK")
""")
    assert "MATRIX-dense-OK" in out
    assert "MATRIX-pooled-OK" in out
    assert "SCALEDOWN-DETERMINISM-OK" in out


@pytest.mark.slow
def test_abort_mid_migration_restores_and_leaks_nothing():
    """Abort with per-block copy ops literally in flight: the copy session
    is cancel-or-joined, tickets unwind, block tables were never flipped
    (device truth unchanged), the paused sequences resume in place on the
    OLD config, and the pool conserves."""
    out = run_with_devices(MIG_COMMON + """
import time

srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, kv_mode="paged",
                    kv_block_size=16)
srv.boot(c6)
reqs = mixed_reqs([6, 6, 30, 30, 60, 60])
for r in reqs: srv.submit(r)
orig = srv.engine._copy_block
def slow_copy(src, dst):
    time.sleep(0.05)                 # keep ops in flight across a tick
    orig(src, dst)
srv.engine.copy_block = slow_copy

t, n, task, aborted, before = 0.0, 0, None, False, None
while any(r.finish_s is None for r in reqs):
    if n == 10 and task is None:
        task = srv.start_scale(c4)
    srv.tick(t); t += .1; n += 1
    if task is not None and not task.done:
        task.advance(t)
        if not aborted and task.phase is ScalePhase.MIGRATING \
                and task._mig_inflight:
            mig_slots = [i for i, s in enumerate(srv.engine.slots)
                         if s.migrating]
            assert mig_slots, "no slot paused while copies in flight"
            before = srv.engine.block_tables[mig_slots].copy()
            task.abort(); aborted = True
            after = srv.engine.block_tables[mig_slots]
            assert (before == after).all()       # tables never flipped
            assert not any(s.migrating or s.reserved
                           for s in srv.engine.slots)
            srv.hmm.kv_blocks.check_invariants()
            assert srv.hmm.kv_blocks.migrations_pending == 0
            assert srv.engine.admit_limit is None
    assert n < 3000
assert aborted and task.phase is ScalePhase.ABORTED
assert srv.hmm.active_cfg.ndev == 6              # still on the old config
assert srv.engine.kv_stats()["used_blocks"] == 0
srv.hmm.kv_blocks.check_invariants()
for r in reqs:                                   # every sequence completed
    assert len(srv.engine.generated[r.rid]) == r.output_len
print("ABORT-MID-MIGRATION-OK")
""")
    assert "ABORT-MID-MIGRATION-OK" in out


@pytest.mark.slow
def test_migration_falls_back_to_preemption_when_survivors_full():
    """Survivor partitions too full to host the doomed blocks: the engine
    preempts (recompute) instead of deadlocking, everything completes on
    the shrunk config, and the pool conserves."""
    out = run_with_devices(MIG_COMMON + """
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, kv_mode="paged",
                    kv_block_size=16, kv_blocks_per_replica=8)
srv.boot(c6)
reqs = mixed_reqs([40] * 6, seed=1)
for r in reqs: srv.submit(r)
t, n, task = 0.0, 0, None
while any(r.finish_s is None for r in reqs):
    if n == 5 and task is None:
        task = srv.start_scale(c4)
    srv.tick(t); t += .1; n += 1
    if task is not None and not task.done:
        task.advance(t)
    assert n < 3000, [r.finish_s for r in reqs]
assert task.done and srv.hmm.active_cfg.ndev == 4
assert srv.engine.preemptions > 0, "fallback never exercised"
srv.hmm.kv_blocks.check_invariants()
assert srv.engine.kv_stats()["used_blocks"] == 0
for r in reqs:
    assert len(srv.engine.generated[r.rid]) == r.output_len
print("PREEMPT-FALLBACK-OK", srv.engine.preemptions)
""")
    assert "PREEMPT-FALLBACK-OK" in out


# ---------------------------------------------------- fast in-process units

def test_cooldown_clears_stale_confirm_timer():
    """Regression (coordinator): a confirm timer tracked before a cooldown
    must not survive it — the first post-cooldown blip would instantly
    satisfy ``confirm_s`` even though the signal flapped in between."""
    from repro.core.coordinator import LoadEstimator, ScalingPolicy
    from repro.serving.metrics import SLO
    from repro.serving.workload import Request

    pol = ScalingPolicy(slo=SLO(1.0, 1.0), window=8, cooldown_s=10.0,
                        confirm_s=2.0)
    est = LoadEstimator(pol)
    for i in range(8):                     # healthy window -> raw 'down'
        r = Request(i, 0.0, 10, 5)
        r.first_token_s = 0.1
        r.finish_s = 0.5
        est.record(r)
    # a 'down' confirm timer is running when a cooldown begins (e.g. the
    # operator scaled manually / a prior decision committed elsewhere)
    est._sig_dir, est._sig_t0 = "down", 0.0
    est.last_action_t = 5.0
    # the signal flaps away DURING the cooldown (high utilization)...
    assert est.decide(6.0, queue_depth=0, utilization=0.9) is None
    # ...and reappears right after it: the stale t0 (0.0) would satisfy
    # confirm_s instantly — the fix restarts the confirm window instead
    assert est.decide(16.0, queue_depth=0, utilization=0.1) is None
    assert est._sig_t0 == 16.0
    # continuous presence from here on confirms normally
    assert est.decide(18.5, queue_depth=0, utilization=0.1) == "down"


def test_utilization_over_admissible_capacity():
    """During a scale-down the load signal must be computed over the
    capacity that SURVIVES (admit_limit slots / partitions) — counting
    doomed slots deflates it exactly while the estimator watches."""
    from repro.configs.base import ModelConfig
    from repro.core.topology import ElasticConfig
    from repro.serving.engine import InferenceEngine, SlotState
    from repro.serving.kv_blocks import KVBlockManager

    mcfg = ModelConfig(name="t", arch_type="dense", num_layers=1, d_model=8,
                       vocab_size=16, num_heads=1, num_kv_heads=1,
                       head_dim=8, d_ff=8)
    # dense: 2 active of 6 slots = 1/3; of the 4 admissible = 1/2
    eng = InferenceEngine(mcfg, batch_per_replica=2, max_len=64)
    eng.cfg = ElasticConfig(dp=3, tp=1, devices=(0, 1, 2))
    eng.slots = [SlotState(active=i < 2) for i in range(6)]
    assert eng.utilization() == pytest.approx(2 / 6)
    eng.admit_limit = 4
    assert eng.utilization() == pytest.approx(2 / 4)
    eng.admit_limit = None
    # paged: occupancy over the surviving partitions' blocks only
    eng.kv = KVBlockManager(3, 8, 16)
    eng.kv.allocate(1, 6 * 16, partition=0)
    assert eng.utilization() == pytest.approx(6 / 24)
    eng.admit_limit = 4                      # 2 surviving partitions
    assert eng.utilization() == pytest.approx(6 / 16)
    eng.admit_limit = None
    assert eng.utilization() == pytest.approx(6 / 24)


def test_simulator_migration_policy_and_events():
    """The simulator costs migrate-mode scale-downs as migration bytes via
    the SAME projected_migration_blocks policy the driver projects with,
    records them on its events, and drain mode is bounded by the doomed
    sequences' completion instead."""
    from repro.configs import get_config
    from repro.serving.driver import projected_migration_blocks
    from repro.serving.metrics import summarize
    from repro.serving.simulator import ServingSimulator
    from repro.serving.workload import Request

    mcfg = get_config("deepseek-v2-lite-16b")

    def loaded(scaledown):
        sim = ServingSimulator(mcfg, tp=2, ndev=8, kv_mode="paged",
                               pool_blocks=4000, scaledown=scaledown)
        for i in range(8):
            sim.submit(Request(i, 0.0, 4096, 4000))
        sim.step(0.0)
        assert sim.used_blocks() > 0
        return sim

    sim = loaded("migrate")
    expect = projected_migration_blocks(sim.used_blocks(), 4, 2)
    task = sim.command_scale(4)
    ev = sim.events[-1]
    assert ev.migrated_blocks == expect > 0
    assert ev.migration_bytes == expect * sim.perf._kv_block_bytes
    assert ev.cost.breakdown["kv_migration"] > 0
    assert ev.cost.migration_bytes == ev.migration_bytes
    assert task.migrated_blocks == expect        # DriverEvent fill-in path
    t_migrate = ev.t_ready

    sim_d = loaded("drain")
    sim_d.command_scale(4)
    ev_d = sim_d.events[-1]
    assert ev_d.migrated_blocks == 0
    # drain waits for the doomed share of in-flight sequences to finish —
    # with 4000-token outputs that dwarfs the staging window
    assert ev_d.t_ready > t_migrate
    st = summarize([], backend=sim)
    assert st["scaledown_mode"] == "migrate"
    assert st["migrated_blocks"] == expect
