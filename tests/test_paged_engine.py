"""Paged-KV engine tests (real JAX on host devices, subprocess):

* token parity with the dense engine, including shared-prefix CoW runs,
* bit-identical tokens across a scale-up event with paged KV (the block
  tables survive the pool growth verbatim — the zero-copy remap claim),
* preemption under pool pressure: an over-committed burst completes,
* pool conservation at the end of every run (check_invariants).
"""
import pytest

from helpers import TEST_MOE, run_with_devices

PAGED_COMMON = TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request, shared_prefix_workload

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))

def drive(srv, reqs, tmax=3000):
    for r in reqs: srv.submit(r)
    t, n = 0.0, 0
    while any(r.finish_s is None for r in reqs):
        srv.tick(t); t += .1; n += 1
        assert n < tmax, [r.finish_s for r in reqs]
    return srv
"""


@pytest.mark.slow
def test_paged_engine_matches_dense_and_shares_blocks():
    """Same workload, dense vs paged: identical tokens per request, and the
    shared-prefix workload actually shares blocks + triggers CoW forks."""
    out = run_with_devices(PAGED_COMMON + """
def build(kv_mode):
    srv = ElasticServer(MCFG, tp=2, batch_per_replica=4, max_len=128,
                        prefill_buckets=(32, 64), seed=0, kv_mode=kv_mode,
                        kv_block_size=16)
    srv.boot(c4)
    return srv

reqs = lambda: shared_prefix_workload(
    [(0.0, 3), (0.5, 5)], prefix_len=40, suffix_range=(0, 6),
    vocab_size=128, seed=2, output_range=(10, 20))

paged = drive(build("paged"), reqs())
dense = drive(build("dense"), reqs())
st = paged.engine.kv_stats()
assert st["shared_block_hits"] > 0, st
assert st["cow_copies"] > 0, st
assert st["used_blocks"] == 0, st
paged.hmm.kv_blocks.check_invariants()
for rid in dense.engine.generated:
    assert dense.engine.generated[rid] == paged.engine.generated[rid], rid

# a request whose prefill token is its ONLY token must still be reported
# finished (it never reaches decode_tick) — regression for the
# finished-at-admission path, both layouts
for srv in (paged, dense):
    r1 = Request(900, 0.0, 16, 1, prompt=np.arange(16) % 128)
    srv.submit(r1)
    srv.tick(99.0)
    assert r1.finish_s == 99.0, r1
    assert len(srv.engine.generated[900]) == 1
paged.hmm.kv_blocks.check_invariants()
print("PAGED-DENSE-PARITY-OK", st["shared_block_hits"], st["cow_copies"])
""", ndev=4)
    assert "PAGED-DENSE-PARITY-OK" in out


@pytest.mark.slow
def test_paged_tokens_identical_across_scaleup():
    """Scale 4->6 devices mid-decode with paged KV: surviving block tables
    are reused verbatim on the grown pool, so every token matches the
    unscaled run bit for bit."""
    out = run_with_devices(PAGED_COMMON + """
def run(scale):
    srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), seed=0, kv_mode="paged",
                        kv_block_size=16)
    srv.boot(c4 if scale else c6)
    rng = np.random.default_rng(0)
    reqs = [Request(i, 0.0, 16, 40, prompt=rng.integers(0, 128, 16))
            for i in range(4)]
    for r in reqs: srv.submit(r)
    t, n, task = 0.0, 0, None
    while any(r.finish_s is None for r in reqs):
        if scale and n == 5 and task is None:
            task = srv.start_scale(c6)
        srv.tick(t); t += .1; n += 1
        if task is not None and not task.done:
            task.advance(t)
        assert n < 500
    return {r.rid: srv.engine.generated[r.rid] for r in reqs}, srv

ref_toks, _ = run(False)
got_toks, srv = run(True)
assert srv.hmm.kv_blocks.num_partitions == 3
srv.hmm.kv_blocks.check_invariants()
assert srv.engine.preemptions == 0
for rid in ref_toks:
    assert ref_toks[rid] == got_toks[rid], (rid, ref_toks[rid], got_toks[rid])
print("PAGED-SCALEUP-DETERMINISM-OK")
""")
    assert "PAGED-SCALEUP-DETERMINISM-OK" in out


@pytest.mark.slow
def test_closed_loop_driver_paged_up_down_shrinks_partitions():
    """The unchanged ClusterDriver loop over a PAGED real engine: burst ->
    scale up (pool grows a partition), idle -> drain + scale down (doomed
    partition verified empty, then dropped), CoW sharing active throughout,
    pool conserves."""
    out = run_with_devices(PAGED_COMMON + """
from repro.core.coordinator import ScalingPolicy
from repro.serving.driver import ClusterDriver, DriverConfig
from repro.serving.metrics import SLO
from repro.serving.workload import scripted_burst

policy = ScalingPolicy(slo=SLO(ttft_s=5.0, tpot_s=5.0), window=8,
                       cooldown_s=1.0, queue_scale_up=3)
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, kv_mode="paged",
                    kv_block_size=16, kv_blocks_per_replica=10)
srv.boot(c4)
srv.preinitialize(c6)
driver = ClusterDriver(srv, policy, mcfg=MCFG, tp=2, device_pool=range(6),
                       config=DriverConfig(dt=0.05, settle_s=2.0,
                                           prewarm_next=False))
reqs = scripted_burst([(0.0, 2), (0.5, 7), (6.0, 1)], vocab_size=128, seed=1,
                      output_range=(30, 50))
reqs += shared_prefix_workload([(0.3, 3)], prefix_len=40, suffix_range=(0, 6),
                               vocab_size=128, seed=4, output_range=(10, 20),
                               rid0=100)
reqs.sort(key=lambda r: r.arrival_s)
until = 0.0
while any(r.finish_s is None for r in reqs) or \
        "down" not in [e.direction for e in driver.events]:
    until += 10.0
    driver.run(reqs if until == 10.0 else [], until=until)
    assert until < 300.0, "stalled"
dirs = [e.direction for e in driver.events]
assert "up" in dirs and "down" in dirs, dirs
assert srv.hmm.active_cfg.ndev == 4
assert srv.hmm.kv_blocks.num_partitions == srv.hmm.active_cfg.dp == 2
assert srv.engine.kv_stats()["shared_block_hits"] > 0
assert srv.engine.kv_stats()["used_blocks"] == 0
srv.hmm.kv_blocks.check_invariants()
print("PAGED-CLOSED-LOOP-OK", dirs)
""")
    assert "PAGED-CLOSED-LOOP-OK" in out


@pytest.mark.slow
def test_paged_engine_preempts_under_pressure_and_completes():
    """Pool sized well below the admitted sequences' eventual footprint: the
    engine preempts (recompute-on-resume) instead of deadlocking, every
    request still finishes, and the pool drains clean."""
    out = run_with_devices(PAGED_COMMON + """
srv = ElasticServer(MCFG, tp=2, batch_per_replica=4, max_len=128,
                    prefill_buckets=(32,), seed=0, kv_mode="paged",
                    kv_block_size=16, kv_blocks_per_replica=8)
srv.boot(c4)
rng = np.random.default_rng(1)
reqs = [Request(i, 0.0, 16, 60, prompt=rng.integers(0, 128, 16))
        for i in range(8)]
drive(srv, reqs)
st = srv.engine.kv_stats()
assert srv.engine.preemptions > 0, st
assert st["used_blocks"] == 0, st
srv.hmm.kv_blocks.check_invariants()
# preempted requests were recomputed and still produced full outputs
for r in reqs:
    assert len(srv.engine.generated[r.rid]) == r.output_len, r.rid
# a request that could NEVER fit a partition fails fast at submit instead
# of head-of-line-blocking the queue forever
try:
    srv.submit(Request(999, 0.0, 16, 1000, prompt=rng.integers(0, 128, 16)))
    raise SystemExit("oversized request was accepted")
except ValueError:
    pass
print("PAGED-PREEMPT-OK", srv.engine.preemptions)
""", ndev=4)
    assert "PAGED-PREEMPT-OK" in out


@pytest.mark.slow
def test_lazy_prefill_bucket_cache_is_bounded():
    """Regression: paged mode lazily compiles a prefill executable per
    unseen padded length, and resumed-after-preemption prompts keep growing,
    so the cache must be LRU-bounded.  AOT-precompiled buckets are pinned;
    a recently-hit lazy bucket outlives an older one; an evicted bucket is
    transparently recompiled when a prompt needs it again."""
    out = run_with_devices(PAGED_COMMON + """
srv = ElasticServer(MCFG, tp=2, batch_per_replica=4, max_len=512,
                    prefill_buckets=(32,), seed=0, kv_mode="paged",
                    kv_block_size=16)
srv.boot(c4)
eng = srv.engine
cap = eng.MAX_LAZY_PREFILL
assert cap == 8
# fill the lazy cache exactly to capacity: buckets 64, 96, ..., 288
for i in range(2, 2 + cap):
    eng._prefill(32 * i)
    assert len(eng._lazy_prefill) <= cap
assert len(eng._lazy_prefill) == cap
before = set(eng.compiled)
eng._prefill(64)                      # cache hit — refreshes 64's recency
assert set(eng.compiled) == before    # a hit never compiles or evicts
eng._prefill(320)                     # one past capacity -> one eviction
assert len(eng._lazy_prefill) == cap
assert "prefill_96" not in eng.compiled     # oldest unrefreshed: evicted
assert "prefill_64" in eng.compiled         # refreshed: survived (LRU)
assert "prefill_32" in eng.compiled         # AOT bucket: never evictable
assert "prefill_32" not in eng._lazy_prefill
# the evicted bucket is recompiled on demand: a 70-token prompt pads to 96
rng = np.random.default_rng(3)
reqs = [Request(0, 0.0, 70, 8, prompt=rng.integers(0, 128, 70))]
drive(srv, reqs)
assert "prefill_96" in eng.compiled
assert len(eng._lazy_prefill) <= cap
assert len(eng.generated[0]) == 8
assert eng.kv_stats()["used_blocks"] == 0
srv.hmm.kv_blocks.check_invariants()
print("LAZY-PREFILL-LRU-OK", sorted(eng._lazy_prefill))
""", ndev=4)
    assert "LAZY-PREFILL-LRU-OK" in out
