"""Hypothesis property suite for the token-budget chunk scheduler and its
interaction with the paged KV pool (DESIGN.md §8).

Scheduler contracts (serving/scheduler.py):
* the per-tick prefill budget is never exceeded, and every planned chunk is
  at most one compiled bucket wide;
* each job's chunks arrive strictly in order and exactly cover
  ``[skip, total)`` — one ``final`` chunk per job, landing on ``total``;
* FIFO no-skipping: a later job never receives budget while an earlier
  unpaused job was denied;
* progress / no starvation: whenever any unpaused work remains, at least
  one chunk is planned (budget >= chunk), so prefill drains in a bounded
  number of ticks while decode — which is never charged against the
  budget — runs every tick by construction.

Pool contracts under chunked prefill (serving/kv_blocks.py): random
admit / chunk / preempt / finish / append interleavings with deferred
registration (``allocate(register=False)`` + progressive
``register_written``) keep ``check_invariants`` green, and — the CoW
soundness property the deferral exists for — ``prefix_match_blocks`` never
returns a block whose content has not been written yet.

CI runs this file as a dedicated tier-1 step under the fixed profile
registered below (deadline disabled, derandomized) so it cannot flake.
"""
import math
import os

import pytest

pytest.importorskip("hypothesis")  # optional test extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.kv_blocks import KVBlockManager, blocks_for
from repro.serving.scheduler import (PrefillJob, TokenBudgetScheduler,
                                     prefix_skip)

settings.register_profile("repro-ci", deadline=None, derandomize=True,
                          max_examples=40)
settings.register_profile("repro-ci-thorough", deadline=None,
                          derandomize=True, max_examples=300)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "repro-ci"))


# ------------------------------------------------------ scheduler properties

@given(chunk=st.sampled_from([1, 4, 16, 32]),
       budget_chunks=st.integers(1, 4),
       specs=st.lists(st.tuples(st.integers(1, 100), st.integers(0, 3)),
                      min_size=1, max_size=8),
       pauses=st.lists(st.integers(0, 7), max_size=4))
def test_budget_order_coverage_and_progress(chunk, budget_chunks, specs,
                                            pauses):
    """Drive plan/apply ticks until every job drains; check all four
    scheduler contracts on the way.  ``specs`` are (total, skip_blocks);
    ``pauses`` toggles jobs paused for one tick mid-run (migration)."""
    budget = chunk * budget_chunks
    sched = TokenBudgetScheduler(chunk, budget)
    jobs = []
    for i, (total, skip_blocks) in enumerate(specs):
        skip = prefix_skip(skip_blocks, chunk, total)
        jobs.append(PrefillJob(slot=i, rid=i, pos=skip, total=total))
    chunks_seen = {j.rid: [] for j in jobs}
    start_pos = {j.rid: j.pos for j in jobs}
    # pauses can waste every other tick (a paused job makes no progress),
    # so the drain bound is 2x the chunk count — still finite, which is
    # the point: prefill always drains, decode never waits on it
    ticks, tick_bound = 0, 2 * sum(
        math.ceil(j.remaining / chunk) for j in jobs) + len(pauses) + 4
    while any(j.remaining > 0 for j in jobs):
        for p in pauses:                       # freeze a rotating subset
            jobs[p % len(jobs)].paused = (ticks % 2 == 0)
        plans = sched.plan(jobs)
        # budget never exceeded; chunks never wider than the bucket
        assert sum(p.take for p in plans) <= budget
        assert all(0 < p.take <= chunk for p in plans)
        # FIFO no-skipping: the distinct planned rids are exactly a prefix
        # of the unpaused, unfinished jobs in admission order — a later job
        # never receives budget while an earlier one was denied
        planned = list(dict.fromkeys(p.rid for p in plans))
        eligible = [j.rid for j in jobs if not j.paused and j.remaining > 0]
        assert planned == eligible[:len(planned)]
        # progress whenever anything is runnable
        if eligible:
            assert plans, "runnable work but empty plan (starvation)"
        by_rid = {j.rid: j for j in jobs}
        for p in plans:
            job = by_rid[p.rid]
            assert p.start == job.pos, "out-of-order chunk"
            assert p.final == (p.start + p.take == job.total)
            chunks_seen[p.rid].append((p.start, p.take, p.final))
            job.pos = p.start + p.take
        for j in jobs:
            j.paused = False
        ticks += 1
        assert ticks <= tick_bound, "scheduler failed to drain in bound"
    for rid, got in chunks_seen.items():
        total = next(j.total for j in jobs if j.rid == rid)
        # exact coverage of [skip, total): contiguous, one final at the end
        pos = start_pos[rid]
        for k, (start, take, final) in enumerate(got):
            assert start == pos
            pos += take
            assert final == (k == len(got) - 1)
        assert pos == total


@given(chunk=st.sampled_from([1, 4, 16, 32]),
       budget_chunks=st.integers(1, 4),
       totals=st.lists(st.integers(1, 100), min_size=1, max_size=8),
       paused_heads=st.integers(0, 8))
def test_paused_head_jobs_are_invisible_to_fifo(chunk, budget_chunks,
                                                totals, paused_heads):
    """Paused-head pin (KV-migration freeze): when the first ``paused_heads``
    jobs in admission order are paused, one plan() tick must (a) give no
    budget to any paused job, (b) start spending at the first UNPAUSED job —
    paused jobs are invisible to FIFO order, they don't block the queue or
    reserve budget — (c) stay within budget, and (d) leave every paused
    job's position untouched when the plan is applied."""
    budget = chunk * budget_chunks
    sched = TokenBudgetScheduler(chunk, budget)
    jobs = [PrefillJob(slot=i, rid=i, pos=0, total=t)
            for i, t in enumerate(totals)]
    k = min(paused_heads, len(jobs))
    for j in jobs[:k]:
        j.paused = True
    pos_before = {j.rid: j.pos for j in jobs}
    plans = sched.plan(jobs)
    paused_rids = {j.rid for j in jobs[:k]}
    assert all(p.rid not in paused_rids for p in plans)
    assert sum(p.take for p in plans) <= budget
    unpaused = [j for j in jobs[k:] if j.remaining > 0]
    if unpaused:
        # the head of the *unpaused* queue is served first, from its pos
        assert plans and plans[0].rid == unpaused[0].rid
        assert plans[0].start == unpaused[0].pos
        # FIFO prefix over the unpaused queue only
        planned = list(dict.fromkeys(p.rid for p in plans))
        assert planned == [j.rid for j in unpaused[:len(planned)]]
    else:
        assert plans == []
    for p in plans:                     # apply, as the engine tick would
        next(j for j in jobs if j.rid == p.rid).pos = p.start + p.take
    for j in jobs[:k]:
        assert j.pos == pos_before[j.rid], "paused job advanced"


@given(num_shared=st.integers(0, 20), bs=st.sampled_from([1, 4, 16, 256]),
       prompt_len=st.integers(1, 4096))
def test_prefix_skip_always_leaves_work(num_shared, bs, prompt_len):
    """The prefix-cache seed position is block-aligned, never exceeds the
    matched prefix, and always leaves at least one token to compute (the
    last position's logits produce the first output token)."""
    skip = prefix_skip(num_shared, bs, prompt_len)
    assert 0 <= skip < prompt_len
    assert skip % bs == 0
    assert skip <= num_shared * bs


def test_budget_below_one_chunk_rejected():
    with pytest.raises(AssertionError):
        TokenBudgetScheduler(32, 16)
    assert TokenBudgetScheduler(32).budget == 32


# --------------------------------------- pool invariants under interleavings

@given(data=st.data())
def test_kv_pool_invariants_under_chunked_interleavings(data):
    """Random admit/chunk/append/preempt/finish interleavings with deferred
    registration: ``check_invariants`` holds after every operation, and an
    arriving prompt can only ever match blocks whose content was already
    registered as written — never a block still waiting for its chunk."""
    bs = data.draw(st.sampled_from([2, 4]), label="block_size")
    mgr = KVBlockManager(num_partitions=2, blocks_per_partition=8,
                         block_size=bs)
    sched = TokenBudgetScheduler(bs, 2 * bs)
    state = {}          # rid -> dict(tokens, sb, done)
    jobs = []           # PrefillJob list, admission order
    registered = set()  # block ids whose content is registered (model)
    next_rid = 0

    def mirror_register(rid, upto):
        sb = state[rid]["sb"]
        toks = state[rid]["tokens"]
        nb = len(sb.blocks) if upto >= len(toks) else upto // bs
        registered.update(sb.blocks[:nb])

    def drop(rid):
        released = mgr.preempt(rid) if not state[rid]["done"] \
            else mgr.free(rid)
        registered.difference_update(released)
        state.pop(rid)
        jobs[:] = [j for j in jobs if j.rid != rid]

    actions = data.draw(st.lists(
        st.sampled_from(["admit", "chunk", "chunk", "append", "preempt",
                         "finish"]), min_size=4, max_size=50),
        label="actions")
    for act in actions:
        if act == "admit":
            part = data.draw(st.integers(0, 1), label="partition")
            n = data.draw(st.integers(1, 3 * bs), label="prompt_len")
            toks = data.draw(st.lists(st.integers(0, 1), min_size=n,
                                      max_size=n), label="tokens")
            hits = mgr.prefix_match_blocks(part, toks)
            # CoW soundness: only written (registered) blocks are matchable
            assert set(hits) <= registered, (hits, registered)
            if not mgr.can_allocate(n, part, tokens=toks):
                mgr.check_invariants()
                continue
            rid = next_rid
            next_rid += 1
            sb = mgr.allocate(rid, n, partition=part, tokens=toks,
                              register=False)
            assert sb.num_shared == len(hits) or sb.num_shared <= len(hits)
            skip = prefix_skip(sb.num_shared, bs, n)
            state[rid] = {"tokens": toks, "sb": sb, "done": False}
            jobs.append(PrefillJob(slot=rid, rid=rid, pos=skip, total=n))
        elif act == "chunk" and jobs:
            plans = sched.plan(jobs)
            by_rid = {j.rid: j for j in jobs}
            for p in plans:
                job = by_rid[p.rid]
                upto = p.start + p.take
                mgr.register_written(p.rid, state[p.rid]["tokens"], upto)
                mirror_register(p.rid, upto)
                job.pos = upto
                if p.final:
                    state[p.rid]["done"] = True
            jobs[:] = [j for j in jobs if j.remaining > 0]
        elif act == "append" and any(s["done"] for s in state.values()):
            rid = data.draw(st.sampled_from(
                sorted(r for r, s in state.items() if s["done"])),
                label="append_rid")
            try:
                res = mgr.append(rid)
            except MemoryError:
                victim = mgr.victim(exclude=[rid])
                if victim is not None:
                    drop(victim)
                mgr.check_invariants()
                continue
            sb = state[rid]["sb"]
            if res is None:
                # in-place tail write: that block's registered content is
                # stale — the manager unregistered it; mirror that
                registered.discard(sb.blocks[(sb.num_tokens - 1) // bs])
            elif res.cow_src is not None:
                registered.discard(res.block)
        elif act == "preempt" and state:
            rid = data.draw(st.sampled_from(sorted(state)),
                            label="preempt_rid")
            drop(rid)
        elif act == "finish" and any(s["done"] for s in state.values()):
            rid = data.draw(st.sampled_from(
                sorted(r for r, s in state.items() if s["done"])),
                label="finish_rid")
            drop(rid)
        mgr.check_invariants()
    for rid in sorted(state):
        drop(rid)
    mgr.check_invariants()
    assert mgr.used_blocks() == 0, "pool leaked after full drain"


@given(bs=st.sampled_from([2, 4]), n=st.integers(1, 12),
       cut=st.integers(0, 14))
def test_register_written_is_progressive_and_idempotent(bs, n, cut):
    """Registering the same prefix twice (or registering beyond the prompt)
    is a no-op; partial registration exposes exactly the full blocks."""
    mgr = KVBlockManager(num_partitions=1, blocks_per_partition=16,
                         block_size=bs)
    toks = [1] * n
    mgr.allocate(0, n, tokens=toks, register=False)
    assert mgr.prefix_match_blocks(0, toks) == []
    upto = min(cut, n)
    mgr.register_written(0, toks, upto)
    mgr.register_written(0, toks, upto)              # idempotent
    hits = mgr.prefix_match_blocks(0, toks)
    if upto >= n:
        assert len(hits) == blocks_for(n, bs)        # tail matchable too
    else:
        assert len(hits) == upto // bs
    mgr.check_invariants()
    mgr.register_written(0, toks, n)                 # finish registration
    assert len(mgr.prefix_match_blocks(0, toks)) == blocks_for(n, bs)
    mgr.free(0)
    mgr.check_invariants()
