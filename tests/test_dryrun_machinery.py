"""Self-test of the dry-run machinery (subprocess: it needs 512 placeholder
devices, which must never leak into the main test session)."""
import json
import os
import subprocess
import sys

import pytest

from helpers import REPO

pytestmark = pytest.mark.slow


def test_dryrun_single_combination(tmp_path):
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import run_one
rec = run_one("qwen1.5-0.5b", "decode_32k", False, save=False)
assert rec["status"] == "ok", rec
rl = rec["roofline"]
assert rl["chips"] == 256
assert rl["flops"] > 0 and rl["hbm_bytes"] > 0
assert rl["bottleneck"] in ("compute", "memory", "collective")
assert 0 < rl["useful_flops_ratio"] < 2
rec2 = run_one("hubert-xlarge", "decode_32k", False, save=False)
assert rec2["status"] == "skipped"
rec3 = run_one("qwen1.5-0.5b", "decode_32k", True, save=False)
assert rec3["status"] == "ok" and rec3["roofline"]["chips"] == 512
print("DRYRUN-OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DRYRUN-OK" in r.stdout


def test_main_session_has_one_device():
    """The 512-device flag must not leak (per the brief)."""
    import jax
    assert len(jax.devices()) == 1
