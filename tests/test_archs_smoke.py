"""Per-architecture smoke tests (reduced configs, one forward/train step on
CPU, asserting output shapes + no NaNs) — deliverable (f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import model as M

ARCHS = sorted(ASSIGNED)


def _batch(cfg, B=2, S=16, rng=None):
    rng = rng or jax.random.PRNGKey(1)
    ks = jax.random.split(rng, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.arch_type == "encoder":
        batch["frames"] = jax.random.normal(ks[2], (B, S, cfg.d_model))
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ks[2], (B, cfg.num_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch + "-smoke")
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = M.forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    from repro.training.optimizer import AdamWConfig, init_state
    from repro.training.train_loop import make_train_step
    cfg = get_config(arch + "-smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamWConfig(total_steps=10)
    state = init_state(opt, params)
    step = jax.jit(make_train_step(cfg, opt))
    p2, s2, metrics = step(params, state, _batch(cfg))
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["gnorm"])
    # params actually changed
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).has_decode])
def test_prefill_decode_matches_forward(arch):
    import dataclasses
    cfg = get_config(arch + "-smoke")
    cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S, P0 = 2, 12, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.num_image_tokens, cfg.d_model))
    ref, _ = M.forward(cfg, params, batch, remat=False)
    pb = dict(batch)
    pb["tokens"] = toks[:, :P0]
    pb["lengths"] = jnp.full((B,), P0)
    lg, cache = M.prefill(cfg, params, pb, max_len=S + 2)
    errs = [float(jnp.max(jnp.abs(lg - ref[:, P0 - 1])))]
    lengths = jnp.full((B,), P0)
    for t in range(P0, S):
        lg, cache = M.decode_step(cfg, params, toks[:, t:t + 1], cache,
                                  lengths)
        lengths = lengths + 1
        errs.append(float(jnp.max(jnp.abs(lg - ref[:, t]))))
    assert max(errs) < 5e-4, errs
