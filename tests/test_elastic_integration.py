"""Multi-device elastic serving integration tests (subprocess with 8 host
devices — the main session keeps 1 device per the brief).

These are the paper's core claims, executed for real:
* zero-copy: new instances alias the old per-device buffers (pointer check),
* zero divergence: tokens across a scale-up match an unscaled run exactly,
* zero downtime: the engine serves between stage and switchover,
* scale-down drains evicted slots only.
"""
import pytest

from helpers import TEST_MOE, run_with_devices

pytestmark = pytest.mark.slow


def test_hmm_zero_copy_aliasing_and_equality():
    out = run_with_devices(TEST_MOE + """
import jax, numpy as np
from repro.core.topology import ElasticConfig
from repro.core.hmm import HMM

hmm = HMM(MCFG, tp=2, batch_per_replica=2, max_len=32)
c0 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
hmm.boot(c0)
_, _, params0, _ = hmm.attach_active()
q_ptrs = {s.device.id: s.data.unsafe_buffer_pointer()
          for s in params0["blocks"]["attn"]["q"]["w"].addressable_shards}
st = hmm.scale(ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5)))
_, _, nparams, _ = hmm.attach_staged()
q2 = nparams["blocks"]["attn"]["q"]["w"]
alias = sum(1 for s in q2.addressable_shards
            if s.device.id in q_ptrs
            and s.data.unsafe_buffer_pointer() == q_ptrs[s.device.id])
assert alias == 4, alias
ref = jax.tree.map(lambda a: np.asarray(a), params0)
new = jax.tree.map(lambda a: np.asarray(a), nparams)
jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), ref, new)
assert st.zero_copy_bytes > 0 and st.p2p_bytes > 0
print("ALIAS-OK")
""")
    assert "ALIAS-OK" in out


def test_scale_up_zero_token_divergence():
    out = run_with_devices(TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request

def run(scale):
    srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), seed=0)
    c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
    c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
    srv.boot(c4 if scale else c6)
    rng = np.random.default_rng(0)
    reqs = [Request(i, 0.0, 16, 24, prompt=rng.integers(0,128,16))
            for i in range(4)]
    for r in reqs: srv.submit(r)
    t, n = 0.0, 0
    while any(r.finish_s is None for r in reqs):
        if scale and n == 5:
            srv.stage_scale(c6)
            srv.tick(t); t += .1; n += 1   # serving DURING staging
            srv.switchover()
            continue
        srv.tick(t); t += .1; n += 1
        assert n < 500
    return {r.rid: srv.engine.generated[r.rid] for r in reqs}

ref, got = run(False), run(True)
for rid in ref:
    assert ref[rid] == got[rid], (rid, ref[rid], got[rid])
print("NO-DIVERGENCE")
""")
    assert "NO-DIVERGENCE" in out


def test_scale_down_with_drain():
    out = run_with_devices(TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request

srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0)
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
srv.boot(c6)
rng = np.random.default_rng(0)
reqs = [Request(i, 0.0, 16, 30 if i < 4 else 8,
                prompt=rng.integers(0,128,16)) for i in range(6)]
for r in reqs: srv.submit(r)
t, n, staged, switched = 0.0, 0, False, False
while any(r.finish_s is None for r in reqs):
    if n == 3 and not staged:
        srv.stage_scale(c4); staged = True
    if staged and srv._staged_cfg and srv.engine.drained(4):
        srv.switchover(); switched = True
    srv.tick(t); t += .1; n += 1
    assert n < 500
assert switched and srv.engine.num_slots == 4
assert srv.hmm.active_cfg.ndev == 4
print("DOWN-OK")
""")
    assert "DOWN-OK" in out


def test_moe_ep_matches_local():
    """shard_map EP path == single-shard local path (dropless capacity)."""
    out = run_with_devices(TEST_MOE + """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.models.moe import moe_init, moe_local, moe_ep
from repro.distributed.sharding import ParallelCtx

cfg = MCFG
p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
B, S, D = 4, 8, cfg.d_model
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
y_ref, aux_ref = moe_local(cfg, p, x.reshape(B*S, D), capacity=B*S*cfg.top_k)
mesh = Mesh(np.array(jax.devices()[:8]).reshape(4, 2), ("dp", "tp"))
ctx = ParallelCtx(mesh=mesh, ep_axes=("dp","tp"), tp_axis="tp",
                  dp_axes=("dp",), moe_tp=False)
y_ep, aux_ep = moe_ep(cfg, p, x, ctx, capacity=B*S*cfg.top_k)
np.testing.assert_allclose(np.asarray(y_ep).reshape(B*S, D),
                           np.asarray(y_ref), rtol=2e-5, atol=2e-5)
print("MOE-EP-OK")
""", ndev=8)
    assert "MOE-EP-OK" in out


def test_moe_ep_packed_matches_local():
    """Packed decode dispatch (EXPERIMENTS.md §Perf B) == local path."""
    out = run_with_devices(TEST_MOE + """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.models.moe import moe_init, moe_local, moe_ep
from repro.distributed.sharding import ParallelCtx

cfg = MCFG
p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
B, S, D = 4, 8, cfg.d_model
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
y_ref, _ = moe_local(cfg, p, x.reshape(B*S, D), capacity=B*S*cfg.top_k)
mesh = Mesh(np.array(jax.devices()[:8]).reshape(4, 2), ("dp", "tp"))
ctx = ParallelCtx(mesh=mesh, ep_axes=("dp","tp"), tp_axis="tp",
                  dp_axes=("dp",), moe_tp=False, moe_dispatch="packed")
y_pk, _ = moe_ep(cfg, p, x, ctx, capacity=B*S*cfg.top_k)
np.testing.assert_allclose(np.asarray(y_pk).reshape(B*S, D),
                           np.asarray(y_ref), rtol=2e-5, atol=2e-5)
print("MOE-PACKED-OK")
""", ndev=8)
    assert "MOE-PACKED-OK" in out


def test_preinit_makes_activation_fast():
    """IMM pre-initialization (compile cache) removes the dominant scale-up
    cost — the paper's Fig. 4a / Table 1 '-PreInit' effect."""
    out = run_with_devices(TEST_MOE + """
import time
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer

srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=64,
                    prefill_buckets=(32,), seed=0)
c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
srv.boot(c4)
srv.preinitialize(c6)                   # anticipate the target config
t0 = time.perf_counter()
srv.scale_to(c6)
warm = time.perf_counter() - t0
cold_compile = srv.imm._cache[srv.imm._key(c6)].compile_s
assert warm < cold_compile, (warm, cold_compile)
print(f"PREINIT-OK warm={warm:.2f}s cold_compile={cold_compile:.2f}s")
""")
    assert "PREINIT-OK" in out


def test_hmm_bytes_match_planner():
    """The HMM's measured transfer bytes agree with the logical planner:
    for a dense model growing 4->6 devices, P2P bytes == exactly the two new
    devices' shard bytes, zero local copies, and everything previously
    resident is reused zero-copy."""
    out = run_with_devices("""
import jax, numpy as np
from repro.configs.base import ModelConfig
from repro.core.topology import ElasticConfig
from repro.core.hmm import HMM

MCFG = ModelConfig(name="dense-t", arch_type="dense", num_layers=2,
                   d_model=64, vocab_size=128, num_heads=4, num_kv_heads=4,
                   head_dim=16, d_ff=128, dtype="float32")
hmm = HMM(MCFG, tp=2, batch_per_replica=2, max_len=32)
c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
hmm.boot(c4)
_, _, params, _ = hmm.attach_active()
# params-only resident bytes (KV state is handed over at commit, not stage)
resident_params = 0
seen = set()
for leaf in jax.tree.leaves(params):
    for sh in leaf.addressable_shards:
        ptr = sh.data.unsafe_buffer_pointer()
        if ptr not in seen:
            seen.add(ptr)
            resident_params += sh.data.nbytes
st = hmm.scale(c6)
# expected p2p: per-leaf bytes of the shards devices 4 and 5 must hold
mesh6 = __import__("repro.core.hmm", fromlist=["make_instance_mesh"]) \
    .make_instance_mesh(c6)
shardings = hmm.param_shardings(params, mesh6)
want = 0
for leaf, sh in zip(jax.tree.leaves(params), jax.tree.leaves(shardings)):
    for dev, idx in sh.devices_indices_map(leaf.shape).items():
        if dev.id in (4, 5):
            n = leaf.dtype.itemsize
            for d, sl in zip(leaf.shape, idx):
                n *= len(range(*sl.indices(d)))
            want += n
assert st.p2p_bytes == want, (st.p2p_bytes, want)
assert st.local_bytes == 0
# zero-copy bytes == every parameter byte resident on shared devices
assert st.zero_copy_bytes == resident_params, \
    (st.zero_copy_bytes, resident_params)
print("PLAN-MATCH-OK")
""")
    assert "PLAN-MATCH-OK" in out
