"""Training substrate + serving metrics + cost model + HLO analyzer tests."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config


def test_training_loss_decreases(tmp_path):
    from repro.training.train_loop import train
    cfg = get_config("qwen1.5-0.5b-smoke")
    out = train(cfg, steps=25, batch=8, seq_len=64, log_every=5)
    assert out["history"][-1][1] < out["history"][0][1]


def test_checkpoint_roundtrip(tmp_path):
    from repro.models.model import init_params
    from repro.training import checkpoint
    cfg = get_config("yi-6b-smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt.npz")
    checkpoint.save(path, params)
    restored = checkpoint.restore(path, jax.eval_shape(lambda: params))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, restored)


def test_metrics_ttft_tpot_slo():
    from repro.serving.metrics import SLO, meets_slo, slo_attainment
    from repro.serving.workload import Request
    r = Request(0, 10.0, 100, 11)
    r.first_token_s = 10.5
    r.finish_s = 15.5
    assert abs(r.ttft - 0.5) < 1e-9
    assert abs(r.tpot - 0.5) < 1e-9
    slo = SLO(ttft_s=1.0, tpot_s=1.0)
    assert meets_slo(r, slo)
    assert slo_attainment([r], slo) == 1.0
    assert meets_slo(r, SLO(ttft_s=0.1, tpot_s=1.0)) is False


def test_workload_rate_profiles():
    from repro.serving.workload import burst, make_workload, step_up
    reqs = make_workload(duration_s=30.0, rps_fn=step_up(1.0, 5.0, 15.0),
                         seed=0)
    early = sum(1 for r in reqs if r.arrival_s < 15)
    late = sum(1 for r in reqs if r.arrival_s >= 15)
    assert late > 2 * early


def test_load_estimator_decisions():
    from repro.core.coordinator import LoadEstimator, ScalingPolicy
    from repro.serving.metrics import SLO
    from repro.serving.workload import Request
    pol = ScalingPolicy(slo=SLO(1.0, 1.0), window=8, cooldown_s=0.0)
    est = LoadEstimator(pol)
    for i in range(8):
        r = Request(i, 0.0, 10, 5)
        r.first_token_s = 5.0   # ttft 5s -> violation
        r.finish_s = 6.0
        est.record(r)
    assert est.decide(100.0, queue_depth=0, utilization=0.9) == "up"
    for i in range(8):
        r = Request(i, 0.0, 10, 5)
        r.first_token_s = 0.1
        r.finish_s = 0.5
        est.record(r)
    assert est.decide(200.0, queue_depth=0, utilization=0.1) == "down"


def test_cost_model_reproduces_table1_ordering():
    """Ablation ordering (Table 1): full < -IPCAlloc < -HCCL < -PreInit <
    -ZeroCopy; downtime only without zero-copy."""
    from repro.core.costmodel import plan_cost
    from repro.core.scaling_plan import plan_elastic
    from repro.core.topology import ElasticConfig, kv_cache_bytes, model_tensors
    mcfg = get_config("deepseek-v2-lite-16b")
    tensors = model_tensors(mcfg, tp=2,
                            kv_bytes_per_replica=kv_cache_bytes(mcfg, 8, 4096))
    old = ElasticConfig(dp=3, tp=2, devices=tuple(range(6)))
    new = ElasticConfig(dp=4, tp=2, devices=tuple(range(8)))
    plan = plan_elastic(tensors, old, new)
    full = plan_cost(plan)
    no_ipc = plan_cost(plan, ipc_safe_alloc=False)
    no_hccl = plan_cost(plan, ipc_safe_alloc=False, hccl=False)
    no_pre = plan_cost(plan, ipc_safe_alloc=False, hccl=False, preinit=False)
    no_zc = plan_cost(plan, ipc_safe_alloc=False, hccl=False, preinit=False,
                      zero_copy=False)
    ts = [full.scale_time_s, no_ipc.scale_time_s, no_hccl.scale_time_s,
          no_pre.scale_time_s, no_zc.scale_time_s]
    assert ts == sorted(ts), ts
    assert full.downtime_s == 0 and no_pre.downtime_s == 0
    assert no_zc.downtime_s > 0
    assert no_ipc.peak_mem_gb > full.peak_mem_gb


def test_hlo_analyzer_counts_loops_and_collectives():
    """Known program: scan of n matmuls + psum -> analyzer must count
    n * 2*M*N*K flops and the all-reduce bytes."""
    from repro.analysis.hlo_costs import analyze
    n, m = 5, 128

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y.sum()

    x = jnp.ones((m, m), jnp.float32)
    w = jnp.ones((m, m), jnp.float32)
    hlo = jax.jit(f).lower(x, w).compile().as_text()
    costs = analyze(hlo)
    want = n * 2 * m * m * m
    assert 0.5 * want <= costs.flops <= 1.5 * want, (costs.flops, want)


def test_roofline_terms_and_bottleneck():
    from repro.analysis.roofline import Roofline
    r = Roofline(flops=1e15, hbm_bytes=1e12, coll_bytes={"all-reduce": 1e11},
                 chips=256, model_flops=5e14)
    assert abs(r.t_compute - 1e15 / (256 * 197e12)) < 1e-12
    assert r.bottleneck in ("compute", "memory", "collective")
    assert 0 < r.useful_flops_ratio < 1


def test_optimized_sharding_rules():
    """§Perf sharding rules: head-aligned KV replication and flash-decoding
    seq sharding of KV / MLA-latent caches."""
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed.sharding import cache_specs, param_specs
    from repro.models.model import init_cache, init_params

    devs = np.array(jax.devices() * 1)  # 1 device: shapes only matter
    # fake a (data=1, model=1) mesh: divisibility rules still evaluated vs 1
    # -> use eval_shape trees with a 16x16-shaped Mesh of repeated devices?
    # jax requires unique devices; test the rule function on shapes directly
    from repro.distributed.sharding import _spec_for_path

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    # kv proj 2 heads x 128 = 256 divisible by 16, but head-misaligned:
    s_naive = _spec_for_path("blocks/attn/k/w", (28, 4096, 256), m, 1, None)
    s_aligned = _spec_for_path("blocks/attn/k/w", (28, 4096, 256), m, 1, 2)
    assert s_naive == P(None, None, "model")
    assert s_aligned == P(None, None, None)
    # 32 kv heads stay sharded either way
    s32 = _spec_for_path("blocks/attn/k/w", (32, 2560, 2560), m, 1, 32)
    assert s32 == P(None, None, "model")

    cfg = get_config("chatglm3-6b")
    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 1024))
    specs = cache_specs(cfg, cache, m, kv_seq_shard=True)
    assert specs["k"] == P(None, ("data",), "model", None, None)
    cfg2 = get_config("deepseek-v2-lite-16b")
    cache2 = jax.eval_shape(lambda: init_cache(cfg2, 128, 1024))
    specs2 = cache_specs(cfg2, cache2, m, kv_seq_shard=True)
    assert specs2["c"] == P(None, ("data",), "model", None)
