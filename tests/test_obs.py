"""Unit tests for the tracing/telemetry layer (repro.obs, DESIGN.md §9):
ring-buffer bounds, thread lanes, the disabled fast path, the injectable
clock, Chrome-trace export schema, and tools/trace_report.py."""
from __future__ import annotations

import json
import sys
import threading
from pathlib import Path

import pytest

from repro import obs
from repro.obs import tracer as tracer_mod

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _no_global_tracer():
    """Tests install their own tracer; always restore the null default."""
    yield
    obs.install(None)


# ---------------------------------------------------------------- recording

def test_span_complete_instant_counter():
    t = iter(range(100))
    tr = obs.Tracer(clock=lambda: float(next(t)))
    with tr.span("work", cat="serve", args={"k": 1}):
        pass
    tr.complete("staged", 10.0, 12.5, cat="transfer")
    tr.instant("admit", cat="req", args={"rid": 7})
    tr.counter("depth", 3.0, cat="serve")
    evs = tr.events()
    assert [e.ph for e in evs] == ["X", "X", "i", "C"]
    span = evs[0]
    assert span.name == "work" and span.cat == "serve"
    assert (span.t0, span.t1) == (0.0, 1.0) and span.dur == 1.0
    assert evs[1].dur == 2.5
    assert evs[3].args == {"value": 3.0}


def test_ring_buffer_bounded():
    tr = obs.Tracer(capacity=16)
    for i in range(100):
        tr.instant(f"e{i}")
    evs = tr.events()
    assert len(evs) == 16
    assert evs[0].name == "e84" and evs[-1].name == "e99"  # oldest evicted
    tr.clear()
    assert tr.events() == []


def test_explicit_time_span_out_of_order_ok():
    tr = obs.Tracer()
    tr.complete("later", 5.0, 6.0)
    tr.complete("earlier", 1.0, 2.0)   # explicit timestamps need no ordering
    assert [e.t0 for e in tr.events()] == [5.0, 1.0]


def test_thread_lanes_and_names():
    tr = obs.Tracer()
    tr.instant("main-ev")

    def worker():
        tr.complete("op", 0.0, 1.0, cat="transfer")

    th = threading.Thread(target=worker, name="hmm-transfer-test")
    th.start()
    th.join()
    main_ev, op = tr.events()
    assert main_ev.tid == threading.get_ident()
    assert op.tid != main_ev.tid
    assert tr.thread_names()[op.tid] == "hmm-transfer-test"


def test_string_lane_passthrough():
    tr = obs.Tracer()
    tr.complete("scale.STAGING", 0.0, 1.0, cat="scale", tid="scale")
    assert tr.events()[0].tid == "scale"


def test_metrics_registry():
    m = obs.MetricsRegistry()
    m.inc("ticks")
    m.inc("ticks", 2)
    m.gauge("util", 0.5)
    snap = m.snapshot()
    assert snap["counters"] == {"ticks": 3}
    assert snap["gauges"] == {"util": 0.5}


# ------------------------------------------------------------ null fast path

def test_null_tracer_is_default_and_noop():
    assert obs.get_tracer() is obs.NULL_TRACER
    nt = obs.NULL_TRACER
    assert nt.enabled is False and nt.metrics is None
    nt.complete("x", 0, 1)
    nt.instant("x")
    nt.counter("x", 1.0)
    with nt.span("x"):
        pass
    assert nt.events() == [] and nt.thread_names() == {}
    assert nt.now() > 0  # still a usable clock for unconditional call sites


def test_install_and_reset():
    tr = obs.Tracer()
    assert obs.install(tr) is tr
    assert obs.get_tracer() is tr
    assert obs.install(None) is obs.NULL_TRACER
    assert obs.get_tracer() is obs.NULL_TRACER


def test_traced_decorator_short_circuits_when_disabled(monkeypatch):
    calls = []

    @obs.traced("unit.fn", cat="test")
    def fn(x):
        calls.append(x)
        return x * 2

    # disabled: no span machinery, result passes through
    assert fn(3) == 6
    tr = obs.Tracer()
    obs.install(tr)
    assert fn(4) == 8
    assert calls == [3, 4]
    evs = tr.events()
    assert len(evs) == 1 and evs[0].name == "unit.fn" and evs[0].cat == "test"

    # sabotage the real span path: the disabled branch must never touch it
    obs.install(None)
    monkeypatch.setattr(obs.Tracer, "span",
                        lambda *a, **k: pytest.fail("span on disabled path"))
    assert fn(5) == 10


# ------------------------------------------------------------------- export

def _sample_tracer():
    tr = obs.Tracer(clock=lambda: 0.0)
    tr.complete("scale.STAGING", 100.0, 101.0, cat="scale", tid="scale")
    tr.complete("decode.tick", 100.2, 100.3, cat="serve")
    tr.instant("req.admit", cat="req", t=100.1, args={"rid": 1})
    tr.counter("routing.top_expert_share", 0.25, cat="routing", t=100.4)
    return tr


def test_chrome_trace_schema_and_normalization():
    tr = _sample_tracer()
    doc = obs.chrome_trace(tr, extra_metadata={"run": "unit"})
    obs.validate_trace(doc)
    assert doc["displayTimeUnit"] == "ms"
    assert doc["metadata"] == {"run": "unit"}
    evs = [r for r in doc["traceEvents"] if r["ph"] != "M"]
    # ts normalized to µs relative to the earliest event
    assert min(r["ts"] for r in evs) == 0.0
    span = next(r for r in evs if r["name"] == "scale.STAGING")
    assert span["ph"] == "X" and span["dur"] == pytest.approx(1e6)
    assert span["tid"] < 0  # synthetic string lane
    names = [r for r in doc["traceEvents"]
             if r["ph"] == "M" and r["name"] == "thread_name"]
    assert any(r["args"]["name"] == "scale" and r["tid"] == span["tid"]
               for r in names)
    inst = next(r for r in evs if r["name"] == "req.admit")
    assert inst["s"] == "t" and inst["args"] == {"rid": 1}
    ctr = next(r for r in evs if r["ph"] == "C")
    assert ctr["args"] == {"value": 0.25}


def test_write_and_load_roundtrip(tmp_path):
    tr = _sample_tracer()
    path = tmp_path / "trace.json"
    written = obs.write_chrome_trace(str(path), tr)
    loaded = obs.load_trace(str(path))
    assert loaded == json.loads(json.dumps(written))


def test_validate_trace_rejects_malformed():
    with pytest.raises(AssertionError):
        obs.validate_trace({"events": []})
    with pytest.raises(AssertionError):
        obs.validate_trace({"traceEvents": [{"ph": "X", "pid": 1, "tid": 0,
                                             "ts": 0, "name": "x"}]})  # no dur


def test_sim_clock_domain():
    sim_t = [0.0]
    tr = obs.Tracer(clock=lambda: sim_t[0])
    with tr.span("tick"):
        sim_t[0] = 2.5
    ev = tr.events()[0]
    assert (ev.t0, ev.t1) == (0.0, 2.5)


# ------------------------------------------------------------- trace_report

def _report_mod():
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import trace_report
    finally:
        sys.path.pop(0)
    return trace_report


def test_trace_report_summary_and_overlap(tmp_path, capsys):
    rep = _report_mod()
    tr = obs.Tracer()
    tr.complete("w0", 0.0, 1.0, cat="transfer", tid="a")
    tr.complete("w0", 2.0, 3.0, cat="transfer", tid="a")
    tr.complete("decode.tick", 0.5, 0.6, cat="serve")       # overlaps w0 #1
    tr.complete("scale.STAGING", 0.0, 3.0, cat="scale", tid="scale")
    doc = obs.chrome_trace(tr)

    rows = rep.summary_rows(doc)
    by_name = {r[1]: r for r in rows}
    assert by_name["w0"][2] == 2                      # count
    assert by_name["w0"][3] == pytest.approx(2000.0)  # total_ms
    assert rows[0][1] == "scale.STAGING"              # sorted by total desc
    only = rep.summary_rows(doc, cat="transfer")
    assert {r[1] for r in only} == {"w0"}

    n_tr, n_ov, n_ticks = rep.overlap_report(doc)
    assert (n_tr, n_ov, n_ticks) == (2, 1, 1)

    path = tmp_path / "t.json"
    path.write_text(json.dumps(doc))
    assert rep.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "span summary" in out and "phase timeline" in out
    assert "transfer spans overlapping a decode tick: 1" in out
