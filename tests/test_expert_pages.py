"""Property tests on the virtual expert page table (vpage-remap analogue)."""
import pytest

pytest.importorskip("hypothesis")  # optional test extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.expert_pages import ExpertPageTable
from repro.core.topology import ElasticConfig, expert_owner

sizes = st.sampled_from([2, 4, 6, 8, 12])


def cfg_of(n):
    return ElasticConfig(dp=n // 2, tp=2, devices=tuple(range(n)))


def make_table(L=3, E=24, n0=4):
    t = ExpertPageTable(L, E)
    t.initial_place(cfg_of(n0))
    return t


@settings(max_examples=30, deadline=None)
@given(n0=sizes, seq=st.lists(sizes, min_size=1, max_size=4))
def test_remap_sequence_invariants(n0, seq):
    L, E = 3, 24
    t = make_table(L, E, n0)
    for n in seq:
        cfg = cfg_of(n)
        old_active = dict(t.active)
        migrations = t.stage_remap(cfg)
        # staged table: every expert mapped exactly once, onto new devices
        assert set(t.staged) == {(l, e) for l in range(L) for e in range(E)}
        assert all(ref.device in cfg.devices for ref in t.staged.values())
        # balanced placement: per layer, each device owns floor/ceil(E/n)
        base, extra = divmod(E, n)
        for l in range(L):
            counts = {}
            for e in range(E):
                d = t.staged[(l, e)].device
                counts[d] = counts.get(d, 0) + 1
            assert sorted(counts.values()) == sorted(
                [base + 1] * extra + [base] * (n - extra))
        # migrations cover exactly the experts whose device changed
        moved = {(m.layer, m.expert) for m in migrations}
        want = {k for k, ref in old_active.items()
                if t.staged[k].device != ref.device}
        assert moved == want
        # min-move optimality: per layer, moves == E - sum(min(held, cap))
        for l in range(L):
            held = {}
            for e in range(E):
                d = old_active[(l, e)].device
                held[d] = held.get(d, 0) + 1
            caps = {d: base + (1 if i < extra else 0)
                    for i, d in enumerate(cfg.devices)}
            stay_max = sum(min(held.get(d, 0), caps[d]) for d in cfg.devices)
            n_moves = sum(1 for (ll, _) in moved if ll == l)
            assert n_moves == E - stay_max
        # zero-copy experts keep their page (no reallocation)
        for k, ref in old_active.items():
            if k not in moved:
                assert t.staged[k] == ref
        t.commit()
        # pool conservation: pages in use == experts owned per device
        for d in cfg.devices:
            owned = sum(1 for ref in t.active.values() if ref.device == d)
            assert t.pages_in_use(d) == owned


@settings(max_examples=20, deadline=None)
@given(n0=sizes, n1=sizes)
def test_abort_restores_pool(n0, n1):
    t = make_table(n0=n0)
    in_use_before = {d: t.pages_in_use(d) for d in cfg_of(12).devices}
    t.stage_remap(cfg_of(n1))
    t.abort()
    for d, n in in_use_before.items():
        assert t.pages_in_use(d) == n
    assert t.staged is None


def test_double_buffering_keeps_old_mapping_active():
    """'Old mappings remain active on source devices until the new instance
    takes over' (§5.2): the active table is untouched by staging."""
    t = make_table()
    before = dict(t.active)
    t.stage_remap(cfg_of(8))
    assert t.active == before
    t.commit()
    assert t.active != before


def test_device_table_sorted_logical_order():
    t = make_table()
    cfg = cfg_of(4)
    for d in cfg.devices:
        pages = t.device_table(cfg, layer=0, device=d)
        owners = t.owners(0)[d]
        assert len(pages) == len(owners)
