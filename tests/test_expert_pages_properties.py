"""Hypothesis property suite for the virtual expert page table.

Random sequences of ``stage_remap`` / ``commit`` / ``abort`` across random
``ElasticConfig`` ladders must conserve pages — no leak, no double-mapping,
``pages_in_use`` equal to table cardinality per device — and ``min_move=True``
must never migrate more pages than the contiguous (``min_move=False``)
placement.  The error-path contracts (staged ``device_table`` without a
session, double-staging, idempotent ``abort``) are pinned by unit tests in
the same file.

CI runs this file as a dedicated tier-1 step under the fixed profile
registered below (deadline disabled, derandomized) so it cannot flake.
"""
import copy
import os

import pytest

pytest.importorskip("hypothesis")  # optional test extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.expert_pages import ExpertPageTable, pooled_layout
from repro.core.topology import ElasticConfig

# deterministic, deadline-free profiles — the CI tier-1 job depends on them:
# the ordinary tier-1 pass uses the default budget; the dedicated CI step
# selects 'repro-ci-thorough' via HYPOTHESIS_PROFILE for a deeper sweep
settings.register_profile("repro-ci", deadline=None, derandomize=True,
                          max_examples=40)
settings.register_profile("repro-ci-thorough", deadline=None,
                          derandomize=True, max_examples=300)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "repro-ci"))

SIZES = [1, 2, 3, 4, 6, 8, 12]


def cfg_of(n):
    return ElasticConfig(dp=n, tp=1, devices=tuple(range(n)))


# ------------------------------------------------------------- invariants

def assert_conserved(t: ExpertPageTable):
    """Committed-state conservation: every (layer, expert) mapped to exactly
    one page, no page mapped twice, pool accounting matches the table."""
    refs = list(t.active.values())
    assert len(set(refs)) == len(refs), "page double-mapped"
    per = {}
    for ref in refs:
        per[ref.device] = per.get(ref.device, 0) + 1
    for d in set(t._free) | set(per):
        assert t.pages_in_use(d) == per.get(d, 0), \
            f"device {d}: pages_in_use != mapped pages (leak or dangle)"
        free = t._free[d]
        assert len(set(free)) == len(free), "free list duplicate"
        used = {r.page for r in refs if r.device == d}
        assert not set(free) & used, "page both free and mapped"


def assert_staged_conserved(t: ExpertPageTable):
    """Mid-session conservation: active pages + freshly allocated staged
    pages are each held exactly once."""
    held = set(t.active.values()) | set(t.staged.values())
    per = {}
    for ref in held:
        per[ref.device] = per.get(ref.device, 0) + 1
    for d in set(t._free) | set(per):
        assert t.pages_in_use(d) == per.get(d, 0)
        assert not {r.page for r in held if r.device == d} & set(t._free[d])


# ------------------------------------------------------- property tests

@given(E=st.sampled_from([6, 8, 12, 24]), L=st.integers(1, 3),
       n0=st.sampled_from(SIZES),
       seq=st.lists(st.tuples(st.sampled_from(SIZES), st.booleans(),
                              st.sampled_from(["commit", "abort"])),
                    min_size=1, max_size=6))
def test_page_conservation_over_random_sessions(E, L, n0, seq):
    t = ExpertPageTable(L, E)
    t.initial_place(cfg_of(n0))
    assert_conserved(t)
    committed = cfg_of(n0)
    for n, mm, action in seq:
        cfg = cfg_of(n)
        t.stage_remap(cfg, min_move=mm)
        assert_staged_conserved(t)
        if action == "commit":
            t.commit()
            committed = cfg
        else:
            t.abort()
            t.abort()                    # idempotent: second call is a no-op
        assert_conserved(t)
        # every expert still mapped exactly once onto the committed config
        assert set(t.active) == {(l, e) for l in range(L) for e in range(E)}
        assert all(r.device in committed.devices for r in t.active.values())


@st.composite
def _min_move_case(draw):
    E = draw(st.sampled_from([6, 8, 12, 24]))
    L = draw(st.integers(1, 3))
    n0 = draw(st.sampled_from(SIZES))
    hops = draw(st.lists(st.sampled_from(SIZES), min_size=0, max_size=3))
    # final target where contiguous placement is itself strictly balanced
    # (E % n == 0): there min-move optimality is comparable apples-to-apples.
    # On ragged targets expert_owner may leave devices empty, and min_move
    # pays extra migrations to enforce floor/ceil balance — by design.
    n_final = draw(st.sampled_from([n for n in SIZES if E % n == 0]))
    return E, L, n0, hops, n_final


@given(case=_min_move_case())
def test_min_move_never_migrates_more(case):
    """From ANY reachable placement (random committed history), min-move
    staging migrates no more pages than the contiguous placement, for every
    balanced target."""
    E, L, n0, hops, n_final = case
    t = ExpertPageTable(L, E)
    t.initial_place(cfg_of(n0))
    for n in hops:
        t.stage_remap(cfg_of(n), min_move=True)
        t.commit()
    contig = copy.deepcopy(t)
    n_min = len(t.stage_remap(cfg_of(n_final), min_move=True))
    n_con = len(contig.stage_remap(cfg_of(n_final), min_move=False))
    assert n_min <= n_con, (n_min, n_con)
    t.abort()
    contig.abort()
    assert_conserved(t)
    assert_conserved(contig)


@given(E=st.sampled_from([8, 24]), n0=st.sampled_from(SIZES),
       n1=st.sampled_from(SIZES))
def test_pooled_layout_round_trips_the_table(E, n0, n1):
    """The execution-layout arrays agree with the table they were built
    from: every expert's (rank, slot) points back at its page."""
    L, ppd = 2, 2 * E
    t = ExpertPageTable(L, E, pool_pages_per_device=ppd)
    cfg0, cfg1 = cfg_of(n0), cfg_of(n1)
    t.initial_place(cfg0)
    t.stage_remap(cfg1, min_move=True)
    for table_map, cfg in ((t.active, cfg0), (t.staged, cfg1)):
        lay = pooled_layout(table_map, cfg, L, E, ppd)
        for l in range(L):
            for e in range(E):
                ref = table_map[(l, e)]
                r, s = lay["edest"][l, e], lay["eslot"][l, e]
                assert cfg.devices[r] == ref.device
                assert lay["tables"][l, r, s] == ref.page
                assert lay["gtable"][l, e] == r * ppd + ref.page
    t.abort()


# ------------------------------------------------------ error-path units

def test_device_table_staged_without_session_raises():
    t = ExpertPageTable(2, 8)
    t.initial_place(cfg_of(2))
    with pytest.raises(RuntimeError, match="no staged remap"):
        t.device_table(cfg_of(2), layer=0, device=0, staged=True)
    t.stage_remap(cfg_of(4))
    t.device_table(cfg_of(4), layer=0, device=0, staged=True)  # now legal
    t.abort()
    with pytest.raises(RuntimeError, match="no staged remap"):
        t.device_table(cfg_of(4), layer=0, device=0, staged=True)


def test_double_staging_raises_instead_of_leaking():
    t = ExpertPageTable(2, 8)
    t.initial_place(cfg_of(2))
    t.stage_remap(cfg_of(4))
    with pytest.raises(RuntimeError, match="already open"):
        t.stage_remap(cfg_of(3))
    t.abort()
    t.stage_remap(cfg_of(3))             # legal again after abort
    t.commit()
    assert_conserved(t)


def test_commit_without_session_raises():
    t = ExpertPageTable(1, 4)
    t.initial_place(cfg_of(2))
    with pytest.raises(RuntimeError, match="no staged remap"):
        t.commit()


def test_failed_stage_remap_returns_popped_pages():
    """A MemoryError mid-staging (pool exhausted) must not strand pages
    already popped from the free lists — the pool is exactly as before, so
    a smaller later remap that would fit still succeeds."""
    L, E = 2, 8
    t = ExpertPageTable(L, E, pool_pages_per_device=L * E // 2)  # tight pool
    t.initial_place(cfg_of(2))
    before = {d: t.pages_in_use(d) for d in range(4)}
    with pytest.raises(MemoryError):
        t.stage_remap(cfg_of(1), min_move=True)   # needs E extra on dev 0
    assert t.staged is None
    for d, n in before.items():
        assert t.pages_in_use(d) == n, d
    # a feasible remap still works afterwards
    t.stage_remap(cfg_of(4), min_move=True)
    t.commit()
    assert_conserved(t)


def test_clone_is_independent():
    t = ExpertPageTable(2, 8)
    t.initial_place(cfg_of(2))
    c = t.clone()
    c.stage_remap(cfg_of(4), min_move=True)
    c.commit()
    assert t.staged is None
    assert all(r.device in (0, 1) for r in t.active.values())
    assert_conserved(t)
    assert_conserved(c)


def test_abort_idempotent_and_preserves_shared_pages():
    """abort() frees only staged-only pages, exactly once: pages shared
    between the active and staged tables (unmoved experts) stay allocated,
    and repeated aborts change nothing."""
    t = ExpertPageTable(2, 8)
    t.initial_place(cfg_of(4))
    before = {d: t.pages_in_use(d) for d in range(4)}
    t.stage_remap(cfg_of(2))             # some pages shared, some fresh
    shared = [r for k, r in t.staged.items() if t.active.get(k) == r]
    assert shared, "remap should keep some experts in place"
    for _ in range(3):
        t.abort()
        for d, n in before.items():
            assert t.pages_in_use(d) == n
    assert_conserved(t)
