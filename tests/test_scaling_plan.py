"""Unit + property tests on the scaling planner (the paper's §4.4 logic)."""
import math
import re

import pytest
pytest.importorskip("hypothesis")  # optional test extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core.scaling_plan import (Op, STRATEGIES, placement, plan_cold_restart,
                                     plan_colocated, plan_elastic,
                                     plan_extravagant)
from repro.core.topology import (ElasticConfig, expert_owner, kv_cache_bytes,
                                 model_tensors)

MCFG = get_config("deepseek-v2-lite-16b")
KV = kv_cache_bytes(MCFG, batch=8, max_len=4096)
TENSORS = model_tensors(MCFG, tp=2, kv_bytes_per_replica=KV)


def cfg_of(n, tp=2, base=0):
    return ElasticConfig(dp=n // tp, tp=tp,
                         devices=tuple(range(base, base + n)))


# ------------------------------------------------------------------- units

def test_paper_example_4_to_6():
    """Paper §5.2: DP2-TP2-EP4 on NPUs 0-3 -> DP3-TP2-EP6 on NPUs 0-5,
    with the min-move page-table expert placement (paper-faithful)."""
    from repro.core.expert_pages import ExpertPageTable
    from repro.core.scaling_plan import plan_elastic_paged
    old, new = cfg_of(4), cfg_of(6)
    table = ExpertPageTable(MCFG.num_layers - MCFG.first_k_dense,
                            MCFG.num_experts)
    table.initial_place(old)
    plan = plan_elastic_paged(TENSORS, old, new, table,
                              first_k_dense=MCFG.first_k_dense)
    by = plan.bytes_by_op()
    # zero-copy dominates on shared devices; no disk at all
    assert Op.DISK not in by
    assert by[Op.ZERO_COPY] > by.get(Op.P2P, 0)
    # new devices get attention weights via P2P and fresh KV via INIT
    p2p_dst = {s.dst for s in plan.steps if s.op == Op.P2P}
    assert p2p_dst <= {4, 5}   # min-move: only new devices receive bytes
    init_dst = {s.dst for s in plan.steps if s.op == Op.INIT}
    assert init_dst == {4, 5}
    # KV on surviving devices is reused (zero-copy), never re-initialized
    kv_steps = [s for s in plan.steps if "kv" in s.key.tensor and s.dst < 4]
    assert all(s.op == Op.ZERO_COPY for s in kv_steps)


def test_min_move_beats_contiguous():
    """The page-table (min-move) remap transfers strictly fewer bytes than
    the contiguous dense-layout remap for an uneven transition."""
    from repro.core.expert_pages import ExpertPageTable
    from repro.core.scaling_plan import plan_elastic_paged
    old, new = cfg_of(4), cfg_of(6)
    table = ExpertPageTable(MCFG.num_layers - MCFG.first_k_dense,
                            MCFG.num_experts)
    table.initial_place(old)
    paged = plan_elastic_paged(TENSORS, old, new, table,
                               first_k_dense=MCFG.first_k_dense)
    contiguous = plan_elastic(TENSORS, old, new)
    assert paged.bytes_by_op().get(Op.P2P, 0) < \
        contiguous.bytes_by_op().get(Op.P2P, 0)


def test_scale_down_is_mostly_free():
    old, new = cfg_of(6), cfg_of(4)
    plan = plan_elastic(TENSORS, old, new)
    by = plan.bytes_by_op()
    assert Op.DISK not in by
    # only expert migration moves bytes
    for s in plan.steps:
        if s.op == Op.P2P:
            assert "expert" in s.key.tensor


def test_tp_fixed_enforced():
    with pytest.raises(AssertionError):
        plan_elastic(TENSORS, cfg_of(4, tp=2),
                     ElasticConfig(dp=2, tp=4, devices=tuple(range(8))))


def test_cold_restart_reloads_everything():
    old, new = cfg_of(4), cfg_of(6)
    plan = plan_cold_restart(TENSORS, old, new)
    by = plan.bytes_by_op()
    assert Op.ZERO_COPY not in by and Op.P2P not in by
    place = placement(TENSORS, new)
    want_disk = sum(b for shards in place.values()
                    for key, b in shards.items() if "kv" not in key.tensor)
    assert by[Op.DISK] == want_disk


def test_extravagant_needs_disjoint_devices():
    old = cfg_of(4)
    new = cfg_of(6, base=4)
    plan = plan_extravagant(TENSORS, old, new)
    assert Op.ZERO_COPY not in plan.bytes_by_op()
    with pytest.raises(AssertionError):
        plan_extravagant(TENSORS, old, cfg_of(6))


# -------------------------------------------------------------- properties

sizes = st.sampled_from([2, 4, 6, 8, 12, 16])


@settings(max_examples=25, deadline=None)
@given(n_old=sizes, n_new=sizes)
def test_plan_covers_target_placement_exactly(n_old, n_new):
    """Every (device, shard) of the target placement is produced by exactly
    one non-FREE step; FREEs cover exactly the dropped shards."""
    old, new = cfg_of(n_old), cfg_of(n_new)
    plan = plan_elastic(TENSORS, old, new)
    produced = {}
    for s in plan.steps:
        if s.op == Op.FREE:
            continue
        key = (s.dst, s.key)
        assert key not in produced, f"duplicate step for {key}"
        produced[key] = s
    want = {(d, k) for d, shards in placement(TENSORS, new).items()
            for k in shards}
    assert set(produced) == want

    old_place = placement(TENSORS, old)
    freed = {(s.dst, s.key) for s in plan.steps if s.op == Op.FREE}
    want_freed = {(d, k) for d, shards in old_place.items() for k in shards
                  if (d, k) not in want}
    assert freed == want_freed


@settings(max_examples=25, deadline=None)
@given(n_old=sizes, n_new=sizes)
def test_p2p_sources_hold_content_and_no_disk(n_old, n_new):
    """P2P steps always read from a device that holds identical content under
    the old config; elastic scaling never touches disk."""
    old, new = cfg_of(n_old), cfg_of(n_new)
    plan = plan_elastic(TENSORS, old, new)
    old_place = placement(TENSORS, old)
    for s in plan.steps:
        assert s.op != Op.DISK
        if s.op == Op.P2P:
            assert s.src in old_place and s.key in old_place[s.src]
        if s.op == Op.ZERO_COPY:
            assert s.key in old_place[s.dst]


@settings(max_examples=25, deadline=None)
@given(n_old=sizes, n_new=sizes)
def test_elastic_moves_fewest_bytes(n_old, n_new):
    """The elastic plan's (p2p + disk) bytes never exceed any baseline's."""
    old, new = cfg_of(n_old), cfg_of(n_new)
    pe = plan_elastic(TENSORS, old, new).bytes_by_op()
    moved_e = pe.get(Op.P2P, 0) + pe.get(Op.DISK, 0)
    pc = plan_cold_restart(TENSORS, old, new).bytes_by_op()
    moved_c = pc.get(Op.P2P, 0) + pc.get(Op.DISK, 0)
    assert moved_e <= moved_c


@settings(max_examples=20, deadline=None)
@given(n=sizes, grow=st.integers(1, 3))
def test_identity_and_growth_monotonicity(n, grow):
    """Scaling to the same config is 100% zero-copy; growing only adds
    transfer for new devices."""
    old = cfg_of(n)
    same = plan_elastic(TENSORS, old, cfg_of(n))
    by = same.bytes_by_op()
    assert set(by) == {Op.ZERO_COPY}
    bigger = cfg_of(n + 2 * grow)
    plan = plan_elastic(TENSORS, old, bigger)
    for s in plan.steps:
        if s.op in (Op.P2P, Op.INIT):
            assert s.dst >= n or "expert" in s.key.tensor


@settings(max_examples=20, deadline=None)
@given(n_old=sizes, n_new=sizes)
def test_expert_ownership_matches_plan(n_old, n_new):
    old, new = cfg_of(n_old), cfg_of(n_new)
    plan = plan_elastic(TENSORS, old, new)
    E = MCFG.num_experts
    for s in plan.steps:
        m = re.search(r"/expert(\d+)$", s.key.tensor)
        if not m or s.op == Op.FREE:
            continue
        assert s.dst == expert_owner(int(m.group(1)), E, new)
