"""Property tests on the KV block manager (serving/kv_blocks.py):
conservation — no block leaked, double-owned, or double-freed — across
random alloc/append/free/preempt/CoW/grow interleavings."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test extra
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.kv_blocks import KVBlockManager


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_no_block_leaked_or_double_owned(seed):
    """Random interleaving of allocate (with prefix sharing), append (CoW),
    free, preempt, and partition grow: after every operation the pool
    conserves — every block is either free exactly once or refcounted by
    exactly its holders."""
    rng = np.random.default_rng(seed)
    m = KVBlockManager(2, 6, 4)
    next_seq = 0
    for _ in range(120):
        op = rng.integers(0, 10)
        live = m.live_seqs()
        if op <= 3:                                        # allocate
            part = int(rng.integers(0, m.num_partitions))
            n = int(rng.integers(1, 16))
            toks = [int(t) for t in rng.integers(0, 3, n)]  # tiny vocab:
            try:                                            # forced overlap
                m.allocate(next_seq, n, partition=part,
                           priority=int(rng.integers(0, 3)), tokens=toks)
                next_seq += 1
            except MemoryError:
                v = m.victim()
                if v is not None:
                    m.preempt(v)
        elif op <= 6 and live:                             # append
            s = int(rng.choice(live))
            try:
                m.append(s)
            except MemoryError:
                v = m.victim(exclude=(s,))
                if v is not None:
                    m.preempt(v)
        elif op == 7 and live:                             # free
            m.free(int(rng.choice(live)))
        elif op == 8 and live:                             # preempt victim
            v = m.victim()
            if v is not None:
                m.preempt(v)
        elif op == 9 and m.num_partitions < 4:             # scale up
            m.grow_partitions(m.num_partitions + 1)
        m.check_invariants()
    for s in m.live_seqs():
        m.free(s)
    m.check_invariants()
    assert m.used_blocks() == 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_shared_prefix_refcounts_converge(seed):
    """Many sequences over a tiny vocab share heavily; freeing them all in
    random order always returns the pool to empty."""
    rng = np.random.default_rng(seed)
    m = KVBlockManager(1, 32, 4)
    seqs = []
    for s in range(10):
        n = int(rng.integers(1, 20))
        toks = [int(t) for t in rng.integers(0, 2, n)]
        try:
            m.allocate(s, n, partition=0, tokens=toks)
            seqs.append(s)
        except MemoryError:
            break
        for _ in range(int(rng.integers(0, 4))):
            try:
                m.append(s)
            except MemoryError:
                break
        m.check_invariants()
    rng.shuffle(seqs)
    for s in seqs:
        m.free(s)
        m.check_invariants()
    assert m.used_blocks() == 0 and m.free_blocks() == 32


