"""Skew-aware expert rebalancing (DESIGN.md §10): replica sets, the
pinned-host cold tier, and the two-phase rebalance session.

Fast (single device): page-table lifecycle (stage/commit/abort conserve the
device AND host pools), replica-aware ``pooled_layout`` (least-loaded
assignment, legacy byte-identity, slot-overflow), min-move scale staging
over replicas and host-sourced migrations, ``plan_elastic_paged``/costmodel
``Op.HOST`` accounting, ``RebalancePolicy`` hysteresis, and the simulator
parity loop (sim-owned table + Zipf routing model + shared policy).

Slow (subprocess, 8 host devices): mid-serving rebalance on the real JAX
engine — the policy replicates hot experts and demotes cold ones while
tokens stay bit-identical to the dense run; abort-in-flight conserves both
tiers; a scale event over a fully demoted expert set streams H2D from the
host tier with ZERO expert P2P; routing histograms reset at scale commit.
"""
import numpy as np
import pytest

from helpers import TEST_MOE, run_with_devices

TEST_MOE_CFG = None


def _mcfg():
    global TEST_MOE_CFG
    if TEST_MOE_CFG is None:
        ns = {}
        exec(TEST_MOE, ns)
        TEST_MOE_CFG = ns["MCFG"]
    return TEST_MOE_CFG


def _table(cfg, host_pool_pages=None):
    from repro.core.expert_pages import ExpertPageTable
    mcfg = _mcfg()
    t = ExpertPageTable(mcfg.num_layers, mcfg.num_experts,
                        host_pool_pages=host_pool_pages)
    t.initial_place(cfg)
    return t


def _c4():
    from repro.core.topology import ElasticConfig
    return ElasticConfig(dp=2, tp=2, devices=(0, 1, 2, 3))


def _c6():
    from repro.core.topology import ElasticConfig
    return ElasticConfig(dp=3, tp=2, devices=(0, 1, 2, 3, 4, 5))


def _usage(t, devices):
    from repro.core.expert_pages import HOST
    return {d: t.pages_in_use(d) for d in list(devices) + [HOST]}


# ------------------------------------------------- page-table lifecycle

def test_rebalance_stage_commit_replicate_demote():
    from repro.core.expert_pages import HOST
    cfg = _c4()
    t = _table(cfg)
    before = _usage(t, cfg.devices)
    hot = (0, 0)                      # primary on device 0
    cold = (1, 23)                    # primary on device 3
    ops = t.stage_rebalance([("replicate", *hot, 1), ("demote", *cold)])
    assert [op.kind for op in ops] == ["replicate", "demote"]
    # staged but not applied: replica/host sets untouched, pages reserved
    assert t.replica_count(*hot) == 0 and not t.host
    assert t.pages_in_use(1) == before[1] + 1
    assert t.pages_in_use(HOST) == 1
    t.commit_rebalance()
    assert t.replica_count(*hot) == 1
    assert t.replicas[hot][0].device == 1
    assert t.demoted() == [cold]
    assert t.host[cold].is_host
    # demotion RETAINS the device primary (bit-identity never at risk)
    assert t.active[cold].device == 3
    # undo both: drop_replica + promote free exactly the staged pages
    t.stage_rebalance([("drop_replica", *hot, 1), ("promote", *cold)])
    freed = t.commit_rebalance()
    assert len(freed) == 2
    assert t.replica_count(*hot) == 0 and not t.host
    assert _usage(t, cfg.devices) == before


def test_abort_in_flight_conserves_both_tiers():
    cfg = _c4()
    t = _table(cfg)
    before = _usage(t, cfg.devices)
    active_before = dict(t.active)
    t.stage_rebalance([("replicate", 0, 0, 2), ("replicate", 0, 1, 3),
                       ("demote", 1, 5), ("demote", 1, 6)])
    t.abort_rebalance()
    t.abort_rebalance()               # idempotent
    assert t.staged_rebalance is None
    assert _usage(t, cfg.devices) == before
    assert t.active == active_before
    assert not t.replicas and not t.host


def test_stage_rebalance_validation_and_rollback():
    cfg = _c4()
    t = _table(cfg)
    before = _usage(t, cfg.devices)
    # duplicate copy on a device that already holds one
    dev0 = t.active[(0, 0)].device
    with pytest.raises(ValueError):
        t.stage_rebalance([("replicate", 0, 0, dev0)])
    # a failing action mid-list rolls back the pages popped before it
    with pytest.raises(ValueError):
        t.stage_rebalance([("replicate", 0, 0, 1), ("demote", 0, 1),
                           ("promote", 0, 2)])     # (0,2) not demoted
    assert _usage(t, cfg.devices) == before
    assert t.staged_rebalance is None
    # double demote / unknown kinds / missing replica
    t.stage_rebalance([("demote", 0, 0)])
    t.commit_rebalance()
    with pytest.raises(ValueError):
        t.stage_rebalance([("demote", 0, 0)])
    with pytest.raises(ValueError):
        t.stage_rebalance([("drop_replica", 0, 0, 1)])
    with pytest.raises(ValueError):
        t.stage_rebalance([("evict", 0, 0)])


def test_host_pool_exhaustion_is_recoverable():
    from repro.core.expert_pages import HOST
    cfg = _c4()
    t = _table(cfg, host_pool_pages=1)
    with pytest.raises(MemoryError):
        t.stage_rebalance([("demote", 0, 0), ("demote", 0, 1)])
    assert t.pages_in_use(HOST) == 0
    t.stage_rebalance([("demote", 0, 0)])     # one still fits
    t.commit_rebalance()
    assert t.pages_in_use(HOST) == 1


def test_rebalance_mutually_exclusive_with_scale_staging():
    cfg, c6 = _c4(), _c6()
    t = _table(cfg)
    t.stage_rebalance([("demote", 0, 0)])
    with pytest.raises(RuntimeError):
        t.stage_remap(c6, min_move=True)
    t.abort_rebalance()
    t.stage_remap(c6, min_move=True)
    with pytest.raises(RuntimeError):
        t.stage_rebalance([("demote", 0, 0)])
    t.abort()


# --------------------------------------------- replica-aware serving layout

def test_pooled_layout_without_replicas_is_legacy_identical():
    from repro.core.expert_pages import pooled_layout
    mcfg = _mcfg()
    cfg = _c6()
    t = _table(cfg)
    a = pooled_layout(t.active, cfg, mcfg.num_layers, mcfg.num_experts, 48)
    # legacy contract: expert e serves on its owner rank, slots ascending
    for l in range(mcfg.num_layers):
        for e in range(mcfg.num_experts):
            assert a["edest"][l, e] == cfg.slot(t.active[(l, e)].device)
    # rerun with uniform load + replica kwargs: byte-identical arrays
    b = pooled_layout(t.active, cfg, mcfg.num_layers, mcfg.num_experts, 48,
                      replicas={}, load=None)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_pooled_layout_routes_hot_expert_to_least_loaded_replica():
    from repro.core.expert_pages import pooled_layout
    mcfg = _mcfg()
    cfg = _c4()
    t = _table(cfg)
    hot = (0, 0)                      # owner rank 0
    t.stage_rebalance([("replicate", *hot, 3)])   # replica on rank 3
    t.commit_rebalance()
    E = mcfg.num_experts
    load = np.ones((mcfg.num_layers, E))
    load[0, 0] = 100.0                # expert 0 dominates layer 0
    lay = pooled_layout(t.active, cfg, mcfg.num_layers, E, 48,
                        replicas=t.replicas, load=load,
                        slots_per_rank=E // cfg.ndev + 1)
    # the hot expert is assigned first (descending load) and both candidate
    # ranks start empty — the tie breaks to the primary (rank 0)… then the
    # remaining uniform experts pile load on rank 0, so re-laying-out with
    # rank 0 pre-loaded flips it to the replica.  Pin the observable end
    # state instead: total per-rank load is balanced vs. the no-replica run.
    def rank_loads(layout):
        rl = np.zeros(cfg.ndev)
        for e in range(E):
            rl[layout["edest"][0, e]] += load[0, e]
        return rl
    base = pooled_layout(t.active, cfg, mcfg.num_layers, E, 48)
    assert rank_loads(lay).max() <= rank_loads(base).max()
    # every expert still serves from a rank that truly holds a copy
    for l in range(mcfg.num_layers):
        for e in range(E):
            holders = {cfg.slot(t.active[(l, e)].device)}
            holders.update(cfg.slot(r.device)
                           for r in t.replicas.get((l, e), ()))
            assert int(lay["edest"][l, e]) in holders
    # deterministic: same inputs, same arrays
    lay2 = pooled_layout(t.active, cfg, mcfg.num_layers, E, 48,
                         replicas=t.replicas, load=load,
                         slots_per_rank=E // cfg.ndev + 1)
    for k in lay:
        np.testing.assert_array_equal(lay[k], lay2[k])


def test_pooled_layout_slot_overflow_raises():
    from repro.core.expert_pages import pooled_layout
    mcfg = _mcfg()
    cfg = _c4()
    t = _table(cfg)
    # replicate two experts onto rank 1 and force ALL load there with zero
    # slack: 6 slots per rank cannot take 6 residents + 2 replicas
    t.stage_rebalance([("replicate", 0, 0, 1), ("replicate", 0, 1, 1)])
    t.commit_rebalance()
    # 4 ranks x 5 slots < 24 experts: some expert finds no free slot
    with pytest.raises(ValueError, match="slots_per_rank"):
        pooled_layout(t.active, cfg, mcfg.num_layers, mcfg.num_experts, 48,
                      replicas=t.replicas, slots_per_rank=5)


# --------------------------------- scale events over replicas / host tier

def test_min_move_keeps_expert_via_replica_and_commit_retires_them():
    cfg, c6 = _c4(), _c6()
    t = _table(cfg)
    # replicate (0, 0) onto device 1; then shrink the capacity of its
    # primary's device by moving to 6 devices — with 4 experts per device
    # the primary may or may not keep its seat, but the expert must never
    # migrate while ANY copy has capacity
    t.stage_rebalance([("replicate", 0, 0, 1)])
    t.commit_rebalance()
    migs = t.stage_remap(c6, min_move=True)
    assert all((m.layer, m.expert) != (0, 0) for m in migs)
    kept = t.staged[(0, 0)]
    assert kept in ((t.active[(0, 0)],) + t.replicas[(0, 0)])
    t.commit()
    # all unchosen replicas retired; pool accounts exactly one page per
    # (layer, expert) again
    assert not t.replicas
    total = sum(t.pages_in_use(d) for d in c6.devices)
    assert total == t.num_layers * t.num_experts


def test_scale_migration_sources_from_host_tier():
    from repro.core.expert_pages import HOST
    cfg, c6 = _c4(), _c6()
    t = _table(cfg)
    # demote everything: every forced move must then source from HOST
    t.stage_rebalance([("demote", l, e) for l in range(t.num_layers)
                       for e in range(t.num_experts)])
    t.commit_rebalance()
    migs = t.stage_remap(c6, min_move=True)
    assert migs, "4->6 with 24 experts must move overflow experts"
    assert all(m.src.device == HOST for m in migs)
    t.commit()
    # host copies survive the scale commit (weights are immutable)
    assert len(t.host) == t.num_layers * t.num_experts


def test_plan_elastic_paged_prices_host_and_replica_keeps():
    from repro.core.expert_pages import HOST
    from repro.core.scaling_plan import Op, plan_elastic_paged
    from repro.core.topology import model_tensors
    mcfg = _mcfg()
    cfg, c6 = _c4(), _c6()
    tensors = model_tensors(mcfg, 2)

    # demoted set: movers become Op.HOST steps, not P2P
    t = _table(cfg)
    t.stage_rebalance([("demote", l, e) for l in range(t.num_layers)
                       for e in range(t.num_experts)])
    t.commit_rebalance()
    plan = plan_elastic_paged(tensors, cfg, c6, t,
                              first_k_dense=mcfg.first_k_dense)
    expert_steps = [s for s in plan.steps if "/expert" in s.key.tensor]
    hosts = [s for s in expert_steps if s.op == Op.HOST]
    p2ps = [s for s in expert_steps if s.op == Op.P2P]
    assert hosts and not p2ps, (len(hosts), len(p2ps))
    assert plan.host_bytes_per_device()

    # replica-kept experts price zero-copy: exactly the staged refs NOT
    # already resident (primary or replica) appear as expert movers
    t2 = _table(cfg)
    t2.stage_rebalance([("replicate", 0, 0, 1)])
    t2.commit_rebalance()
    plan2 = plan_elastic_paged(tensors, cfg, c6, t2,
                               first_k_dense=mcfg.first_k_dense)
    moved = {s.key.tensor for s in plan2.steps
             if s.op == Op.P2P and "/expert" in s.key.tensor}
    expect = set()
    for (l, e), ref in t2.staged.items():
        resident = {t2.active[(l, e)]} | set(t2.replicas.get((l, e), ()))
        if ref not in resident:
            expect.add(f"layer{l + mcfg.first_k_dense}/expert{e}")
    assert moved == expect, moved ^ expect
    t2.abort()


def test_costmodel_host_bucket_uses_h2d_bandwidth():
    from repro.core.costmodel import DEFAULT_HW, plan_cost
    from repro.core.scaling_plan import plan_elastic_paged
    from repro.core.topology import model_tensors
    mcfg = _mcfg()
    cfg, c6 = _c4(), _c6()
    tensors = model_tensors(mcfg, 2)
    t_cold = _table(cfg)
    t_cold.stage_rebalance([("demote", l, e) for l in range(t_cold.num_layers)
                            for e in range(t_cold.num_experts)])
    t_cold.commit_rebalance()
    cold_plan = plan_elastic_paged(tensors, cfg, c6, t_cold,
                                   first_k_dense=mcfg.first_k_dense)
    cold = plan_cost(cold_plan)
    warm = plan_cost(plan_elastic_paged(tensors, cfg, c6, _table(cfg),
                                        first_k_dense=mcfg.first_k_dense))
    assert cold.breakdown["host"] > 0 and warm.breakdown["host"] == 0
    # the cold plan moved its expert bytes off the P2P bottleneck
    assert cold.breakdown["p2p"] < warm.breakdown["p2p"]
    # bucket arithmetic: bottleneck device's host bytes over H2D bandwidth
    want = max(cold_plan.host_bytes_per_device().values()) / DEFAULT_HW.h2d_bw
    assert cold.breakdown["host"] == pytest.approx(want)


# ------------------------------------------------------ RebalancePolicy

def _stats(counts):
    c = np.asarray(counts, np.float64)
    return {"samples": 10, "counts": c}


def test_policy_replicates_hot_and_demotes_cold():
    from repro.serving.rebalance import RebalancePolicy
    mcfg = _mcfg()
    cfg = _c4()
    t = _table(cfg)
    E = mcfg.num_experts
    # warm floor of 10 keeps the middling experts inside the neutral band:
    # only expert 0 crosses hot_factor*fair, only expert E-1 cold_factor*fair
    counts = np.full((mcfg.num_layers, E), 10.0)
    counts[:, 0] = 100.0
    counts[:, E - 1] = 0.0
    pol = RebalancePolicy(min_samples=1, max_actions=16)
    actions = pol.decide(_stats(counts), t, cfg, now=0.0, slots_per_rank=7)
    assert any(a[:3] == ("replicate", 0, 0) for a in actions)
    assert any(a[:3] == ("demote", 0, E - 1) for a in actions)
    # the replication target is a device NOT already holding expert 0 and
    # with the least routed load
    for a in actions:
        if a[0] == "replicate":
            assert a[3] != t.active[(a[1], a[2])].device


def test_policy_hysteresis_band_and_undo():
    from repro.serving.rebalance import RebalancePolicy
    mcfg = _mcfg()
    cfg = _c4()
    t = _table(cfg)
    E = mcfg.num_experts
    pol = RebalancePolicy(min_samples=1, max_actions=32)
    # shares inside (cold_factor/E, hot_factor/E): no actions at all
    counts = np.ones((mcfg.num_layers, E))
    assert pol.decide(_stats(counts), t, cfg, 0.0) == []
    # a replicated expert whose share fell below fair -> drop_replica;
    # a demoted expert whose share rose above fair -> promote
    t.stage_rebalance([("replicate", 0, 0, 1), ("demote", 0, 1)])
    t.commit_rebalance()
    counts = np.ones((mcfg.num_layers, E))
    counts[0, 0] = 0.5                # below fair, above cold band
    counts[0, 1] = 2.0                # above fair, below hot band
    actions = pol.decide(_stats(counts), t, cfg, 0.0)
    assert ("drop_replica", 0, 0, 1) in actions
    assert ("promote", 0, 1) in actions
    # but within the neutral band nothing flaps
    counts[0, 0] = 1.2                # above fair -> replica kept
    counts[0, 1] = 0.8                # below fair, above cold -> stays cold
    assert pol.decide(_stats(counts), t, cfg, 0.0) == []


def test_policy_gates_min_samples_cooldown_and_slot_budget():
    from repro.serving.rebalance import RebalancePolicy
    mcfg = _mcfg()
    cfg = _c4()
    t = _table(cfg)
    E = mcfg.num_experts
    counts = np.ones((mcfg.num_layers, E))
    counts[:, 0] = 4 * E
    pol = RebalancePolicy(min_samples=5, cooldown_s=10.0, max_actions=4)
    assert pol.decide({"samples": 2, "counts": counts}, t, cfg, 0.0) == []
    assert pol.decide(None, t, cfg, 0.0) == []
    acts = pol.decide(_stats(counts), t, cfg, 0.0, slots_per_rank=7)
    assert acts and len(acts) <= 4
    # cooldown: an accepted pass blocks the next one for cooldown_s
    assert pol.decide(_stats(counts), t, cfg, 5.0) == []
    assert pol.decide(_stats(counts), t, cfg, 11.0, slots_per_rank=7) != []
    # zero slack -> every rank already full -> replication infeasible
    pol2 = RebalancePolicy(min_samples=1)
    acts = pol2.decide(_stats(counts), t, cfg, 0.0,
                       slots_per_rank=E // cfg.ndev)
    assert all(a[0] != "replicate" for a in acts)


# ------------------------------------------------------ simulator parity

def test_sim_rebalances_and_survives_scale_over_replicas():
    from repro.serving.rebalance import RebalancePolicy, max_rank_load
    from repro.serving.simulator import ServingSimulator
    from repro.serving.workload import make_workload
    mcfg = _mcfg()
    pol = RebalancePolicy(min_samples=2, cooldown_s=1.0)
    sim = ServingSimulator(mcfg, tp=2, ndev=6, expert_mode="pooled",
                           rebalance=pol, routing_skew=1.2)
    reqs = make_workload(duration_s=15.0, rps_fn=lambda t: 4.0,
                         prompt_len=64, output_range=(32, 32), seed=0)
    sim.run(reqs, until=20.0)
    summ = sim.rebalance_summary()
    assert summ is not None
    assert summ["replicated"] >= 1 and summ["demoted"] >= 1
    assert summ["replica_bytes"] > 0 and summ["host_tier_bytes"] > 0
    # the balance metric improved: serving assignment over replicas beats
    # the primary-only assignment on the same synthesized Zipf shares
    from repro.core.expert_pages import pooled_layout
    cfg = sim.current_config()
    share = sim.routing._share
    base = pooled_layout(sim.expert_pages.active, cfg, mcfg.num_layers,
                         mcfg.num_experts, 48)
    rep = pooled_layout(sim.expert_pages.active, cfg, mcfg.num_layers,
                        mcfg.num_experts, 48,
                        replicas=sim.expert_pages.replicas,
                        load=share, slots_per_rank=sim._elm())
    assert (max_rank_load(share, rep["edest"], cfg.ndev)
            <= max_rank_load(share, base["edest"], cfg.ndev))
    # a scale event over the rebalanced table: replicas retire, host tier
    # survives, pool conserves
    task = sim.command_scale(4)
    n = 0
    while not task.done:
        sim.t += 0.5
        sim.step(sim.t)
        task.advance(sim.t)
        n += 1
        assert n < 1000
    t = sim.expert_pages
    assert not t.replicas and t.host
    assert (sum(t.pages_in_use(d) for d in range(4))
            == mcfg.num_layers * mcfg.num_experts)
    # the scale event's cost saw the host tier (H2D bucket populated)
    assert sim.events[-1].cost.breakdown.get("host", 0) > 0


def test_driver_projection_costs_from_sim_page_table():
    from repro.core.coordinator import ScalingPolicy
    from repro.serving.driver import ClusterDriver, DriverConfig
    from repro.serving.metrics import SLO
    from repro.serving.simulator import ServingSimulator
    mcfg = _mcfg()
    policy = ScalingPolicy(slo=SLO(ttft_s=5.0, tpot_s=1.5), window=16)

    def make_driver(sim):
        return ClusterDriver(sim, policy, mcfg=mcfg, tp=2,
                             device_pool=range(8), config=DriverConfig())

    sim = ServingSimulator(mcfg, tp=2, ndev=4, expert_mode="pooled")
    # park every expert in the host tier: the driver's projection — with no
    # explicit page table, via the backend.expert_pages fallback — must see
    # the LIVE placement, so its cost differs from the warm-placement
    # projection (expert movers priced on the H2D path, zero expert P2P;
    # the bucket arithmetic itself is pinned in the costmodel test above)
    sim.expert_pages.stage_rebalance(
        [("demote", l, e) for l in range(mcfg.num_layers)
         for e in range(mcfg.num_experts)])
    sim.expert_pages.commit_rebalance()
    c4, c6 = _c4(), _c6()
    cold = make_driver(sim).projected_cost_s(c4, c6)
    sim_warm = ServingSimulator(mcfg, tp=2, ndev=4, expert_mode="pooled")
    warm = make_driver(sim_warm).projected_cost_s(c4, c6)
    assert cold != warm
    # projection must not leave a staged remap open on the live table
    assert sim.expert_pages.staged is None


# ------------------------- routing-telemetry & transfer accounting fixes

def test_accumulate_routing_resets_samples_with_counts():
    """Regression: a counts-shape change (rebind to a different routed
    executable) must restart the accumulator AND the sample count together —
    zeroing only the counts left ``samples`` overcounting, so skew averages
    divided by the wrong denominator."""
    from repro.serving.engine import InferenceEngine
    eng = InferenceEngine(_mcfg(), batch_per_replica=2, max_len=64,
                          routing_sample_every=1)
    eng._accumulate_routing(np.ones((2, 24), np.int64))
    eng._accumulate_routing(np.ones((2, 24), np.int64))
    assert eng.routing_stats()["samples"] == 2
    eng._accumulate_routing(np.ones((2, 12), np.int64))   # shape change
    st = eng.routing_stats()
    assert st["samples"] == 1
    assert st["counts"].shape == (2, 12)
    np.testing.assert_array_equal(st["counts"], np.ones((2, 12)))
    eng.reset_routing_stats()
    assert eng.routing_stats() is None


def test_cancelled_transfer_ops_excluded_from_op_seconds_and_spans():
    """Regression: ops skipped after ``cancel()`` must not contribute to
    ``op_seconds`` (they never ran) and must not emit a tracer span —
    cancelled work previously polluted transfer-op timelines."""
    import threading

    from repro import obs
    from repro.core.transfer import TransferEngine, TransferOp

    tr = obs.install(obs.Tracer())
    try:
        started, gate = threading.Event(), threading.Event()

        def blocker():
            started.set()
            gate.wait()

        ops = [TransferOp(0, "blocker", blocker),
               TransferOp(1, "skipped", lambda: None)]
        eng = TransferEngine(max_workers=1)
        sess = eng.submit(ops)
        assert started.wait(5.0)
        # flag cancellation while op0 holds the single worker but leave the
        # futures queued: op1 IS dequeued and its _run must hit the
        # early-return branch, not execute
        sess.cancelled.set()
        gate.set()
        assert sess.join(5.0)
        assert ops[0].state == "done"
        assert ops[1].state == "cancelled"
        assert ops[1].seconds == 0.0
        names = [e.name for e in tr._events]
        assert "blocker" in names and "skipped" not in names
        assert sess.op_seconds == ops[0].seconds
        # the contract is the state filter, not happenstance zeros
        ops[1].seconds = 99.0
        assert sess.op_seconds == ops[0].seconds
    finally:
        obs.install(None)


def test_session_cancel_marks_pending_ops_cancelled():
    import threading

    from repro.core.transfer import TransferEngine, TransferOp

    gate = threading.Event()
    ops = [TransferOp(0, "blocker", gate.wait),
           TransferOp(1, "pending", lambda: None)]
    eng = TransferEngine(max_workers=1)
    sess = eng.submit(ops)
    threading.Timer(0.2, gate.set).start()
    sess.cancel()
    gate.set()
    assert sess.join(5.0)
    assert ops[1].state == "cancelled"
    assert sess.op_seconds == ops[0].seconds


# ------------------------------------------------- real engine (subprocess)

REBAL_COMMON = TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.rebalance import RebalancePolicy
from repro.serving.workload import Request

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))

def serve(srv, reqs, hook=None, max_ticks=600):
    t, n = 0.0, 0
    for r in reqs: srv.submit(r)
    while any(r.finish_s is None for r in reqs):
        if hook is not None:
            hook(srv, n, t)
        srv.tick(t); t += .1; n += 1
        assert n < max_ticks, "serve loop did not finish"
    return t

def mkreqs(n=4, out=40, base=0):
    rng = np.random.default_rng(0)
    return [Request(base + i, 0.0, 16, out, prompt=rng.integers(0, 128, 16))
            for i in range(n)]
"""


@pytest.mark.slow
def test_policy_rebalances_mid_serving_tokens_bit_identical():
    """The acceptance criterion: on the real engine the policy replicates
    >=1 hot expert AND demotes >=1 cold expert mid-serving, and every
    generated token matches the dense (unbalanced, un-rebalanced) run bit
    for bit."""
    out = run_with_devices(REBAL_COMMON + """
ref = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, expert_mode="dense")
ref.boot(c4)
ref_reqs = mkreqs()
serve(ref, ref_reqs)
ref_toks = {r.rid: ref.engine.generated[r.rid] for r in ref_reqs}

# near-uniform router traffic still has experts above/below fair share;
# tight bands make the policy act on it (hysteresis is a config knob)
pol = RebalancePolicy(hot_factor=1.02, cold_factor=0.98, min_samples=3,
                      cooldown_s=0.5, max_actions=8)
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, expert_mode="pooled",
                    routing_sample_every=1, rebalance=pol)
srv.boot(c4)
reqs = mkreqs()
serve(srv, reqs)
got_toks = {r.rid: srv.engine.generated[r.rid] for r in reqs}
for rid in ref_toks:
    assert ref_toks[rid] == got_toks[rid], (rid, ref_toks[rid], got_toks[rid])

summ = srv.rebalance_summary()
assert summ is not None, "policy never acted"
assert summ["replicated"] >= 1, summ
assert summ["demoted"] >= 1, summ
assert summ["replica_bytes"] > 0 and summ["d2h_bytes"] > 0, summ
assert summ["host_tier_bytes"] == srv.hmm.host_tier_bytes() > 0
t = srv.hmm.page_table
assert t.replicas and t.host
print("REBALANCE-TOKENS-OK", summ["replicated"], summ["demoted"])
""")
    assert "REBALANCE-TOKENS-OK" in out


@pytest.mark.slow
def test_abort_in_flight_then_cold_scale_streams_h2d():
    """One subprocess, three acceptance checks: (1) aborting a rebalance
    with transfers in flight restores the page table and conserves device
    AND host pools; (2) a subsequent full demotion commits; (3) the 4->6
    scale event then sources every expert migration from the host tier —
    ZERO expert P2P, expert_h2d_bytes == moved pages — and (4) the routing
    histogram resets at scale commit (satellite: stale-stats fix)."""
    out = run_with_devices(REBAL_COMMON + """
from repro.core.expert_pages import HOST

srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, expert_mode="pooled",
                    routing_sample_every=1)
srv.boot(c4)
pt = srv.hmm.page_table
usage0 = {d: pt.pages_in_use(d) for d in list(c4.devices) + [HOST]}
active0 = dict(pt.active)

# (1) abort in flight
task = srv.start_rebalance([("replicate", 0, 0, 1), ("demote", 1, 23)])
assert srv.hmm._rebalance_ops is not None
task.abort()
assert {d: pt.pages_in_use(d) for d in usage0} == usage0
assert pt.active == active0 and not pt.replicas and not pt.host
assert srv.hmm._expert_host_pool == {}

# serving still healthy after the abort
reqs = mkreqs(2, out=10, base=100)
serve(srv, reqs)

# (2) demote EVERYTHING (batches of 8: bounded sessions like the policy's)
keys = [(l, e) for l in range(MCFG.num_layers)
        for e in range(MCFG.num_experts)]
for i in range(0, len(keys), 8):
    task = srv.start_rebalance([("demote", l, e)
                                for l, e in keys[i:i+8]])
    t = 0.0
    while not task.done:
        srv.tick(t); t += .1
assert len(pt.host) == len(keys)
assert srv.hmm.host_tier_bytes() == len(keys) * srv.hmm.expert_page_nbytes()

# decode a bit so the routing histogram is non-empty before the scale
reqs2 = mkreqs(2, out=10, base=200)
serve(srv, reqs2)
pre = srv.engine.routing_stats()
assert pre is not None and pre["samples"] > 0

# (3) cold 4->6 scale-up: every mover streams from the host tier
task = srv.start_scale(c6)
t, n = 100.0, 0
while not task.done:
    srv.tick(t); task.advance(t); t += .1; n += 1
    assert n < 500
migs = srv.hmm.last_migrations
page = srv.hmm.expert_page_nbytes()
assert migs and all(m.src.device == HOST for m in migs)
st = task.stage_stats
assert st.expert_p2p_bytes == 0, st.expert_p2p_bytes
assert st.expert_h2d_bytes == len(migs) * page, \\
    (st.expert_h2d_bytes, len(migs), page)
# host copies survive the scale commit
assert len(pt.host) == len(keys)

# (4) routing stats were reset at switchover (no decode ran since commit:
# the histogram must be empty, not carrying pre-scale counts)
assert srv.engine.routing_stats() is None

# tokens post-scale still match a dense 6-dev reference
ref = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, expert_mode="dense")
ref.boot(c6)
rr = mkreqs(2, out=10)
serve(ref, rr)
gg = mkreqs(2, out=10, base=300)
serve(srv, gg)
for a, b in zip(rr, gg):
    assert ref.engine.generated[a.rid] == srv.engine.generated[b.rid]
print("REBALANCE-ABORT-COLD-SCALE-OK", len(migs), st.expert_h2d_bytes)
""")
    assert "REBALANCE-ABORT-COLD-SCALE-OK" in out


@pytest.mark.slow
def test_routing_stats_reset_on_4_to_6_scaleup():
    """Satellite regression: scale-event commit must restart the routing
    histogram — post-commit stats describe ONLY the new placement."""
    out = run_with_devices(REBAL_COMMON + """
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, expert_mode="pooled",
                    routing_sample_every=1)
srv.boot(c4)
reqs = mkreqs(2, out=20)
serve(srv, reqs)
pre = srv.engine.routing_stats()
assert pre is not None and pre["samples"] >= 10

# 4->6 scale-up while requests are in flight: the histogram captured at
# commit must NOT carry the pre-scale counts forward
task = None
post_commit = "unset"
reqs2 = mkreqs(2, out=30, base=100)
for r in reqs2: srv.submit(r)
t, n = 200.0, 0
while any(r.finish_s is None for r in reqs2):
    if n == 2 and task is None:
        task = srv.start_scale(c6)
    srv.tick(t)
    if task is not None and not task.done:
        task.advance(t)
        if task.done:
            post_commit = srv.engine.routing_stats()
    t += .1; n += 1
    assert n < 500
assert task is not None and task.done
assert post_commit != "unset"
# the regression pin: at commit the histogram is EMPTY — the pre-scale
# counts (>= 10 samples) did not survive the placement change
assert post_commit is None, post_commit
# sampling resumed under the new placement
final = srv.engine.routing_stats()
assert final is not None and final["samples"] >= 1
print("ROUTING-RESET-OK", pre["samples"], final["samples"])
""")
    assert "ROUTING-RESET-OK" in out
