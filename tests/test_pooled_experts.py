"""Pooled expert weight store (``expert_mode="pooled"``) — parity and
byte-accounting tests for the vpage remap in the serving path (DESIGN.md §2).

Fast (single device): pooled execution is bit-identical to the dense banks
at f32, for the local path and for prefill+decode logits/tokens.

Slow (subprocess, 8 host devices, patterns from test_elastic_integration /
test_paged_engine): tokens across an EP scale event mid-decode match the
dense run exactly; the scale event's expert-weight P2P bytes equal the sum
of ``stage_remap(min_move=True)`` Migration page sizes and agree page-for-
page with ``plan_elastic_paged``; commit moves zero expert-weight bytes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import TEST_MOE, run_with_devices

TEST_MOE_CFG = None


def _mcfg():
    global TEST_MOE_CFG
    if TEST_MOE_CFG is None:
        ns = {}
        exec(TEST_MOE, ns)
        TEST_MOE_CFG = ns["MCFG"]
    return TEST_MOE_CFG


# ------------------------------------------------------------ fast parity

def test_moe_local_pooled_matches_dense_bitwise():
    from repro.core.expert_pages import ExpertPageTable, pooled_layout
    from repro.core.topology import ElasticConfig
    from repro.models.moe import moe_init, moe_local, moe_local_pooled

    mcfg = _mcfg()
    cfg = ElasticConfig(dp=1, tp=1, devices=(0,))
    E, L = mcfg.num_experts, mcfg.num_layers
    ppd = L * E
    p = moe_init(jax.random.PRNGKey(0), mcfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, mcfg.d_model))
    y_ref, aux_ref = moe_local(mcfg, p, x)

    t = ExpertPageTable(L, E, pool_pages_per_device=ppd)
    t.initial_place(cfg)
    lay = pooled_layout(t.active, cfg, L, E, ppd)
    pool = {k: np.zeros((cfg.ndev * ppd,) + np.asarray(p[k]).shape[1:],
                        np.float32) for k in ("wi", "wg", "wo")}
    for (l, e), ref in t.active.items():
        if l == 0:
            row = cfg.slot(ref.device) * ppd + ref.page
            for k in pool:
                pool[k][row] = np.asarray(p[k])[e]
    pp = {"router": p["router"],
          **{k: jnp.asarray(v[0]) for k, v in lay.items()}}
    y_p, aux_p = moe_local_pooled(mcfg, pp,
                                  {k: jnp.asarray(v)
                                   for k, v in pool.items()}, x)
    np.testing.assert_array_equal(np.asarray(y_p), np.asarray(y_ref))
    assert float(aux_p) == float(aux_ref)


def test_pooled_decode_logits_match_dense():
    """Same seed, one device: prefill + decode logits of the pooled store
    are allclose to (in fact bit-identical with) the dense banks, and the
    greedy tokens are identical."""
    from repro.core.hmm import HMM
    from repro.core.topology import ElasticConfig
    from repro.models import model as M

    mcfg = _mcfg()
    c1 = ElasticConfig(dp=1, tp=1, devices=(0,))

    def boot(mode):
        hmm = HMM(mcfg, tp=1, batch_per_replica=2, max_len=32,
                  expert_mode=mode, seed=0)
        hmm.boot(c1)
        return hmm.attach_active()[2]

    dense_p, pooled_p = boot("dense"), boot("pooled")
    assert "moe_pool" in pooled_p and "wi" not in pooled_p["blocks"]["moe"]
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 128, (2, 8)), jnp.int32),
             "lengths": jnp.asarray([8, 6], jnp.int32)}
    lg_d, cache_d = M.prefill(mcfg, dense_p, batch, max_len=32)
    lg_p, cache_p = M.prefill(mcfg, pooled_p, batch, max_len=32)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d),
                               rtol=1e-6, atol=1e-6)
    tok = jnp.argmax(lg_d, axis=-1).astype(jnp.int32)
    assert (jnp.argmax(lg_p, axis=-1).astype(jnp.int32) == tok).all()
    lengths = batch["lengths"]
    lg_d2, _ = M.decode_step(mcfg, dense_p, tok[:, None], cache_d, lengths)
    lg_p2, _ = M.decode_step(mcfg, pooled_p, tok[:, None], cache_p, lengths)
    np.testing.assert_allclose(np.asarray(lg_p2), np.asarray(lg_d2),
                               rtol=1e-6, atol=1e-6)
    assert (jnp.argmax(lg_p2, -1) == jnp.argmax(lg_d2, -1)).all()


def test_transition_cost_pooled_sees_min_move_migration():
    """The closed loop must see the cheaper min-move migration through the
    shared costing path.  The P2P bottleneck (max bytes into one device) is
    where it shows: on scale-down, contiguous placement reshuffles experts
    among the survivors while min-move only moves the evicted devices'
    orphans — strictly less traffic.  (On scale-up both placements send the
    same page count to the fresh devices, so the bottleneck ties.)"""
    from repro.core.topology import ElasticConfig
    from repro.serving.driver import transition_cost

    mcfg = _mcfg()
    c6 = ElasticConfig(dp=3, tp=2, devices=(0, 1, 2, 3, 4, 5))
    c4 = ElasticConfig(dp=2, tp=2, devices=(0, 1, 2, 3))
    dense = transition_cost(mcfg, 2, c6, c4)
    pooled = transition_cost(mcfg, 2, c6, c4, expert_mode="pooled")
    assert pooled.breakdown["p2p"] < dense.breakdown["p2p"]
    up_d = transition_cost(mcfg, 2, c4, c6)
    up_p = transition_cost(mcfg, 2, c4, c6, expert_mode="pooled")
    assert up_p.breakdown["p2p"] <= up_d.breakdown["p2p"]

    # the simulator threads its expert_mode into the same costing path
    from repro.serving.simulator import ServingSimulator

    def sim_cost(mode):
        sim = ServingSimulator(mcfg, tp=2, ndev=6, expert_mode=mode)
        return sim.command_scale(4).event.cost

    assert (sim_cost("pooled").breakdown["p2p"]
            < sim_cost("dense").breakdown["p2p"])

    # a LIVE page table (post-remap, non-contiguous) costs from the actual
    # placement — the ClusterDriver passes backend.hmm.page_table — and is
    # never mutated by the projection
    from repro.core.expert_pages import ExpertPageTable
    live = ExpertPageTable(mcfg.num_layers, mcfg.num_experts)
    live.initial_place(c4)
    live.stage_remap(c6, min_move=True)
    live.commit()
    before = dict(live.active)
    cost = transition_cost(mcfg, 2, c6, c4, expert_mode="pooled",
                           page_table=live)
    assert cost.scale_time_s > 0
    assert live.staged is None and live.active == before


# --------------------------------------------------- slow subprocess runs

POOLED_COMMON = TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))

def run(expert_mode, scale, kv_mode="dense", incremental=True):
    srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), seed=0,
                        expert_mode=expert_mode, kv_mode=kv_mode,
                        kv_block_size=16)
    srv.boot(c4 if scale else c6)
    rng = np.random.default_rng(0)
    reqs = [Request(i, 0.0, 16, 40, prompt=rng.integers(0, 128, 16))
            for i in range(4)]
    for r in reqs: srv.submit(r)
    t, n, task = 0.0, 0, None
    while any(r.finish_s is None for r in reqs):
        if scale and n == 5 and task is None:
            if incremental:
                task = srv.start_scale(c6)
            else:
                srv.stage_scale(c6); srv.tick(t); t += .1; n += 1
                srv.switchover(); continue
        srv.tick(t); t += .1; n += 1
        if task is not None and not task.done:
            task.advance(t)
        assert n < 500
    while task is not None and not task.done:   # byte assertions need DONE
        srv.tick(t); task.advance(t); t += .1
    return {r.rid: srv.engine.generated[r.rid] for r in reqs}, srv, task
"""


@pytest.mark.slow
def test_pooled_tokens_identical_across_scaleup_with_exact_migration_bytes():
    """The acceptance criterion end-to-end: pooled decode tokens across an
    incremental 4->6 scale event match the dense run bit for bit; expert
    P2P bytes during staging equal exactly sum(Migration page sizes) and
    match plan_elastic_paged page-for-page; commit moves zero expert-weight
    bytes (table swap only)."""
    out = run_with_devices(POOLED_COMMON + """
from repro.core.expert_pages import ExpertPageTable
from repro.core.scaling_plan import Op, plan_elastic_paged
from repro.core.topology import model_tensors

ref_toks, _, _ = run("dense", scale=False)

# the pre-scale placement for the planner cross-check: initial_place is
# deterministic, so a fresh table reproduces the booted server's state
snapshot = ExpertPageTable(MCFG.num_layers, MCFG.num_experts)
snapshot.initial_place(c4)

got_toks, srv, task = run("pooled", scale=True)
for rid in ref_toks:
    assert ref_toks[rid] == got_toks[rid], (rid, ref_toks[rid], got_toks[rid])

migs = srv.hmm.last_migrations
page = srv.hmm.expert_page_nbytes()
stage = task.stage_stats              # frozen when STAGING completed
final = srv.hmm.last_stats            # stage + commit, merged
assert migs, "4->6 with 24 experts must migrate overflow experts"
# staged expert P2P == exactly the migration pages, nothing else
assert stage.expert_p2p_bytes == len(migs) * page, \
    (stage.expert_p2p_bytes, len(migs), page)
# commit moved ZERO expert-weight bytes (and zero weight bytes at all)
assert final.expert_p2p_bytes == stage.expert_p2p_bytes
assert final.p2p_bytes == stage.p2p_bytes
assert final.expert_local_bytes == 0   # no _assemble_rows concatenation

# page-for-page agreement with the logical planner
tensors = model_tensors(MCFG, 2)
plan = plan_elastic_paged(tensors, c4, c6, snapshot, first_k_dense=0)
plan_moves = {(s.key.tensor, s.src, s.dst) for s in plan.steps
              if s.op == Op.P2P and "/expert" in s.key.tensor}
exec_moves = {(f"layer{m.layer}/expert{m.expert}",
               m.src.device, m.dst.device) for m in migs}
assert plan_moves == exec_moves, (plan_moves ^ exec_moves)

# min-move strictly beats the dense contiguous regroup on expert bytes
_, dsrv, dtask = run("dense", scale=True)
assert dtask.stage_stats.expert_p2p_bytes > stage.expert_p2p_bytes
print("POOLED-SCALEUP-BYTES-OK", len(migs), stage.expert_p2p_bytes)
""")
    assert "POOLED-SCALEUP-BYTES-OK" in out


@pytest.mark.slow
def test_pooled_with_paged_kv_tokens_match_dense():
    """Both indirections at once — pooled expert weights + paged KV blocks:
    tokens still match the dense/dense engine exactly, across a scale
    event, and the block pool conserves."""
    out = run_with_devices(POOLED_COMMON + """
ref_toks, _, _ = run("dense", scale=False, kv_mode="dense")
got_toks, srv, _ = run("pooled", scale=True, kv_mode="paged")
assert srv.hmm.kv_blocks.num_partitions == 3
srv.hmm.kv_blocks.check_invariants()
for rid in ref_toks:
    assert ref_toks[rid] == got_toks[rid], rid
print("POOLED-PAGED-KV-OK")
""")
    assert "POOLED-PAGED-KV-OK" in out


@pytest.mark.slow
def test_pooled_scale_with_nondefault_device_pool():
    """ElasticConfig device ints are LOGICAL indices into ``all_devices``;
    with a shifted pool (all_devices = jax.devices()[2:]) the migration
    path must still resolve shard sources/destinations by physical device —
    regression for keying pool shards by jax device id."""
    out = run_with_devices(TEST_MOE + """
import jax, numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))

def run(devpool):
    srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), seed=0, expert_mode="pooled",
                        all_devices=devpool)
    srv.boot(c4)
    rng = np.random.default_rng(0)
    reqs = [Request(i, 0.0, 16, 24, prompt=rng.integers(0, 128, 16))
            for i in range(4)]
    for r in reqs: srv.submit(r)
    t, n = 0.0, 0
    while any(r.finish_s is None for r in reqs):
        if n == 5:
            srv.stage_scale(c6); srv.tick(t); t += .1; n += 1
            srv.switchover(); continue
        srv.tick(t); t += .1; n += 1
        assert n < 500
    return {r.rid: srv.engine.generated[r.rid] for r in reqs}, srv

ref, _ = run(None)                       # default jax.devices()
got, srv = run(jax.devices()[2:])        # logical 0..5 -> physical 2..7
assert srv.hmm.last_stats.expert_p2p_bytes == \
    len(srv.hmm.last_migrations) * srv.hmm.expert_page_nbytes()
for rid in ref:
    assert ref[rid] == got[rid], rid
print("POOLED-DEVPOOL-OK")
""")
    assert "POOLED-DEVPOOL-OK" in out


@pytest.mark.slow
def test_pooled_scaledown_and_abort_restore_pool():
    """Scale down 6->4 with the pooled store (drain + min-move migration
    off the evicted devices), and an aborted staging returns every staged
    page — pages_in_use matches the committed table afterwards."""
    out = run_with_devices(POOLED_COMMON + """
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, expert_mode="pooled")
srv.boot(c6)
rng = np.random.default_rng(0)
reqs = [Request(i, 0.0, 16, 20, prompt=rng.integers(0, 128, 16))
        for i in range(4)]
for r in reqs: srv.submit(r)

# abort mid-staging: pool bookkeeping must fully unwind
task = srv.start_scale(c4)
srv.tick(0.0); task.advance(0.0)
task.abort()
for d in c6.devices:
    owned = sum(1 for ref in srv.hmm.page_table.active.values()
                if ref.device == d)
    assert srv.hmm.page_table.pages_in_use(d) == owned
assert srv.hmm.page_table.staged is None
# idempotent: a second abort (HMM-level) is a no-op, no double-free
srv.hmm.abort()
for d in c6.devices:
    owned = sum(1 for ref in srv.hmm.page_table.active.values()
                if ref.device == d)
    assert srv.hmm.page_table.pages_in_use(d) == owned
# mid-flight ops (staging="overlap") are covered by
# tests/test_overlap_staging.py::test_overlap_abort_in_flight_leaves_no_staged_pages

# now the real scale-down, driven to completion
t, n, task = 0.1, 0, srv.start_scale(c4)
while any(r.finish_s is None for r in reqs) or not task.done:
    srv.tick(t)
    if not task.done:
        task.advance(t)
    t += .1; n += 1
    assert n < 1000
assert srv.hmm.active_cfg.ndev == 4
# every expert now lives on the surviving devices, balanced
for ref in srv.hmm.page_table.active.values():
    assert ref.device in c4.devices
st = srv.hmm.last_stats
assert st.expert_p2p_bytes == len(srv.hmm.last_migrations) * \
    srv.hmm.expert_page_nbytes()
print("POOLED-SCALEDOWN-OK")
""")
    assert "POOLED-SCALEDOWN-OK" in out
