"""Overlapped staging — the background TransferEngine pipeline (DESIGN.md
§3).  What must hold:

* serial vs overlap `TransferStats` are byte-equal field by field (dense,
  pooled, and paged-KV combos) — same reshard calls, different thread;
* with `staging="overlap"` the engine serves real decode ticks while
  transfer ops are literally in flight, and tokens stay bit-identical to
  an unscaled run;
* abort cancels-or-joins in-flight ops and leaves zero staged-page leaks
  in the ExpertPageTable (idempotent, mid-flight included);
* a commit/abort race stress over repeated scale events keeps the pool and
  the serving loop consistent;
* the cost model, simulator, driver, and metrics all speak the overlap
  surface (scale time, decode stall, overlap efficiency).
"""
import pytest

from helpers import TEST_MOE, run_with_devices


# ------------------------------------------------------- transfer engine

def test_transfer_engine_runs_polls_and_orders_results():
    from repro.core.transfer import TransferEngine, TransferOp

    eng = TransferEngine(max_workers=2)
    ops = [TransferOp(index=i, label=f"op{i}", fn=lambda i=i: i * i)
           for i in range(8)]
    sess = eng.submit(ops)
    assert sess.join(timeout=30.0)
    assert sess.finished() and sess.remaining() == 0
    assert [op.result for op in sess.ops] == [i * i for i in range(8)]
    assert all(op.state == "done" for op in ops)
    assert sess.op_seconds >= 0.0 and not sess.failed_ops()
    eng.shutdown()


def test_transfer_engine_cancel_joins_running_and_skips_pending():
    import threading

    from repro.core.transfer import TransferEngine, TransferOp

    started = threading.Event()
    release = threading.Event()

    def blocker():
        started.set()
        release.wait(timeout=30.0)
        return "ran"

    eng = TransferEngine(max_workers=1)   # one worker => rest stay pending
    ops = [TransferOp(index=0, label="blocker", fn=blocker)] + [
        TransferOp(index=i, label=f"p{i}", fn=lambda: "ran")
        for i in range(1, 5)]
    sess = eng.submit(ops)
    assert started.wait(timeout=30.0)
    release.set()                          # cancel() must JOIN the runner
    sess.cancel()
    assert sess.finished()
    assert ops[0].state == "done"          # running op joined, not killed
    assert all(op.state == "cancelled" for op in ops[1:])
    eng.shutdown()


def test_transfer_engine_reports_failures():
    from repro.core.transfer import TransferEngine, TransferOp

    def boom():
        raise ValueError("transfer exploded")

    eng = TransferEngine(max_workers=2)
    sess = eng.submit([TransferOp(index=0, label="ok", fn=lambda: 1),
                       TransferOp(index=1, label="bad", fn=boom)])
    sess.join(timeout=30.0)
    failed = sess.failed_ops()
    assert len(failed) == 1 and failed[0].label == "bad"
    assert isinstance(failed[0].error, ValueError)
    eng.shutdown()


# ------------------------------------------------- cost model / simulator

def test_costmodel_overlap_hides_warmup_and_cuts_stall():
    from repro.configs import get_config
    from repro.core.costmodel import DEFAULT_HW, plan_cost
    from repro.core.scaling_plan import STRATEGIES, placement
    from repro.core.topology import ElasticConfig, kv_cache_bytes, \
        model_tensors

    mcfg = get_config("deepseek-v2-lite-16b")
    kvb = kv_cache_bytes(mcfg, 8, 4096)
    tensors = model_tensors(mcfg, 2, kv_bytes_per_replica=kvb)
    old = ElasticConfig(2, 2, (0, 1, 2, 3))
    new = ElasticConfig(3, 2, (0, 1, 2, 3, 4, 5))
    plan = STRATEGIES["elastic"](tensors, old, new)
    resident = {d: sum(s.values())
                for d, s in placement(tensors, old).items()}
    cs = plan_cost(plan, strategy="elastic", staging="serial",
                   resident_bytes_per_device=resident)
    co = plan_cost(plan, strategy="elastic", staging="overlap",
                   resident_bytes_per_device=resident)
    assert cs.staging == "serial" and co.staging == "overlap"
    # serial sums transfer + warmup; overlap hides warmup under the
    # (contention-slowed) transfer window
    assert co.scale_time_s < cs.scale_time_s
    # serial stalls decode for the whole transfer; overlap only the
    # HBM-contention share
    assert cs.decode_stall_s > 0
    assert 0 < co.decode_stall_s < cs.decode_stall_s
    # op_s carries the serial-equivalent transfer time, contention-scaled
    assert co.breakdown["op_s"] == pytest.approx(
        cs.breakdown["op_s"] * DEFAULT_HW.overlap_contention)
    # peak memory / byte accounting are staging-mode independent
    assert co.peak_mem_bytes_per_device == cs.peak_mem_bytes_per_device
    # downtime strategies: the outage subsumes the stall
    cd = plan_cost(plan, strategy="cold_restart", staging="serial",
                   resident_bytes_per_device=resident)
    assert cd.downtime_s > 0 and cd.decode_stall_s == 0.0


def test_sim_overlap_backend_stalls_less_and_reports_summary():
    from repro.configs import get_config
    from repro.core.topology import ElasticConfig
    from repro.serving.metrics import summarize
    from repro.serving.simulator import ServingSimulator
    from repro.serving.workload import Request

    mcfg = get_config("deepseek-v2-lite-16b")

    def scale_once(staging):
        sim = ServingSimulator(mcfg, tp=2, ndev=4, strategy="elastic",
                               staging=staging)
        for i in range(24):
            sim.submit(Request(i, 0.0, 2000, 600))
        sim.run([], until=5.0)
        task = sim.start_scale(ElasticConfig(3, 2, (0, 1, 2, 3, 4, 5)))
        sim.run([], until=task.event.t_ready + 5.0)
        assert task.done
        return sim, task

    sim_s, task_s = scale_once("serial")
    sim_o, task_o = scale_once("overlap")
    # overlap commits sooner and stalls decode less
    assert task_o.event.t_ready < task_s.event.t_ready
    assert 0 < task_o.stall_s < task_s.stall_s
    assert task_o.overlap_efficiency is not None
    for sim, staging in ((sim_s, "serial"), (sim_o, "overlap")):
        summ = sim.scaling_summary()
        assert summ["staging_mode"] == staging
        assert summ["decode_stall_s"] >= 0
        out = summarize(sim.finished, backend=sim)
        assert out["staging_mode"] == staging
        assert "decode_stall_s" in out


def test_driver_adopts_backend_staging_mode_and_logs_completion():
    from repro.configs import get_config
    from repro.core.coordinator import ScalingPolicy
    from repro.serving.driver import ClusterDriver, DriverConfig
    from repro.serving.metrics import SLO
    from repro.serving.simulator import ServingSimulator
    from repro.serving.workload import burst, make_workload

    mcfg = get_config("deepseek-v2-lite-16b")
    sim = ServingSimulator(mcfg, tp=2, ndev=4, strategy="elastic",
                           staging="overlap")
    policy = ScalingPolicy(slo=SLO(ttft_s=5.0, tpot_s=1.5), window=16,
                           cooldown_s=15.0, queue_scale_up=6, confirm_s=1.0)
    driver = ClusterDriver(sim, policy, mcfg=mcfg, tp=2,
                           device_pool=range(8),
                           config=DriverConfig(dt=0.05, settle_s=15.0,
                                               min_dp=2))
    # adoption: projections use the backend's own staging mode...
    assert driver._staging == "overlap"
    cur = sim.current_config()
    from repro.core.topology import ElasticConfig
    tgt = ElasticConfig(3, 2, (0, 1, 2, 3, 4, 5))
    proj_overlap = driver.projected_cost_s(cur, tgt)
    driver_serial = ClusterDriver(sim, policy, mcfg=mcfg, tp=2,
                                  device_pool=range(8),
                                  config=DriverConfig(staging="serial"))
    # ...and the DriverConfig override wins over adoption
    assert driver_serial._staging == "serial"
    assert proj_overlap < driver_serial.projected_cost_s(cur, tgt)
    # closed loop: completed events carry staging + completion metrics
    reqs = make_workload(duration_s=200.0, rps_fn=burst(2.0, 14.0, 60.0,
                                                        60.0),
                         prompt_len=2000, output_range=(500, 750), seed=0)
    driver.run(reqs, until=300.0)
    ups = [e for e in driver.events if e.direction == "up"]
    assert ups and all(e.staging == "overlap" for e in driver.events)
    done = [e for e in driver.events if e.stall_s is not None]
    assert done, "no event got completion metrics filled in"
    assert all(e.overlap_eff is not None for e in done)


# ----------------------------------------------------------- real engine

@pytest.mark.slow
def test_overlap_engine_ticks_during_flight_tokens_and_stats_exact():
    """Real decode ticks run while transfer ops are IN FLIGHT on the
    background engine (>= 3 of them, single worker to stretch the window);
    tokens match an unscaled run bit-for-bit; and the overlapped
    TransferStats equal the serial monolithic ones field by field."""
    out = run_with_devices(TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.core.hmm import HMM, TransferStats
from repro.serving.driver import ScalePhase
from repro.serving.workload import Request

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))

# monolithic serial reference byte accounting (no serving, boot only)
href = HMM(MCFG, tp=2, batch_per_replica=2, max_len=128, seed=0)
href.boot(c4)
ref_stats = href.scale(c6)

def run(scale):
    srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                        prefill_buckets=(32,), seed=0, staging="overlap",
                        transfer_workers=1)
    srv.boot(c4 if scale else c6)
    if scale:
        srv.preinitialize(c6)   # the driver's prewarm; compile overlap is
                                # exercised by the closed-loop test below
        # throttle each transfer op so the in-flight window deterministically
        # spans several ticks (warm jit caches can otherwise finish the tiny
        # test model's staging before the first poll)
        import time as _time
        orig = srv.hmm._stage_unit
        def slow_unit(*a, **k):
            _time.sleep(0.1)
            return orig(*a, **k)
        srv.hmm._stage_unit = slow_unit
    rng = np.random.default_rng(0)
    reqs = [Request(i, 0.0, 16, 40, prompt=rng.integers(0,128,16))
            for i in range(4)]
    for r in reqs: srv.submit(r)
    t, n, task, mid = 0.0, 0, None, 0
    while any(r.finish_s is None for r in reqs) or \
            (task is not None and not task.done):
        if scale and n == 5 and task is None:
            task = srv.start_scale(c6)
        srv.tick(t); t += .1; n += 1
        if task is not None and not task.done:
            if task.phase is ScalePhase.STAGING and srv.hmm.staging_in_flight:
                mid += 1          # this tick ran with ops in flight
            task.advance(t)
        assert n < 20000
    toks = {r.rid: srv.engine.generated[r.rid] for r in reqs}
    return toks, task, mid

ref_toks, _, _ = run(False)
got_toks, task, mid = run(True)
assert task is not None and task.phase is ScalePhase.DONE
assert mid >= 3, mid
for f in TransferStats.BYTE_FIELDS:
    a, b = getattr(ref_stats, f), getattr(task.stage_stats, f)
    assert a == b, (f, a, b)
assert task.stall_s < task.stage_stats.wall_s, \
    (task.stall_s, task.stage_stats.wall_s)   # the serve loop never blocked
for rid in ref_toks:
    assert ref_toks[rid] == got_toks[rid], (rid, ref_toks[rid], got_toks[rid])
print(f"OVERLAP-INTERLEAVE-OK ticks={mid} stall={task.stall_s:.4f}")
""")
    assert "OVERLAP-INTERLEAVE-OK" in out


@pytest.mark.slow
def test_serial_vs_overlap_stats_byte_equality_all_combos():
    """Field-by-field TransferStats equality between staging modes for the
    (dense|pooled experts) x (dense|paged KV) matrix, staging AND commit,
    plus bit-identical staged parameter trees."""
    out = run_with_devices(TEST_MOE + """
import numpy as np, jax
from repro.core.topology import ElasticConfig
from repro.core.hmm import HMM, TransferStats

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))

def scale_with(staging, expert_mode, kv_mode):
    h = HMM(MCFG, tp=2, batch_per_replica=2, max_len=128, seed=0,
            expert_mode=expert_mode, kv_mode=kv_mode, kv_block_size=16,
            staging=staging)
    h.boot(c4)
    stage = h.scale(c6)
    import dataclasses
    staged_leaves = [np.asarray(x) for x in jax.tree.leaves(h.staged[2])]
    stage = dataclasses.replace(stage)      # freeze pre-commit snapshot
    h.commit()
    return stage, h.last_stats, staged_leaves

for expert_mode in ("dense", "pooled"):
    for kv_mode in ("dense", "paged"):
        s_stage, s_total, s_leaves = scale_with("serial", expert_mode, kv_mode)
        o_stage, o_total, o_leaves = scale_with("overlap", expert_mode, kv_mode)
        for f in TransferStats.BYTE_FIELDS:
            assert getattr(s_stage, f) == getattr(o_stage, f), \
                (expert_mode, kv_mode, "stage", f)
            assert getattr(s_total, f) == getattr(o_total, f), \
                (expert_mode, kv_mode, "total", f)
        for a, b in zip(s_leaves, o_leaves):
            assert a.dtype == b.dtype and np.array_equal(a, b), \
                (expert_mode, kv_mode)
        print("COMBO-OK", expert_mode, kv_mode)
print("STATS-EQUALITY-OK")
""")
    assert "STATS-EQUALITY-OK" in out
    assert out.count("COMBO-OK") == 4


@pytest.mark.slow
def test_overlap_abort_in_flight_leaves_no_staged_pages():
    """abort() with transfer ops mid-flight cancels-or-joins them and fully
    unwinds the page pool (idempotent, repeatable, and a subsequent scale
    completes with exact byte accounting)."""
    out = run_with_devices(TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, expert_mode="pooled",
                    staging="overlap", transfer_workers=1)
srv.boot(c4)
srv.preinitialize(c6)
rng = np.random.default_rng(0)
reqs = [Request(i, 0.0, 16, 60, prompt=rng.integers(0, 128, 16))
        for i in range(4)]
for r in reqs: srv.submit(r)

# throttle ops so every abort provably lands while ops are mid-flight
import time as _time
orig_unit = srv.hmm._stage_unit
def slow_unit(*a, **k):
    _time.sleep(0.05)
    return orig_unit(*a, **k)
srv.hmm._stage_unit = slow_unit

def pool_consistent():
    for d in srv.hmm.active_cfg.devices:
        owned = sum(1 for ref in srv.hmm.page_table.active.values()
                    if ref.device == d)
        assert srv.hmm.page_table.pages_in_use(d) == owned, d
    assert srv.hmm.page_table.staged is None
    assert srv.hmm.staged is None and not srv.hmm.staging_in_flight

# abort immediately: ops are pending/mid-flight on the background engine
for trial in range(3):
    task = srv.start_scale(c6)
    assert srv.hmm.staging_in_flight
    srv.tick(0.1 * trial)
    task.abort()
    pool_consistent()
    srv.hmm.abort()          # idempotent: second abort is a no-op
    pool_consistent()
    print("ABORT-TRIAL-OK", trial)

# the pool must be fully reusable: a real scale completes afterwards
t, n, task = 1.0, 0, srv.start_scale(c6)
while any(r.finish_s is None for r in reqs) or not task.done:
    srv.tick(t)
    if not task.done: task.advance(t)
    t += .1; n += 1
    assert n < 20000
assert srv.hmm.active_cfg.ndev == 6
assert srv.hmm.last_stats.expert_p2p_bytes == \
    len(srv.hmm.last_migrations) * srv.hmm.expert_page_nbytes()
print("ABORT-IN-FLIGHT-OK")
""")
    assert "ABORT-IN-FLIGHT-OK" in out
    assert out.count("ABORT-TRIAL-OK") == 3


@pytest.mark.slow
def test_overlap_failed_op_unwinds_task_and_server_state():
    """A transfer op that raises mid-flight aborts the session AND the
    task: admit_limit released, _active_task cleared, phase ABORTED, pool
    conserved — and the next scale succeeds."""
    out = run_with_devices(TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.driver import ScalePhase
from repro.serving.workload import Request

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, expert_mode="pooled",
                    staging="overlap", transfer_workers=1)
srv.boot(c6)
srv.preinitialize(c4)
rng = np.random.default_rng(0)
reqs = [Request(i, 0.0, 16, 40, prompt=rng.integers(0, 128, 16))
        for i in range(4)]
for r in reqs: srv.submit(r)
srv.tick(0.0)

orig = srv.hmm._stage_unit
calls = []
def failing_unit(*a, **k):
    calls.append(1)
    if len(calls) == 3:
        raise RuntimeError("injected transfer failure")
    return orig(*a, **k)
srv.hmm._stage_unit = failing_unit

task = srv.start_scale(c4)            # scale-DOWN: admit_limit throttled
assert srv.engine.admit_limit is not None
t, raised = 0.1, False
for n in range(200):
    srv.tick(t)
    try:
        task.advance(t)
    except RuntimeError as e:
        assert "transfer op" in str(e) or "injected" in str(e), e
        raised = True
        break
    t += 0.1
    if task.done: break
assert raised, "injected failure never surfaced"
assert task.phase is ScalePhase.ABORTED
assert srv.engine.admit_limit is None          # capacity released
assert srv._active_task is None and srv._staged_cfg is None
assert srv.hmm.staged is None and not srv.hmm.staging_in_flight
for d in c6.devices:
    owned = sum(1 for ref in srv.hmm.page_table.active.values()
                if ref.device == d)
    assert srv.hmm.page_table.pages_in_use(d) == owned, d

# serving continues on the still-active config and the next scale works
srv.hmm._stage_unit = orig
task = srv.start_scale(c4)
n = 0
while any(r.finish_s is None for r in reqs) or not task.done:
    srv.tick(t)
    if not task.done: task.advance(t)
    t += 0.1; n += 1
    assert n < 20000
assert srv.hmm.active_cfg.ndev == 4
print("FAIL-UNWIND-OK")
""")
    assert "FAIL-UNWIND-OK" in out


@pytest.mark.slow
def test_overlap_commit_abort_race_stress():
    """Interleave aborts (mid-flight) and commits over repeated up/down
    scale events on the pooled + paged-KV stack: the pool conserves pages,
    serving never wedges, and every request finishes."""
    out = run_with_devices(TEST_MOE + """
import numpy as np
from repro.core.topology import ElasticConfig
from repro.core.elastic_engine import ElasticServer
from repro.serving.workload import Request

c4 = ElasticConfig(dp=2, tp=2, devices=(0,1,2,3))
c6 = ElasticConfig(dp=3, tp=2, devices=(0,1,2,3,4,5))
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, expert_mode="pooled",
                    kv_mode="paged", kv_block_size=16,
                    staging="overlap", transfer_workers=2)
srv.boot(c4)
srv.preinitialize(c6)
rng = np.random.default_rng(0)
reqs = [Request(i, 0.0, 16, 120, prompt=rng.integers(0, 128, 16))
        for i in range(4)]
for r in reqs: srv.submit(r)

t, n = 0.0, 0
plan = [("abort", c6), ("commit", c6), ("abort", c4), ("commit", c4),
        ("abort", c6), ("commit", c6)]
for action, target in plan:
    task = srv.start_scale(target)
    srv.tick(t); t += .1; n += 1          # at least one tick mid-flight
    if action == "abort":
        task.abort()
    else:
        while not task.done:
            srv.tick(t); task.advance(t); t += .1; n += 1
            assert n < 40000
    for d in srv.hmm.active_cfg.devices:
        owned = sum(1 for ref in srv.hmm.page_table.active.values()
                    if ref.device == d)
        assert srv.hmm.page_table.pages_in_use(d) == owned, (action, d)
    assert srv.hmm.page_table.staged is None
    srv.engine.kv.check_invariants()
    print("RACE-STEP-OK", action, target.ndev, srv.hmm.active_cfg.ndev)

while any(r.finish_s is None for r in reqs):
    srv.tick(t); t += .1; n += 1
    assert n < 40000
assert srv.hmm.active_cfg.ndev == 6
print("RACE-STRESS-OK")
""")
    assert "RACE-STRESS-OK" in out
    assert out.count("RACE-STEP-OK") == 6


@pytest.mark.slow
def test_overlap_closed_loop_driver_compiles_during_staging():
    """The unchanged ClusterDriver loop over an overlapped ElasticServer:
    scale-up under backlog with a COLD target compile — the IMM AOT compile
    runs inside the STAGING window (STAGING ∥ COMPILING) — then scale-down,
    with completion metrics in the driver event log."""
    out = run_with_devices(TEST_MOE + """
from repro.core.coordinator import ScalingPolicy
from repro.core.elastic_engine import ElasticServer
from repro.core.topology import ElasticConfig
from repro.serving.driver import ClusterDriver, DriverConfig
from repro.serving.metrics import SLO, summarize
from repro.serving.workload import scripted_burst

policy = ScalingPolicy(slo=SLO(ttft_s=1.0, tpot_s=1.0), window=8,
                       cooldown_s=1.0, queue_scale_up=3)
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, staging="overlap")
srv.boot(ElasticConfig(dp=2, tp=2, devices=(0,1,2,3)))
driver = ClusterDriver(srv, policy, mcfg=MCFG, tp=2, device_pool=range(6),
                       config=DriverConfig(dt=0.05, settle_s=2.0,
                                           prewarm_next=False))
assert driver._staging == "overlap"
reqs = scripted_burst([(0.0, 2), (0.5, 7), (6.0, 1)], vocab_size=128, seed=1)
until = 0.0
while any(r.finish_s is None for r in reqs):
    until += 10.0
    driver.run(reqs if until == 10.0 else [], until=until)
    assert until < 400.0, "stalled"
dirs = [e.direction for e in driver.events]
assert "up" in dirs and "down" in dirs, dirs
assert srv.hmm.active_cfg.ndev == 4
# the target was never pre-initialized, so the IMM compiled it cold — and
# overlapped tasks never enter a COMPILING phase: the compile ran inside
# the STAGING window on the serve thread (its cost shows up as stall)
assert srv.imm.stats["preinit_misses"] >= 1, srv.imm.stats
done = [e for e in driver.events if e.stall_s is not None]
assert done and all(e.staging == "overlap" for e in driver.events)
assert all(e.overlap_eff is not None for e in done)
summ = summarize(driver.finished, backend=srv)
assert summ["staging_mode"] == "overlap"
assert summ["decode_stall_s"] >= 0.0
print("OVERLAP-CLOSED-LOOP-OK", dirs)
""")
    assert "OVERLAP-CLOSED-LOOP-OK" in out
