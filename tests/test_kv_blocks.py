"""KV block manager (serving/kv_blocks.py): conservation property tests
over random alloc/append/free/preempt/CoW interleavings, CoW/prefix-sharing
unit tests, elastic partition grow/shrink, and preemption-under-pressure on
the discrete-event simulator backend."""
import numpy as np
import pytest

from repro.serving.kv_blocks import KVBlockManager, blocks_for


# ------------------------------------------------------------------ units

def test_blocks_for():
    assert blocks_for(0, 16) == 1
    assert blocks_for(1, 16) == 1
    assert blocks_for(16, 16) == 1
    assert blocks_for(17, 16) == 2


def test_alloc_free_roundtrip():
    m = KVBlockManager(2, 4, 16)
    a = m.allocate(1, 40, partition=0)
    assert len(a.blocks) == 3 and m.free_blocks(0) == 1
    assert m.free_blocks(1) == 4            # partitions are independent
    released = m.free(1)
    assert sorted(released) == sorted(a.blocks)
    assert m.free_blocks() == 8
    m.check_invariants()


def test_pool_dry_raises_and_can_allocate_agrees():
    m = KVBlockManager(1, 4, 16)
    assert m.can_allocate(64, 0)
    m.allocate(1, 64, partition=0)          # 4 blocks: pool now dry
    assert not m.can_allocate(1, 0)
    with pytest.raises(MemoryError):
        m.allocate(2, 1, partition=0)
    m.check_invariants()


def test_prefix_sharing_and_cow():
    """Identical leading chunks are shared refcounted; the first append
    into a shared block forks it (caller copies contents)."""
    m = KVBlockManager(1, 16, 4)
    toks = list(range(10))                  # chunks (0..3)(4..7)(8,9 partial)
    a = m.allocate(1, 10, partition=0, tokens=toks)
    b = m.allocate(2, 10, partition=0, tokens=toks)
    assert b.blocks == a.blocks and b.num_shared == 3
    assert m.used_blocks() == 3             # fully shared
    r = m.append(2)                         # pos 10 -> shared partial tail
    assert r is not None and r.cow_src == a.blocks[2] and r.grew
    assert m.seq(2).blocks[2] == r.block != a.blocks[2]
    assert m.cow_copies == 1
    # seq 1 now owns its tail alone: in-place append, no copy
    assert m.append(1) is None
    m.check_invariants()
    m.free(1)
    m.check_invariants()
    assert m.used_blocks() == len(m.seq(2).blocks)
    m.free(2)
    assert m.used_blocks() == 0


def test_partial_tail_matches_shorter_request_only():
    """A request whose tail is a PREFIX of a live block's contents shares
    it; a longer tail (tokens the block doesn't hold) must not match."""
    m = KVBlockManager(1, 16, 4)
    m.allocate(1, 6, partition=0, tokens=[0, 1, 2, 3, 4, 5])
    shorter = m.allocate(2, 5, partition=0, tokens=[0, 1, 2, 3, 4])
    assert shorter.num_shared == 2          # full block + partial tail
    longer = m.allocate(3, 7, partition=0, tokens=[0, 1, 2, 3, 4, 5, 6])
    assert longer.num_shared == 1           # only the full block
    m.check_invariants()


def test_mismatched_prefix_not_shared():
    m = KVBlockManager(1, 16, 4)
    m.allocate(1, 8, partition=0, tokens=[0, 1, 2, 3, 4, 5, 6, 7])
    b = m.allocate(2, 8, partition=0, tokens=[0, 1, 2, 9, 4, 5, 6, 7])
    assert b.num_shared == 0
    m.check_invariants()


def test_prefix_sharing_is_partition_local():
    m = KVBlockManager(2, 8, 4)
    m.allocate(1, 8, partition=0, tokens=[0, 1, 2, 3, 4, 5, 6, 7])
    b = m.allocate(2, 8, partition=1, tokens=[0, 1, 2, 3, 4, 5, 6, 7])
    assert b.num_shared == 0                # replica pools do not alias
    m.check_invariants()


def test_victim_order_lowest_priority_then_youngest():
    m = KVBlockManager(1, 16, 4)
    m.allocate(1, 4, partition=0, priority=1)
    m.allocate(2, 4, partition=0, priority=0)
    m.allocate(3, 4, partition=0, priority=0)
    assert m.victim() == 3                  # priority 0, youngest
    assert m.victim(exclude=(3,)) == 2
    m.preempt(3)
    assert m.preemptions == 1
    m.check_invariants()


def test_grow_and_shrink_partitions():
    m = KVBlockManager(2, 4, 16)
    a = m.allocate(1, 64, partition=0)
    m.grow_partitions(3)
    assert m.num_blocks == 12
    assert m.seq(1).blocks == a.blocks      # tables survive verbatim
    m.allocate(2, 16, partition=2)
    with pytest.raises(AssertionError):
        m.shrink_partitions(2)              # partition 2 not drained
    m.free(2)
    m.shrink_partitions(2)
    assert m.num_blocks == 8
    m.check_invariants()


# ------------------------------------------- live migration (zero-drain)

def test_migration_moves_component_and_preserves_sharing():
    """A CoW-sharing component migrates whole: tables remapped, refcounts
    moved block-for-block, and the prefix registry re-keyed so NEW requests
    in the destination partition still share the moved prefix."""
    m = KVBlockManager(3, 8, 4)
    toks = list(range(10))
    src_blocks = list(m.allocate(1, 10, partition=2, tokens=toks).blocks)
    m.allocate(2, 10, partition=2, tokens=toks)       # fully shared
    m.allocate(3, 5, partition=2, tokens=[9, 8, 7, 6, 5])
    assert m.share_components(2) == [[1, 2], [3]]
    t = m.begin_migration([1, 2], 0)
    assert t.num_blocks == 3                          # shared counted once
    assert m.migrating(1) and not m.migrating(3)
    m.check_invariants()                              # mid-flight
    released = m.commit_migration(t)
    m.check_invariants()
    assert sorted(released) == sorted(src_blocks)
    assert m.seq(1).partition == 0
    assert m.seq(1).blocks == m.seq(2).blocks         # sharing survived
    assert all(b // 8 == 0 for b in m.seq(1).blocks)
    # prefix registry followed the blocks into the new partition
    d = m.allocate(4, 10, partition=0, tokens=toks)
    assert d.num_shared == 3 and d.blocks == m.seq(1).blocks
    # CoW still forks on append after the move
    r = m.append(2)
    assert r is not None and r.cow_src is not None
    m.check_invariants()
    for s in (1, 2, 3, 4):
        m.free(s)
    assert m.used_blocks() == 0
    m.shrink_partitions(2)
    m.check_invariants()


def test_migration_abort_restores_everything():
    m = KVBlockManager(2, 6, 4)
    src_blocks = list(m.allocate(1, 12, partition=1).blocks)
    free_before = m.free_blocks(0)
    t = m.begin_migration([1], 0)
    assert m.free_blocks(0) == free_before - 3        # reserved
    m.check_invariants()
    m.abort_migration(t)
    m.abort_migration(t)                              # idempotent
    assert m.free_blocks(0) == free_before
    assert m.seq(1).blocks == src_blocks and m.seq(1).partition == 1
    m.check_invariants()
    # a fresh migration after the abort succeeds
    m.commit_migration(m.begin_migration([1], 0))
    assert m.seq(1).partition == 0
    m.check_invariants()


def test_migration_guards():
    """Dst dry -> MemoryError (the engine's preempt fallback); a component
    torn apart, a frozen append, and a shrink with a pending ticket are
    caller bugs -> assertion."""
    m = KVBlockManager(2, 4, 4)
    m.allocate(1, 16, partition=0)                    # partition 0 full
    m.allocate(2, 8, partition=1)
    with pytest.raises(MemoryError):
        m.begin_migration([2], 0)
    m.check_invariants()                              # failed begin leaks nothing
    toks = list(range(8))
    m.free(1)
    m.allocate(3, 8, partition=1, tokens=toks)
    m.allocate(4, 8, partition=1, tokens=toks)        # shares with 3
    with pytest.raises(AssertionError):
        m.begin_migration([3], 0)                     # co-owner left behind
    t = m.begin_migration([2], 0)
    with pytest.raises(AssertionError):
        m.append(2)                                   # frozen mid-migration
    with pytest.raises(AssertionError):
        m.shrink_partitions(1)                        # ticket pending
    assert m.victim(candidates=[2, 3]) == 3           # migrating excluded
    m.abort_migration(t)
    m.check_invariants()


def test_migration_random_walk_conserves():
    """Deterministic random interleaving of alloc/append/free/migrate/
    abort across 3 partitions: conservation holds at every step."""
    import random
    rng = random.Random(7)
    m = KVBlockManager(3, 10, 4)
    nxt = 0
    for step in range(400):
        op = rng.random()
        live = [s for s in m.live_seqs() if not m.migrating(s)]
        if op < 0.35:
            p = rng.randrange(3)
            if m.can_allocate(6, p):
                m.allocate(nxt, 6, partition=p)
                nxt += 1
        elif op < 0.6 and live:
            s = rng.choice(live)
            try:
                m.append(s)
            except MemoryError:
                m.preempt(s)
        elif op < 0.75 and live:
            m.free(rng.choice(live))
        elif live:
            s = rng.choice(live)
            src = m.seq(s).partition
            dst = rng.choice([q for q in range(3) if q != src])
            comp = next(c for c in m.share_components(src) if s in c)
            if all(not m.migrating(x) for x in comp):
                try:
                    t = m.begin_migration(comp, dst)
                except MemoryError:
                    continue
                m.check_invariants()
                if rng.random() < 0.3:
                    m.abort_migration(t)
                else:
                    m.commit_migration(t)
        m.check_invariants()
    for s in list(m.live_seqs()):
        m.free(s)
    assert m.used_blocks() == 0
    m.check_invariants()


# ------------------------------------------------- simulator under pressure

def test_simulator_paged_preempts_and_completes():
    """Block-occupancy admission over-commits the pool; the overflow is
    resolved by preemption and the whole burst still completes — while the
    same pool under dense (full-length-reservation) admission leaves the
    burst queued far longer."""
    from repro.configs import get_config
    from repro.serving.simulator import PerfModel, ServingSimulator
    from repro.serving.workload import burst, make_workload

    mcfg = get_config("qwen3-30b-a3b")

    def run(kv_mode):
        perf = PerfModel(mcfg, kv_seq_len=32768, kv_block_size=512,
                         max_batch_per_dev=48)
        sim = ServingSimulator(mcfg, tp=2, ndev=2, strategy="elastic",
                               perf=perf, kv_mode=kv_mode)
        reqs = make_workload(duration_s=60.0,
                             rps_fn=burst(0.4, 8.0, 10.0, 30.0),
                             prompt_len=(2000, 8000),
                             output_range=(500, 1500), seed=3)
        t = 0.0
        while t < 600.0 and any(r.finish_s is None for r in reqs):
            t += 5.0
            sim.run(reqs if t == 5.0 else [], until=t)
        return reqs, sim, t

    reqs_p, sim_p, makespan_p = run("paged")
    assert all(r.finish_s is not None for r in reqs_p), "burst did not finish"
    assert sim_p.preemptions > 0, "pool pressure never triggered preemption"
    st = sim_p.kv_stats()
    assert st is not None and st["preemptions"] == sim_p.preemptions

    reqs_d, sim_d, makespan_d = run("dense")
    assert sim_d.kv_stats() is None
    unfinished_d = sum(1 for r in reqs_d if r.finish_s is None)
    # dense either never finishes the burst inside the horizon or takes
    # strictly longer than occupancy-based admission
    assert unfinished_d > 0 or makespan_d > makespan_p


def test_closed_loop_driver_over_paged_backend():
    """The unchanged ClusterDriver loop runs over a paged-admission backend:
    block occupancy feeds utilization(), the burst still trips a scale-up,
    and driver events record the pool pressure at decision time."""
    from repro.configs import get_config
    from repro.core.coordinator import ScalingPolicy
    from repro.serving.driver import ClusterDriver, DriverConfig
    from repro.serving.metrics import SLO
    from repro.serving.simulator import ServingSimulator
    from repro.serving.workload import burst, make_workload

    mcfg = get_config("deepseek-v2-lite-16b")
    sim = ServingSimulator(mcfg, tp=2, ndev=4, strategy="elastic",
                           kv_mode="paged")
    policy = ScalingPolicy(slo=SLO(ttft_s=5.0, tpot_s=1.5), window=16,
                           cooldown_s=15.0, queue_scale_up=6, confirm_s=1.0)
    driver = ClusterDriver(sim, policy, mcfg=mcfg, tp=2,
                           device_pool=range(8),
                           config=DriverConfig(dt=0.05, settle_s=15.0,
                                               min_dp=2))
    reqs = make_workload(duration_s=200.0,
                         rps_fn=burst(2.0, 14.0, 60.0, 60.0),
                         prompt_len=(1500, 2500), output_range=(500, 750),
                         seed=0)
    driver.run(reqs, until=300.0)
    ups = [e for e in driver.events if e.direction == "up"]
    assert ups, "driver never scaled up under the burst"
    assert all(e.kv_util is not None for e in driver.events)
    assert len(driver.finished) >= 0.9 * len(reqs)


def test_simulator_paged_utilization_reflects_blocks():
    from repro.configs import get_config
    from repro.serving.simulator import ServingSimulator
    from repro.serving.workload import Request

    mcfg = get_config("deepseek-v2-lite-16b")
    sim = ServingSimulator(mcfg, tp=2, ndev=4, kv_mode="paged",
                           pool_blocks=100)
    assert sim.utilization() == 0.0
    sim.submit(Request(0, 0.0, 4096, 500))
    sim.step(0.0)
    assert sim.used_blocks() > 0
    assert 0.0 < sim.utilization() <= 1.0
