"""KV block manager (serving/kv_blocks.py): conservation property tests
over random alloc/append/free/preempt/CoW interleavings, CoW/prefix-sharing
unit tests, elastic partition grow/shrink, and preemption-under-pressure on
the discrete-event simulator backend."""
import numpy as np
import pytest

from repro.serving.kv_blocks import KVBlockManager, blocks_for


# ------------------------------------------------------------------ units

def test_blocks_for():
    assert blocks_for(0, 16) == 1
    assert blocks_for(1, 16) == 1
    assert blocks_for(16, 16) == 1
    assert blocks_for(17, 16) == 2


def test_alloc_free_roundtrip():
    m = KVBlockManager(2, 4, 16)
    a = m.allocate(1, 40, partition=0)
    assert len(a.blocks) == 3 and m.free_blocks(0) == 1
    assert m.free_blocks(1) == 4            # partitions are independent
    released = m.free(1)
    assert sorted(released) == sorted(a.blocks)
    assert m.free_blocks() == 8
    m.check_invariants()


def test_pool_dry_raises_and_can_allocate_agrees():
    m = KVBlockManager(1, 4, 16)
    assert m.can_allocate(64, 0)
    m.allocate(1, 64, partition=0)          # 4 blocks: pool now dry
    assert not m.can_allocate(1, 0)
    with pytest.raises(MemoryError):
        m.allocate(2, 1, partition=0)
    m.check_invariants()


def test_prefix_sharing_and_cow():
    """Identical leading chunks are shared refcounted; the first append
    into a shared block forks it (caller copies contents)."""
    m = KVBlockManager(1, 16, 4)
    toks = list(range(10))                  # chunks (0..3)(4..7)(8,9 partial)
    a = m.allocate(1, 10, partition=0, tokens=toks)
    b = m.allocate(2, 10, partition=0, tokens=toks)
    assert b.blocks == a.blocks and b.num_shared == 3
    assert m.used_blocks() == 3             # fully shared
    r = m.append(2)                         # pos 10 -> shared partial tail
    assert r is not None and r.cow_src == a.blocks[2] and r.grew
    assert m.seq(2).blocks[2] == r.block != a.blocks[2]
    assert m.cow_copies == 1
    # seq 1 now owns its tail alone: in-place append, no copy
    assert m.append(1) is None
    m.check_invariants()
    m.free(1)
    m.check_invariants()
    assert m.used_blocks() == len(m.seq(2).blocks)
    m.free(2)
    assert m.used_blocks() == 0


def test_partial_tail_matches_shorter_request_only():
    """A request whose tail is a PREFIX of a live block's contents shares
    it; a longer tail (tokens the block doesn't hold) must not match."""
    m = KVBlockManager(1, 16, 4)
    m.allocate(1, 6, partition=0, tokens=[0, 1, 2, 3, 4, 5])
    shorter = m.allocate(2, 5, partition=0, tokens=[0, 1, 2, 3, 4])
    assert shorter.num_shared == 2          # full block + partial tail
    longer = m.allocate(3, 7, partition=0, tokens=[0, 1, 2, 3, 4, 5, 6])
    assert longer.num_shared == 1           # only the full block
    m.check_invariants()


def test_mismatched_prefix_not_shared():
    m = KVBlockManager(1, 16, 4)
    m.allocate(1, 8, partition=0, tokens=[0, 1, 2, 3, 4, 5, 6, 7])
    b = m.allocate(2, 8, partition=0, tokens=[0, 1, 2, 9, 4, 5, 6, 7])
    assert b.num_shared == 0
    m.check_invariants()


def test_prefix_sharing_is_partition_local():
    m = KVBlockManager(2, 8, 4)
    m.allocate(1, 8, partition=0, tokens=[0, 1, 2, 3, 4, 5, 6, 7])
    b = m.allocate(2, 8, partition=1, tokens=[0, 1, 2, 3, 4, 5, 6, 7])
    assert b.num_shared == 0                # replica pools do not alias
    m.check_invariants()


def test_victim_order_lowest_priority_then_youngest():
    m = KVBlockManager(1, 16, 4)
    m.allocate(1, 4, partition=0, priority=1)
    m.allocate(2, 4, partition=0, priority=0)
    m.allocate(3, 4, partition=0, priority=0)
    assert m.victim() == 3                  # priority 0, youngest
    assert m.victim(exclude=(3,)) == 2
    m.preempt(3)
    assert m.preemptions == 1
    m.check_invariants()


def test_grow_and_shrink_partitions():
    m = KVBlockManager(2, 4, 16)
    a = m.allocate(1, 64, partition=0)
    m.grow_partitions(3)
    assert m.num_blocks == 12
    assert m.seq(1).blocks == a.blocks      # tables survive verbatim
    m.allocate(2, 16, partition=2)
    with pytest.raises(AssertionError):
        m.shrink_partitions(2)              # partition 2 not drained
    m.free(2)
    m.shrink_partitions(2)
    assert m.num_blocks == 8
    m.check_invariants()


# ------------------------------------------------- simulator under pressure

def test_simulator_paged_preempts_and_completes():
    """Block-occupancy admission over-commits the pool; the overflow is
    resolved by preemption and the whole burst still completes — while the
    same pool under dense (full-length-reservation) admission leaves the
    burst queued far longer."""
    from repro.configs import get_config
    from repro.serving.simulator import PerfModel, ServingSimulator
    from repro.serving.workload import burst, make_workload

    mcfg = get_config("qwen3-30b-a3b")

    def run(kv_mode):
        perf = PerfModel(mcfg, kv_seq_len=32768, kv_block_size=512,
                         max_batch_per_dev=48)
        sim = ServingSimulator(mcfg, tp=2, ndev=2, strategy="elastic",
                               perf=perf, kv_mode=kv_mode)
        reqs = make_workload(duration_s=60.0,
                             rps_fn=burst(0.4, 8.0, 10.0, 30.0),
                             prompt_len=(2000, 8000),
                             output_range=(500, 1500), seed=3)
        t = 0.0
        while t < 600.0 and any(r.finish_s is None for r in reqs):
            t += 5.0
            sim.run(reqs if t == 5.0 else [], until=t)
        return reqs, sim, t

    reqs_p, sim_p, makespan_p = run("paged")
    assert all(r.finish_s is not None for r in reqs_p), "burst did not finish"
    assert sim_p.preemptions > 0, "pool pressure never triggered preemption"
    st = sim_p.kv_stats()
    assert st is not None and st["preemptions"] == sim_p.preemptions

    reqs_d, sim_d, makespan_d = run("dense")
    assert sim_d.kv_stats() is None
    unfinished_d = sum(1 for r in reqs_d if r.finish_s is None)
    # dense either never finishes the burst inside the horizon or takes
    # strictly longer than occupancy-based admission
    assert unfinished_d > 0 or makespan_d > makespan_p


def test_closed_loop_driver_over_paged_backend():
    """The unchanged ClusterDriver loop runs over a paged-admission backend:
    block occupancy feeds utilization(), the burst still trips a scale-up,
    and driver events record the pool pressure at decision time."""
    from repro.configs import get_config
    from repro.core.coordinator import ScalingPolicy
    from repro.serving.driver import ClusterDriver, DriverConfig
    from repro.serving.metrics import SLO
    from repro.serving.simulator import ServingSimulator
    from repro.serving.workload import burst, make_workload

    mcfg = get_config("deepseek-v2-lite-16b")
    sim = ServingSimulator(mcfg, tp=2, ndev=4, strategy="elastic",
                           kv_mode="paged")
    policy = ScalingPolicy(slo=SLO(ttft_s=5.0, tpot_s=1.5), window=16,
                           cooldown_s=15.0, queue_scale_up=6, confirm_s=1.0)
    driver = ClusterDriver(sim, policy, mcfg=mcfg, tp=2,
                           device_pool=range(8),
                           config=DriverConfig(dt=0.05, settle_s=15.0,
                                               min_dp=2))
    reqs = make_workload(duration_s=200.0,
                         rps_fn=burst(2.0, 14.0, 60.0, 60.0),
                         prompt_len=(1500, 2500), output_range=(500, 750),
                         seed=0)
    driver.run(reqs, until=300.0)
    ups = [e for e in driver.events if e.direction == "up"]
    assert ups, "driver never scaled up under the burst"
    assert all(e.kv_util is not None for e in driver.events)
    assert len(driver.finished) >= 0.9 * len(reqs)


def test_simulator_paged_utilization_reflects_blocks():
    from repro.configs import get_config
    from repro.serving.simulator import ServingSimulator
    from repro.serving.workload import Request

    mcfg = get_config("deepseek-v2-lite-16b")
    sim = ServingSimulator(mcfg, tp=2, ndev=4, kv_mode="paged",
                           pool_blocks=100)
    assert sim.utilization() == 0.0
    sim.submit(Request(0, 0.0, 4096, 500))
    sim.step(0.0)
    assert sim.used_blocks() > 0
    assert 0.0 < sim.utilization() <= 1.0
