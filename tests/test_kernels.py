"""Per-kernel allclose vs pure-jnp oracles, sweeping shapes and dtypes
(interpret=True on CPU) — deliverable (c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F,P", [
    (2, 128, 64, 128, 4),
    (4, 256, 128, 256, 9),
    (1, 128, 32, 128, 2),
])
def test_paged_gmm(E, C, D, F, P, dtype):
    table = jnp.asarray(RNG.permutation(P)[:E].astype(np.int32))
    pool = jnp.asarray(RNG.standard_normal((P, D, F)), dtype)
    x = jnp.asarray(RNG.standard_normal((E, C, D)), dtype)
    got = ops.paged_gmm(table, pool, x)
    want = ref.paged_gmm_ref(table, pool, x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_paged_expert_ffn():
    E, C, D, F, P = 3, 128, 64, 128, 6
    ti, tg, to = (jnp.asarray(RNG.permutation(P)[:E].astype(np.int32))
                  for _ in range(3))
    pi = jnp.asarray(RNG.standard_normal((P, D, F)), jnp.float32)
    pg = jnp.asarray(RNG.standard_normal((P, D, F)), jnp.float32)
    po = jnp.asarray(RNG.standard_normal((P, F, D)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((E, C, D)), jnp.float32)
    # impl='kernel' forces the Pallas path (ops defaults to the ref oracle
    # on CPU per REPRO_POOLED_IMPL, which would compare ref to itself here)
    got = ops.paged_expert_ffn(ti, tg, to, pi, pg, po, x, impl="kernel")
    want = ref.paged_expert_ffn_ref(ti, tg, to, pi, pg, po, x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    # the ops-level default (CPU -> ref fallback) must agree too
    got_auto = ops.paged_expert_ffn(ti, tg, to, pi, pg, po, x)
    np.testing.assert_allclose(got_auto, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("E,C,D,F,bc,bf", [
    (2, 200, 64, 128, 128, 128),    # C % block_c != 0 -> zero-pad C
    (3, 128, 32, 192, 128, 128),    # F % 128-block -> clamp to full dim
    (2, 100, 64, 144, 64, 128),     # both ragged at once
    (1, 128, 32, 384, 128, 256),    # F clamps 256 -> aligned divisor 128
    (1, 128, 32, 130, 128, 128),    # F prime-ish -> full-dim lane tile
])
def test_paged_gmm_unaligned_blocks(E, C, D, F, bc, bf):
    """Pad-or-clamp: token counts not divisible by block_c are zero-padded
    (zero rows produce zero outputs, sliced off); hidden dims not divisible
    by block_f clamp the block to a 128-aligned divisor or the full dim
    (never an unaligned lane tile — Mosaic constraint; padding F would copy
    every pool page).  Results must match the oracle exactly either way."""
    P = E + 2
    table = jnp.asarray(RNG.permutation(P)[:E].astype(np.int32))
    pool = jnp.asarray(RNG.standard_normal((P, D, F)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((E, C, D)), jnp.float32)
    got = ops.paged_gmm(table, pool, x, block_c=bc, block_f=bf)
    want = ref.paged_gmm_ref(table, pool, x)
    assert got.shape == want.shape == (E, C, F)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_paged_gmm_bf16_vs_f32_oracle():
    """bf16 kernel against the f32 oracle (not the bf16 oracle): the paged
    indirection must not add error beyond bf16 rounding of inputs."""
    E, C, D, F, P = 2, 128, 64, 128, 5
    table = jnp.asarray(RNG.permutation(P)[:E].astype(np.int32))
    pool32 = jnp.asarray(RNG.standard_normal((P, D, F)), jnp.float32)
    x32 = jnp.asarray(RNG.standard_normal((E, C, D)), jnp.float32)
    got = ops.paged_gmm(table, pool32.astype(jnp.bfloat16),
                        x32.astype(jnp.bfloat16))
    want = ref.paged_gmm_ref(table, pool32, x32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-1)


def test_paged_gmm_aliased_table_entries():
    """Table entries pointing at the SAME page (post-CoW-style sharing):
    every aliased expert must read identical weights — each grid step only
    dereferences pool[table[e]], so aliasing is free."""
    E, C, D, F, P = 4, 128, 32, 128, 6
    table = jnp.asarray(np.array([3, 3, 5, 3], np.int32))
    pool = jnp.asarray(RNG.standard_normal((P, D, F)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((E, C, D)), jnp.float32)
    got = ops.paged_gmm(table, pool, x)
    want = ref.paged_gmm_ref(table, pool, x)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)
    # experts 0, 1 and 3 share page 3: same inputs -> identical outputs
    same_x = x.at[1].set(x[0]).at[3].set(x[0])
    out = ops.paged_gmm(table, pool, same_x)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[3]))


def test_paged_gmm_remap_invariance():
    """Permuting pages + updating the table must not change results — the
    vpage-remap guarantee at kernel level."""
    E, C, D, F, P = 4, 128, 32, 128, 8
    table = jnp.arange(E, dtype=jnp.int32)
    pool = jnp.asarray(RNG.standard_normal((P, D, F)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((E, C, D)), jnp.float32)
    base = ops.paged_gmm(table, pool, x)
    perm = RNG.permutation(P)
    pool2 = pool[jnp.asarray(np.argsort(perm))]          # pages physically moved
    table2 = jnp.asarray(perm[np.asarray(table)], np.int32)
    got = ops.paged_gmm(table2, pool2, x)
    np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KVH,hd,bq,bk", [
    (2, 256, 4, 2, 64, 128, 128),
    (1, 512, 8, 8, 128, 128, 256),
    (2, 128, 4, 1, 80, 64, 64),
])
def test_flash_attention(B, S, H, KVH, hd, bq, bk, dtype):
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, S, KVH, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, S, KVH, hd)), dtype)
    got = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_attention_non_causal():
    B, S, H, hd = 1, 256, 2, 64
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, hd)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,hd,S", [
    (3, 8, 2, 64, 256),
    (2, 4, 4, 128, 512),
    (1, 16, 2, 80, 128),
])
def test_paged_decode_attention(B, H, KVH, hd, S, dtype):
    q = jnp.asarray(RNG.standard_normal((B, H, hd)), dtype)
    kc = jnp.asarray(RNG.standard_normal((B, S, KVH, hd)), dtype)
    vc = jnp.asarray(RNG.standard_normal((B, S, KVH, hd)), dtype)
    lengths = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    got = ops.paged_decode_attention(q, kc, vc, lengths)
    want = ref.paged_decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,hd,NB,bs,MB", [
    (3, 8, 2, 64, 16, 128, 4),
    (2, 4, 4, 128, 8, 256, 2),
    (1, 16, 2, 80, 12, 64, 6),
])
def test_block_paged_decode_attention(B, H, KVH, hd, NB, bs, MB, dtype):
    """Pallas block-table kernel vs the jnp gather oracle: per-sequence
    block tables index a shared [NB, bs, KVH, hd] pool."""
    from repro.kernels.paged_attention import block_paged_decode_attention
    q = jnp.asarray(RNG.standard_normal((B, H, hd)), dtype)
    kp = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), dtype)
    vp = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), dtype)
    bt = jnp.asarray(RNG.permutation(NB)[:B * MB].reshape(B, MB)
                     .astype(np.int32))
    lengths = jnp.asarray(RNG.integers(1, MB * bs + 1, B), jnp.int32)
    want = ref.block_paged_decode_attention_ref(q, kp, vp, bt, lengths)
    got = block_paged_decode_attention(q, kp, vp, bt, lengths,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))
    # ops export: ref fallback on CPU must agree too
    got_ops = ops.block_paged_decode_attention(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(got_ops, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,hd,NB,bs,MB,Sq", [
    (2, 4, 2, 64, 12, 64, 4, 32),
    (1, 8, 4, 128, 8, 128, 2, 48),
    (3, 4, 1, 80, 20, 32, 6, 16),
])
def test_mixed_block_paged_attention(B, H, KVH, hd, NB, bs, MB, Sq, dtype):
    """Mixed chunked-prefill/decode kernel vs the jnp gather oracle, with
    per-sequence chunk lengths deliberately unaligned to both the compiled
    ``Sq`` bucket and the block size.  Only valid chunk rows are compared —
    padding rows degrade to full-context decode masking by design."""
    from repro.kernels.paged_attention import mixed_block_paged_attention
    q = jnp.asarray(RNG.standard_normal((B, Sq, H, hd)), dtype)
    kp = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), dtype)
    vp = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), dtype)
    bt = jnp.asarray(RNG.permutation(NB)[:B * MB].reshape(B, MB)
                     .astype(np.int32))
    q_lens = RNG.integers(1, Sq + 1, B)
    ctx = np.array([RNG.integers(ql, MB * bs + 1) for ql in q_lens])
    q_lens, ctx = jnp.asarray(q_lens, jnp.int32), jnp.asarray(ctx, jnp.int32)
    want = ref.mixed_block_paged_attention_ref(q, kp, vp, bt, ctx, q_lens)
    got = mixed_block_paged_attention(q, kp, vp, bt, ctx, q_lens,
                                      interpret=True)
    got_ops = ops.mixed_block_paged_attention(q, kp, vp, bt, ctx, q_lens)
    for b in range(B):
        n = int(q_lens[b])
        np.testing.assert_allclose(np.asarray(got[b, :n], np.float32),
                                   np.asarray(want[b, :n], np.float32),
                                   **tol(dtype))
        # ops export: ref fallback on CPU (REPRO_PAGED_IMPL) must agree too
        np.testing.assert_allclose(np.asarray(got_ops[b, :n], np.float32),
                                   np.asarray(want[b, :n], np.float32),
                                   **tol(dtype))


def test_mixed_sentinel_block_rows_are_inert():
    """Block-table entries past a sequence's context may hold the NB
    sentinel (padding / CoW-dropped rows).  They are clamped in-bounds and
    position-masked, so swapping them for arbitrary live rows must not
    change a single output bit."""
    from repro.kernels.paged_attention import mixed_block_paged_attention
    B, H, KVH, hd, NB, bs, MB, Sq = 2, 4, 2, 64, 10, 32, 5, 16
    q = jnp.asarray(RNG.standard_normal((B, Sq, H, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), jnp.float32)
    ctx = jnp.asarray([40, 70], jnp.int32)              # 2 and 3 live blocks
    q_lens = jnp.asarray([7, 16], jnp.int32)
    base_bt = RNG.permutation(NB)[:B * MB].reshape(B, MB).astype(np.int32)
    sent = base_bt.copy()
    junk = base_bt.copy()
    for b in range(B):
        live = (int(ctx[b]) + bs - 1) // bs
        sent[b, live:] = NB                              # sentinel rows
        junk[b, live:] = RNG.integers(0, NB, MB - live)  # arbitrary rows
    outs = [mixed_block_paged_attention(q, kp, vp, jnp.asarray(t), ctx,
                                        q_lens, interpret=True)
            for t in (sent, junk, base_bt)]
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[2]))
    want = ref.mixed_block_paged_attention_ref(q, kp, vp, jnp.asarray(sent),
                                               ctx, q_lens)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_mixed_qlen1_is_exactly_paged_decode():
    """``q_lens == 1`` collapses the mixed mask to plain paged decode — the
    property that lets one kernel serve interleaved prefill+decode ticks."""
    from repro.kernels.paged_attention import mixed_block_paged_attention
    B, H, KVH, hd, NB, bs, MB = 3, 8, 2, 64, 12, 64, 4
    q = jnp.asarray(RNG.standard_normal((B, 1, H, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), jnp.float32)
    bt = jnp.asarray(RNG.permutation(NB)[:B * MB].reshape(B, MB)
                     .astype(np.int32))
    ctx = jnp.asarray(RNG.integers(1, MB * bs + 1, B), jnp.int32)
    ones = jnp.ones((B,), jnp.int32)
    got = mixed_block_paged_attention(q, kp, vp, bt, ctx, ones,
                                      interpret=True)
    want = ref.block_paged_decode_attention_ref(q[:, 0], kp, vp, bt, ctx)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_mixed_bf16_vs_f32_oracle():
    """bf16 mixed kernel against the f32 oracle: the paged indirection and
    online softmax must not add error beyond bf16 input rounding."""
    from repro.kernels.paged_attention import mixed_block_paged_attention
    B, H, KVH, hd, NB, bs, MB, Sq = 2, 4, 2, 64, 8, 64, 3, 24
    q32 = jnp.asarray(RNG.standard_normal((B, Sq, H, hd)), jnp.float32)
    kp32 = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), jnp.float32)
    vp32 = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), jnp.float32)
    bt = jnp.asarray(RNG.permutation(NB)[:B * MB].reshape(B, MB)
                     .astype(np.int32))
    ctx = jnp.asarray([100, 192], jnp.int32)
    q_lens = jnp.asarray([24, 13], jnp.int32)
    got = mixed_block_paged_attention(
        q32.astype(jnp.bfloat16), kp32.astype(jnp.bfloat16),
        vp32.astype(jnp.bfloat16), bt, ctx, q_lens, interpret=True)
    want = ref.mixed_block_paged_attention_ref(q32, kp32, vp32, bt, ctx,
                                               q_lens)
    for b in range(B):
        n = int(q_lens[b])
        np.testing.assert_allclose(np.asarray(got[b, :n], np.float32),
                                   np.asarray(want[b, :n], np.float32),
                                   rtol=5e-2, atol=5e-2)


def test_block_paged_decode_remap_invariance():
    """Permuting pool rows + rewriting the tables must not change results —
    the zero-copy-remap guarantee at kernel level (what makes the HMM's
    commit-time pool growth safe for live sequences)."""
    from repro.kernels.paged_attention import block_paged_decode_attention
    B, H, KVH, hd, NB, bs, MB = 2, 4, 2, 64, 12, 128, 3
    q = jnp.asarray(RNG.standard_normal((B, H, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((NB, bs, KVH, hd)), jnp.float32)
    bt = jnp.asarray(RNG.permutation(NB)[:B * MB].reshape(B, MB)
                     .astype(np.int32))
    lengths = jnp.asarray([200, 350], jnp.int32)
    base = block_paged_decode_attention(q, kp, vp, bt, lengths,
                                        interpret=True)
    perm = RNG.permutation(NB)
    inv = np.argsort(perm)
    kp2, vp2 = kp[jnp.asarray(inv)], vp[jnp.asarray(inv)]  # rows moved
    bt2 = jnp.asarray(perm[np.asarray(bt)].astype(np.int32))
    got = block_paged_decode_attention(q, kp2, vp2, bt2, lengths,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 64, 64),
    (2, 64, 8, 16, 8, 16),
])
def test_ssd_scan(B, S, H, P, N, chunk):
    x = jnp.asarray(RNG.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.random((B, S, H)) * 0.5 + 0.01, jnp.float32)
    A = -jnp.asarray(RNG.random(H) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    y1, s1 = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y2, s2 = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_model_ssd():
    """Kernel agrees with the model's chunked SSD (used in mamba2_forward)."""
    from repro.models.mamba2 import _ssd_chunked
    B, S, H, P, N = 1, 128, 2, 16, 8
    x = jnp.asarray(RNG.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.random((B, S, H)) * 0.5 + 0.01, jnp.float32)
    A = -jnp.asarray(RNG.random(H) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, S, N)), jnp.float32)
    y1, s1 = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    y2, s2 = _ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,r,dr,S", [
    (2, 4, 128, 16, 256),
    (1, 16, 512, 64, 512),
    (3, 8, 256, 32, 128),
])
def test_mla_decode_attention(B, H, r, dr, S, dtype):
    qe = jnp.asarray(RNG.standard_normal((B, H, r)), dtype)
    qr = jnp.asarray(RNG.standard_normal((B, H, dr)), dtype)
    cc = jnp.asarray(RNG.standard_normal((B, S, r)), dtype)
    kr = jnp.asarray(RNG.standard_normal((B, S, dr)), dtype)
    lengths = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    got = ops.mla_decode_attention(qe, qr, cc, kr, lengths)
    want = ref.mla_decode_attention_ref(qe, qr, cc, kr, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,S,KVH,hd,block", [
    (3, 256, 2, 64, 128),
    (2, 512, 4, 128, 256),
    (1, 128, 1, 80, 64),
])
def test_kv_cache_write_inplace(B, S, KVH, hd, block):
    cache = jnp.asarray(RNG.standard_normal((B, S, KVH, hd)), jnp.float32)
    new = jnp.asarray(RNG.standard_normal((B, KVH, hd)), jnp.float32)
    pos = jnp.asarray(RNG.integers(0, S, B), jnp.int32)
    want = ref.kv_cache_write_ref(cache, new, pos)
    got = ops.kv_cache_write(cache, new, pos, block_s=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
