"""Smoke tests for the paper's own evaluation models (qwen3-30b-a3b and
deepseek-v3 reduced variants) + config registry sanity."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, PAPER_MODELS, REGISTRY, get_config
from repro.models import model as M


def test_registry_complete():
    assert len(ASSIGNED) == 10
    assert set(PAPER_MODELS) == {"deepseek-v2-lite-16b", "qwen3-30b-a3b",
                                 "deepseek-v3"}
    with pytest.raises(KeyError):
        get_config("nonexistent-model")


@pytest.mark.parametrize("name", ["qwen3-30b-a3b", "deepseek-v3"])
def test_paper_model_smoke(name):
    cfg = get_config(name + "-smoke")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    logits, aux = M.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all()


def test_full_config_param_counts():
    """Full (non-reduced) configs land near their nameplate sizes."""
    approx = {
        "yi-6b": (5e9, 8e9),
        "chatglm3-6b": (5e9, 8e9),
        "deepseek-v2-lite-16b": (13e9, 18e9),
        "qwen3-30b-a3b": (25e9, 34e9),
        "arctic-480b": (380e9, 520e9),
        "mamba2-1.3b": (0.9e9, 1.7e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "qwen1.5-0.5b": (0.4e9, 0.8e9),
        "stablelm-3b": (2.0e9, 3.5e9),
        "hubert-xlarge": (0.7e9, 1.3e9),
        "deepseek-v3": (550e9, 750e9),
    }
    for name, (lo, hi) in approx.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_active_param_counts_much_smaller_for_moe():
    for name in ["arctic-480b", "deepseek-v3", "qwen3-30b-a3b"]:
        cfg = get_config(name)
        assert cfg.param_count(active_only=True) < 0.25 * cfg.param_count()
