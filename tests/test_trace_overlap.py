"""Acceptance tests for the tracing layer over the real concurrency surface
(DESIGN.md §9): a closed-loop ``ClusterDriver`` run over an overlapped
``ElasticServer`` exports a Chrome-trace JSON in which a per-``TransferOp``
span demonstrably overlaps a ``decode.tick`` span — the visual proof of
STAGING ∥ serving — and ``tools/trace_report.py`` summarizes it.  The
simulator emits the same schema in sim-time.
"""
import json
import sys
from pathlib import Path

import pytest

from helpers import TEST_MOE, run_with_devices

REPO = Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_driver_closed_loop_trace_transfer_overlaps_decode(tmp_path):
    """The ISSUE's acceptance criterion: closed-loop driver, real engine,
    staging="overlap", exported trace shows a transfer-op span intersecting
    a decode-tick span; trace_report prints the overlap count; routing
    histograms ride along in the same trace."""
    trace_path = tmp_path / "trace.json"
    out = run_with_devices(TEST_MOE + f"""
import sys, time
from repro import obs
from repro.core.coordinator import ScalingPolicy
from repro.core.elastic_engine import ElasticServer
from repro.core.topology import ElasticConfig
from repro.serving.driver import ClusterDriver, DriverConfig
from repro.serving.metrics import SLO, summarize
from repro.serving.workload import scripted_burst

tr = obs.install(obs.Tracer(capacity=200_000))

policy = ScalingPolicy(slo=SLO(ttft_s=1.0, tpot_s=1.0), window=8,
                       cooldown_s=1.0, queue_scale_up=3)
srv = ElasticServer(MCFG, tp=2, batch_per_replica=2, max_len=128,
                    prefill_buckets=(32,), seed=0, staging="overlap",
                    transfer_workers=1, routing_sample_every=4)
srv.boot(ElasticConfig(dp=2, tp=2, devices=(0,1,2,3)))

# throttle each transfer op so the staging window deterministically spans
# several driver ticks (same trick as test_overlap_staging.py)
orig = srv.hmm._stage_unit
def slow_unit(*a, **k):
    time.sleep(0.05)
    return orig(*a, **k)
srv.hmm._stage_unit = slow_unit

driver = ClusterDriver(srv, policy, mcfg=MCFG, tp=2, device_pool=range(6),
                       config=DriverConfig(dt=0.05, settle_s=2.0,
                                           prewarm_next=False))
reqs = scripted_burst([(0.0, 2), (0.5, 7), (6.0, 1)], vocab_size=128, seed=1)
until = 0.0
while any(r.finish_s is None for r in reqs):
    until += 10.0
    driver.run(reqs if until == 10.0 else [], until=until)
    assert until < 400.0, "stalled"
assert any(e.direction == "up" for e in driver.events)

doc = obs.write_chrome_trace({str(trace_path)!r}, tr,
                             extra_metadata={{"run": "acceptance"}})
obs.validate_trace(doc)

cats = {{r.get("cat") for r in doc["traceEvents"] if r["ph"] != "M"}}
for want in ("scale", "hmm", "transfer", "serve", "req", "routing"):
    assert want in cats, (want, cats)

# the acceptance predicate: >= 1 transfer-op span intersects a decode tick
sys.path.insert(0, {str(REPO / "tools")!r})
import trace_report
n_transfer, n_overlap, n_ticks = trace_report.overlap_report(doc)
assert n_transfer >= 1 and n_ticks >= 1, (n_transfer, n_ticks)
assert n_overlap >= 1, "no TransferOp span overlapped a decode.tick span"

# routing histograms were sampled during the run and reach summarize()
rt = srv.routing_stats()
assert rt is not None and rt["samples"] >= 1
assert rt["counts"].shape == (MCFG.num_layers, MCFG.num_experts)
summ = summarize(driver.finished, backend=srv)
assert summ["routing_samples"] == rt["samples"]

# driver events carry the routing telemetry columns
done = [e for e in driver.events if e.routing_samples is not None]
assert done, [e.routing_samples for e in driver.events]

# the CLI consumes the exported file end to end
assert trace_report.main([{str(trace_path)!r}]) == 0
print("TRACE-OVERLAP-OK", n_transfer, n_overlap, n_ticks)
""")
    assert "TRACE-OVERLAP-OK" in out
    # the artifact written by the subprocess is a loadable Chrome trace
    doc = json.loads(trace_path.read_text())
    assert doc["traceEvents"] and doc["metadata"] == {"run": "acceptance"}


def test_sim_backend_emits_same_schema_in_sim_time():
    """The simulator emits the same event schema with explicit sim-time
    stamps: a scale.STAGING span on the sim-scale lane covering
    [t_command, t_ready], decode ticks at the modelled step duration, and
    per-request lifecycle instants — no wall-clock values leak in."""
    from repro import obs
    from repro.configs import get_config
    from repro.core.topology import ElasticConfig
    from repro.serving.simulator import ServingSimulator
    from repro.serving.workload import Request

    tr = obs.install(obs.Tracer())
    try:
        mcfg = get_config("deepseek-v2-lite-16b")
        sim = ServingSimulator(mcfg, tp=2, ndev=4, strategy="elastic")
        reqs = [Request(i, 0.0, 512, 20) for i in range(4)]
        for r in reqs:
            sim.submit(r)
        task = sim.start_scale(ElasticConfig(4, 2, tuple(range(8))))
        t, horizon = 0.0, 600.0
        while t < horizon and (any(r.finish_s is None for r in reqs)
                               or not task.done):
            sim.step(t)
            if not task.done:
                task.advance(t)
            t += 0.05
        assert all(r.finish_s is not None for r in reqs)
        assert task.done

        evs = tr.events()
        staging = [e for e in evs if e.name == "scale.STAGING"]
        assert len(staging) == 1 and staging[0].tid == "sim-scale"
        assert staging[0].t0 == task.event.t_command
        assert staging[0].t1 == task.event.t_ready
        commits = [e for e in evs if e.name == "scale.commit"]
        assert len(commits) == 1 and commits[0].ph == "i"

        ticks = [e for e in evs if e.name == "decode.tick"]
        assert ticks and all(e.tid == "sim" for e in ticks)
        # sim clock domain: every timestamp sits inside the sim horizon,
        # nowhere near time.perf_counter()'s wall-clock origin
        assert all(0.0 <= e.t0 <= horizon and e.t1 <= 2 * horizon
                   for e in evs if e.ph == "X")
        # span duration is the modelled decode step, not quantum dt
        b, nd = ticks[0].args["batch"], ticks[0].args["ndev"]
        assert ticks[0].dur == pytest.approx(sim.perf.decode_step_s(b, nd))

        admits = {e.args["rid"] for e in evs if e.name == "req.admit"}
        firsts = {e.args["rid"] for e in evs if e.name == "req.first_token"}
        finishes = {e.args["rid"] for e in evs if e.name == "req.finish"}
        assert admits == firsts == finishes == {0, 1, 2, 3}

        # the same exporter consumes a sim-time trace unchanged
        doc = obs.chrome_trace(tr)
        obs.validate_trace(doc)
        spans = [r for r in doc["traceEvents"] if r["ph"] == "X"]
        assert min(r["ts"] for r in spans) == 0.0     # normalized
    finally:
        obs.install(None)


def test_null_tracer_keeps_sim_and_scheduler_silent():
    """With no tracer installed the instrumented paths stay no-ops — the
    guard every hot loop relies on for the <=2%% overhead budget."""
    from repro import obs
    from repro.serving.scheduler import PrefillJob, TokenBudgetScheduler

    assert obs.get_tracer() is obs.NULL_TRACER
    sched = TokenBudgetScheduler(chunk=8)
    plans = sched.plan([PrefillJob(slot=0, rid=0, pos=0, total=16)])
    assert [p.take for p in plans] == [8]
    assert obs.NULL_TRACER.events() == []
