"""Unit tests for serving/metrics.py: SLO verdicts on partially-complete
requests, ITL iteration edge cases, backend-stat normalization, and
old-vs-new parity for the single-pass ``slo_attainment_timeline``."""
from __future__ import annotations

import math

import numpy as np
import pytest

from repro.serving.metrics import (SLO, iter_itls, kv_pool_stats,
                                   latency_percentiles, meets_slo,
                                   scaling_overlap_stats, slo_attainment,
                                   slo_attainment_timeline, summarize)
from repro.serving.workload import Request


def req(rid, arrival=0.0, first=None, finish=None, out_len=10,
        token_times=None):
    r = Request(rid, arrival, prompt_len=8, output_len=out_len)
    r.first_token_s = first
    r.finish_s = finish
    r.token_times = token_times
    return r


SLO_1 = SLO(ttft_s=1.0, tpot_s=0.1)


# ---------------------------------------------------------------- meets_slo

def test_meets_slo_partial_completion():
    assert meets_slo(req(0), SLO_1) is None                    # nothing yet
    assert meets_slo(req(1, first=0.5), SLO_1) is None         # no finish
    # finish but no first token (preempted/declined): no ttft -> no verdict
    assert meets_slo(req(2, finish=3.0), SLO_1) is None
    assert meets_slo(req(3, first=0.5, finish=1.1), SLO_1) is True
    assert meets_slo(req(4, first=2.0, finish=2.5), SLO_1) is False  # ttft
    assert meets_slo(req(5, first=0.5, finish=5.0), SLO_1) is False  # tpot
    # single-token output: tpot undefined, verdict on ttft alone
    assert meets_slo(req(6, first=0.5, finish=0.5, out_len=1), SLO_1) is True


def test_slo_attainment_ignores_unjudgeable():
    reqs = [req(0), req(1, first=0.5, finish=1.0), req(2, first=2.0,
                                                       finish=2.1)]
    assert slo_attainment(reqs, SLO_1) == 0.5
    assert math.isnan(slo_attainment([req(0)], SLO_1))


# ----------------------------------------------------------------- iter_itls

def test_iter_itls_edge_cases():
    assert list(iter_itls([])) == []
    assert list(iter_itls([req(0)])) == []                     # no times
    assert list(iter_itls([req(0, token_times=[1.0])])) == []  # 1 token
    got = list(iter_itls([req(0, token_times=[1.0, 1.5, 2.5]),
                          req(1, token_times=[0.0, 0.25])]))
    assert got == pytest.approx([0.5, 1.0, 0.25])


def test_latency_percentiles_nan_when_empty():
    lat = latency_percentiles([])
    assert all(math.isnan(v) for v in lat.values())


# -------------------------------------------------- backend normalization

class _Backend:
    def __init__(self, kv=None, scaling=None, routing=None):
        self._kv, self._scaling, self._routing = kv, scaling, routing

    def kv_stats(self):
        return self._kv

    def scaling_summary(self):
        return self._scaling

    def routing_stats(self):
        return self._routing


def test_kv_pool_stats_normalization():
    assert kv_pool_stats(object()) is None          # no kv_stats at all
    assert kv_pool_stats(_Backend()) is None        # dense backend: None
    st = kv_pool_stats(_Backend(kv={"num_blocks": 8, "used_blocks": 3,
                                    "utilization": 0.375}))
    assert (st.num_blocks, st.used_blocks) == (8, 3)
    assert st.preemptions == 0                      # missing key defaults


def test_scaling_overlap_stats_normalization():
    assert scaling_overlap_stats(object()) is None
    assert scaling_overlap_stats(_Backend()) is None      # no events yet
    out = scaling_overlap_stats(_Backend(scaling={"decode_stall_s": 0.5}))
    assert out == {"staging_mode": "serial", "decode_stall_s": 0.5}
    out = scaling_overlap_stats(_Backend(scaling={
        "staging_mode": "overlap", "decode_stall_s": 0.1,
        "overlap_efficiency": 1.5, "scaledown_mode": "migrate",
        "migrated_blocks": 4, "migration_bytes": 1024}))
    assert out["overlap_efficiency"] == 1.5
    assert out["scaledown_mode"] == "migrate"
    assert out["migrated_blocks"] == 4 and out["migration_bytes"] == 1024


def test_summarize_ttft_matches_percentile_core_and_routing():
    reqs = [req(i, first=0.1 * (i + 1), finish=1.0 + i) for i in range(5)]
    out = summarize(reqs, slo=SLO_1)
    lat = latency_percentiles(reqs)
    assert out["ttft_p50"] == lat["ttft_p50"]
    assert out["ttft_p99"] == lat["ttft_p99"]
    ttfts = [r.ttft for r in reqs]
    assert out["ttft_p50"] == float(np.median(ttfts))  # p50 == median
    assert "routing_samples" not in out
    empty = summarize([])
    assert math.isnan(empty["ttft_p50"]) and math.isnan(empty["ttft_p99"])

    rt = {"samples": 3, "counts": np.ones((2, 4)),
          "top_expert_share": 0.25, "expert_cv": 0.0}
    out = summarize(reqs, backend=_Backend(routing=rt))
    assert out["routing_samples"] == 3
    assert out["routing_top_expert_share"] == 0.25
    assert out["routing_expert_cv"] == 0.0
    # telemetry-absent backend adds no routing keys
    out = summarize(reqs, backend=_Backend())
    assert "routing_samples" not in out


# ------------------------------------------------------- timeline parity

def _timeline_reference(reqs, slo, window_s=10.0, dt=1.0):
    """The original O(T·N) rescan, kept verbatim as the parity oracle."""
    finished = [r for r in reqs if r.finish_s is not None]
    if not finished:
        return np.array([]), np.array([])
    t_end = max(r.finish_s for r in finished)
    ts = np.arange(0.0, t_end + dt, dt)
    att = []
    for t in ts:
        win = [r for r in finished if t - window_s <= r.finish_s <= t]
        oks = [meets_slo(r, slo) for r in win]
        oks = [o for o in oks if o is not None]
        att.append(sum(oks) / len(oks) if oks else np.nan)
    return ts, np.array(att)


def _random_reqs(rng, n):
    reqs = []
    for i in range(n):
        finish = float(rng.uniform(0, 60)) if rng.random() < 0.8 else None
        first = (float(rng.uniform(0, 2.0))
                 if finish is not None and rng.random() < 0.9 else None)
        reqs.append(req(i, first=first, finish=finish,
                        out_len=int(rng.integers(1, 20))))
    return reqs


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("window_s,dt", [(10.0, 1.0), (3.5, 0.7), (0.5, 2.0)])
def test_timeline_parity_old_vs_new(seed, window_s, dt):
    rng = np.random.default_rng(seed)
    reqs = _random_reqs(rng, 40)
    ts_new, att_new = slo_attainment_timeline(reqs, SLO_1, window_s, dt)
    ts_ref, att_ref = _timeline_reference(reqs, SLO_1, window_s, dt)
    np.testing.assert_array_equal(ts_new, ts_ref)
    np.testing.assert_array_equal(att_new, att_ref)  # NaN positions too


def test_timeline_empty_and_unjudgeable():
    assert slo_attainment_timeline([], SLO_1)[0].size == 0
    # finishes exist but no verdicts (no first_token): all-NaN timeline
    ts, att = slo_attainment_timeline([req(0, finish=2.0)], SLO_1)
    assert ts.size == len(att) and np.isnan(att).all()


def test_timeline_window_inclusive_both_ends():
    r = req(0, first=0.1, finish=5.0, out_len=1)  # tpot undefined: ttft-only
    ts, att = slo_attainment_timeline([r], SLO_1, window_s=5.0, dt=5.0)
    # at t=5.0: window [0, 5] includes finish_s == t
    assert att[-1] == 1.0
    ts, att = slo_attainment_timeline([r], SLO_1, window_s=2.0, dt=1.0)
    # at t=7.0 the window [5, 7] still includes it; beyond t_end not sampled
    assert att[-1] == 1.0
